"""Property tests for the cubic sparsity schedule (paper Eq. 2)."""
import jax.numpy as jnp
import numpy as np
from _hypo import given, settings, strategies as st

from repro.core.schedule import keep_count, sparsity_at


@given(s_max=st.floats(0.05, 0.99), m=st.integers(10, 10_000),
       d=st.integers(0, 5))
@settings(max_examples=50, deadline=None)
def test_endpoints(s_max, m, d):
    d = min(d, m - 1)
    s0 = float(sparsity_at(0, s_init=0.0, s_max=s_max, total_steps=m,
                           decay=d))
    sm = float(sparsity_at(m, s_init=0.0, s_max=s_max, total_steps=m,
                           decay=d))
    assert abs(s0 - 0.0) < 1e-5
    assert abs(sm - s_max) < 1e-5


@given(s_max=st.floats(0.05, 0.99), m=st.integers(10, 1000))
@settings(max_examples=25, deadline=None)
def test_monotone_nondecreasing(s_max, m):
    steps = np.linspace(0, m, 17).astype(int)
    vals = [float(sparsity_at(i, s_init=0.0, s_max=s_max,
                              total_steps=m)) for i in steps]
    assert all(b >= a - 1e-6 for a, b in zip(vals, vals[1:]))


def test_decay_reaches_smax_early():
    # with decay d, s hits s_max at step m-d (paper §5.4.3)
    s = sparsity_at(900, s_init=0.0, s_max=0.8, total_steps=1000,
                    decay=100)
    assert abs(float(s) - 0.8) < 1e-6


@given(s=st.floats(0.0, 1.0), n=st.integers(1, 512))
@settings(max_examples=50, deadline=None)
def test_keep_count_bounds(s, n):
    k = int(keep_count(jnp.float32(s), n))
    assert 1 <= k <= n
    # never keeps fewer than the exact fraction rounded up
    assert k >= min(n, max(1, int(np.ceil((1 - s) * n) - 1e-9)))
