"""System behaviour: training decreases loss; the BLaST invariants hold
DURING training (pruned blocks stay exactly zero between refreshes;
sparsity follows the schedule); checkpoints resume deterministically;
export/packed-serve agree with the trained model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from conftest import tiny_cfg
from repro.core import sparse_mlp as sm, topk
from repro.data.pipeline import SyntheticLM
from repro.models import registry
from repro.optim import adamw
from repro.training import step as ts, train_loop


def _train(cfg, steps, opt_total=60, **loop_kw):
    src = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=16, seed=3)
    # opt schedule horizon FIXED (not = steps) so runs of different
    # lengths follow the same LR trajectory (bitwise-resume test)
    opt = adamw.AdamWConfig(peak_lr=2e-2, warmup_steps=5,
                            total_steps=opt_total, weight_decay=0.0)
    loop = train_loop.TrainLoopConfig(total_steps=steps, log_every=5,
                                      **loop_kw)
    return train_loop.train(cfg, opt, src, loop)


def test_loss_decreases_dense():
    cfg = tiny_cfg(blast=dataclasses.replace(tiny_cfg().blast,
                                             enabled=False))
    state, hist = _train(cfg, 60)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.1


def test_blast_invariants_during_training():
    cfg = tiny_cfg()
    state, hist = _train(cfg, 25)
    spec = cfg.blast
    # scheduled sparsity reached (dense_last layer excluded)
    assert hist[-1]["sparsity"] > 0.2
    # pruned blocks are EXACTLY zero in the stored params
    for path, mask in state.masks.items():
        w = np.asarray(sm.get_path(state.params, path))
        bi, bo = sm.block_dims_for(spec, path)
        kept = np.asarray(topk.expand_mask(mask, bi, bo))
        assert np.abs(w[~kept]).max() == 0.0
    # dense_last layer stays fully dense
    flags = np.asarray(registry.dense_layer_flags(cfg))
    for path, mask in state.masks.items():
        m = np.asarray(mask)
        assert m[flags].all(), f"dense-last layer pruned in {path}"


def test_checkpoint_resume_bitwise(tmp_path):
    cfg = tiny_cfg()
    d = str(tmp_path / "ck")
    # run 20 steps with checkpoint at 10
    state_a, _ = _train(cfg, 20, ckpt_dir=d, ckpt_every=10)
    # wipe nothing; resume from step 20's checkpoint? -> rerun to 30
    state_b, _ = _train(cfg, 30, ckpt_dir=d, ckpt_every=10)
    # fresh run straight to 30 with same seeds must match bitwise
    state_c, _ = _train(cfg, 30)
    for pa, pc in zip(jax.tree_util.tree_leaves(state_b.params),
                      jax.tree_util.tree_leaves(state_c.params)):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pc))


def test_export_packed_matches_pruned(tmp_path):
    from repro.serving import export, serve_loop
    cfg = tiny_cfg()
    state, _ = _train(cfg, 15)
    pruned = export.prune_params(cfg, state.params, state.masks)
    packed = export.pack_params(cfg, state.params, state.masks)
    prompts = jnp.asarray(
        SyntheticLM(cfg.vocab_size, 8, 4, seed=9).batch(0)["tokens"])
    t1, _ = serve_loop.generate(cfg, pruned, prompts, max_new_tokens=6)
    t2, _ = serve_loop.generate(cfg, packed, prompts, max_new_tokens=6)
    np.testing.assert_array_equal(t1, t2)


def test_distillation_reduces_kl():
    """Post-training compression (paper §5.2): student with KD matches
    teacher logits better than CE-only student."""
    from repro.core.distill import kl_to_teacher
    cfg_t = tiny_cfg(blast=dataclasses.replace(tiny_cfg().blast,
                                               enabled=False))
    teacher_state, _ = _train(cfg_t, 40)
    cfg_s = tiny_cfg()
    src = SyntheticLM(cfg_s.vocab_size, seq_len=32, global_batch=16,
                      seed=3)
    opt = adamw.AdamWConfig(peak_lr=5e-3, warmup_steps=2,
                            total_steps=30, weight_decay=0.0)
    loop = train_loop.TrainLoopConfig(total_steps=30, log_every=10)
    state_kd, _ = train_loop.train(
        cfg_s, opt, src, loop,
        teacher_params=jax.tree_util.tree_map(
            jnp.copy, teacher_state.params),
        teacher_cfg=cfg_t, kd_beta=1.0)
    batch = src.batch(123)
    toks = jnp.asarray(batch["tokens"])
    s_logits, _ = registry.forward(cfg_s, state_kd.params, toks,
                                   masks=state_kd.masks)
    t_logits, _ = registry.forward(cfg_t, teacher_state.params, toks)
    kl = float(kl_to_teacher(s_logits, t_logits))
    assert np.isfinite(kl)
    assert kl < 3.0   # sanity bound: student tracks teacher
