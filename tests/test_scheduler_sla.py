"""Scheduler tests: SLA-class ordering, deadline (EDF) order, aging /
no-starvation, and the accounting fixes (queued_at stamped at enqueue,
uid-aware page-gate rejection counting, unified submit-time
feasibility). Pure host-side — no model, no jax."""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _hypo import given, settings, strategies as st  # noqa: E402

from repro.serving.scheduler import (BATCH, INTERACTIVE,  # noqa: E402
                                     FIFOScheduler, Request, SLAScheduler)


def _req(uid, plen=4, budget=8, **kw):
    return Request(uid, np.arange(1, plen + 1, dtype=np.int32), budget,
                   **kw)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


# --------------------------------------------------------- satellite bugs
def test_queued_at_stamped_at_submit_not_construction():
    """A request constructed long before submission must not inflate
    queued-time stats: the enqueue re-stamps ``queued_at``."""
    clock = FakeClock(100.0)
    sched = FIFOScheduler(2, 16, clock=clock)
    req = _req(0)                     # constructed at fake-time "now"
    ctor_stamp = req.queued_at        # time.monotonic(), irrelevant
    clock.t = 123.0
    sched.submit(req)
    assert req.queued_at == 123.0
    assert req.queued_at != ctor_stamp
    # deadline resolves against the enqueue stamp
    r2 = _req(1, deadline_s=2.5)
    clock.t = 200.0
    sched.submit(r2)
    assert r2.deadline_at == 202.5
    assert req.deadline_at is None


def test_rejections_count_distinct_blocked_heads():
    """A single page-blocked head waiting N engine steps is ONE
    rejection event (but N rejected_steps); a new blocked head is a
    second event."""
    sched = FIFOScheduler(4, 16)
    sched.submit(_req(0))
    blocked = lambda group: 10**9     # page gate always over budget
    for _ in range(5):
        assert sched.admit(4, free_pages=0, page_cost=blocked) == []
    assert sched.rejections == 1
    assert sched.rejected_steps == 5
    # head admitted elsewhere -> new head blocks -> second event
    [r0] = sched.admit(4)
    assert r0.uid == 0
    sched.submit(_req(7))
    for _ in range(3):
        assert sched.admit(4, free_pages=0, page_cost=blocked) == []
    assert sched.rejections == 2
    assert sched.rejected_steps == 8
    sched.reset_stats()
    assert sched.rejections == 0 and sched.rejected_steps == 0


def test_feasibility_hook_runs_at_submit():
    """The engine-installed feasibility hook rejects at submit, after
    the slot gate, with the hook's own message."""
    sched = FIFOScheduler(2, 16)

    def hook(req):
        if req.prompt_len > 8:
            raise ValueError("oversized request: too many pages")
    sched.feasibility = hook
    sched.submit(_req(0, plen=8))          # passes both gates
    with pytest.raises(ValueError, match="max_len"):
        sched.submit(_req(1, plen=16))     # slot gate first
    with pytest.raises(ValueError, match="oversized request"):
        sched.submit(_req(2, plen=12))     # then the page gate
    assert len(sched) == 1                 # rejected requests never queue


# ----------------------------------------------------------- SLA ordering
def test_priority_classes_order_admission():
    clock = FakeClock()
    sched = SLAScheduler(4, 16, clock=clock)
    sched.submit(_req(0, priority=BATCH))
    sched.submit(_req(1, priority=BATCH))
    sched.submit(_req(2, priority=INTERACTIVE))
    # interactive jumps the batch tier; within a class, arrival order
    assert [r.uid for r in sched.admit(3)] == [2, 0, 1]


def test_strict_arrival_order_within_class():
    sched = SLAScheduler(8, 16, clock=FakeClock())
    for uid in range(5):
        sched.submit(_req(uid, priority=BATCH))
    assert [r.uid for r in sched.admit(8)] == [0, 1, 2, 3, 4]


def test_deadline_orders_within_class_only():
    clock = FakeClock()
    sched = SLAScheduler(8, 16, clock=clock)
    sched.submit(_req(0, priority=BATCH, deadline_s=1.0))
    sched.submit(_req(1, priority=INTERACTIVE))          # no deadline
    sched.submit(_req(2, priority=INTERACTIVE, deadline_s=5.0))
    sched.submit(_req(3, priority=INTERACTIVE, deadline_s=2.0))
    # class first (0 before 1), EDF within class, deadline-less last
    assert [r.uid for r in sched.admit(8)] == [3, 2, 1, 0]


def test_aging_promotes_waiting_batch_request():
    clock = FakeClock()
    sched = SLAScheduler(4, 16, aging_s=10.0, clock=clock)
    sched.submit(_req(0, priority=BATCH))
    clock.t = 11.0                       # one full aging period waited
    sched.submit(_req(1, priority=INTERACTIVE))
    # batch aged to effective class 0; ties break by arrival -> 0 first
    assert [r.uid for r in sched.admit(1)] == [0]
    # aging disabled: interactive always wins
    sched2 = SLAScheduler(4, 16, aging_s=None, clock=clock)
    clock.t = 0.0
    sched2.submit(_req(0, priority=BATCH))
    clock.t = 1000.0
    sched2.submit(_req(1, priority=INTERACTIVE))
    assert [r.uid for r in sched2.admit(1)] == [1]


def test_page_gate_semantics_preserved_under_sla():
    """The ordered head still blocks head-of-line on pages — a batch
    request behind a page-blocked interactive head must wait."""
    sched = SLAScheduler(4, 16, clock=FakeClock())
    sched.submit(_req(0, priority=BATCH, budget=1))
    sched.submit(_req(1, priority=INTERACTIVE, budget=8))
    cost = lambda group: sum(r.max_new_tokens for r in group)
    # interactive head needs 8 pages, only 4 free: NOTHING admits even
    # though the batch request alone would fit
    assert sched.admit(4, free_pages=4, page_cost=cost) == []
    assert sched.rejections == 1
    # enough pages: ordered prefix admits
    got = sched.admit(4, free_pages=9, page_cost=cost)
    assert [r.uid for r in got] == [1, 0]


@settings(max_examples=15)
@given(prio=st.integers(1, 3), flood=st.integers(1, 3),
       seed=st.integers(0, 10**6))
def test_no_starvable_ordering_property(prio, flood, seed):
    """Anti-starvation bound: a class-``prio`` request facing a
    sustained flood of interactive arrivals is always admitted in
    bounded time — no priority ordering starves an aged request.

    The bound: everyone ages at the same rate, so only interactives
    arriving within ``prio * aging_s`` after the batch request can EVER
    outrank it (later arrivals never close the class gap before the
    batch request ties them, and ties break by arrival). That window
    holds at most ``flood * prio * aging_s / round`` competitors, each
    served one per round — total wait <=
    ``prio * aging_s * (1 + flood)`` plus scheduling slack."""
    aging_s = 10.0
    clock = FakeClock()
    sched = SLAScheduler(4, 16, aging_s=aging_s, clock=clock)
    rng = np.random.default_rng(seed)
    batch = _req(10**6, priority=prio)
    sched.submit(batch)
    admitted_at = None
    uid = 0
    for _ in range(400):                 # rounds of ~1s each
        for _ in range(flood):
            sched.submit(_req(uid, priority=INTERACTIVE))
            uid += 1
        got = sched.admit(1)             # one lane per round
        assert len(got) == 1
        if got[0] is batch:
            admitted_at = clock.t
            break
        clock.t += float(rng.uniform(0.5, 1.5))
    assert admitted_at is not None, "batch request starved"
    bound = prio * aging_s * (1 + flood) + aging_s + 5.0
    assert admitted_at - batch.queued_at <= bound


def test_push_front_restores_order():
    sched = SLAScheduler(4, 16, clock=FakeClock())
    for uid in range(3):
        sched.submit(_req(uid, priority=INTERACTIVE))
    got = sched.admit(3)
    sched.push_front(got[1:])            # un-admit 1 and 2
    assert [r.uid for r in sched.admit(3)] == [1, 2]
