"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see ONE
device (task spec); multi-device tests spawn subprocesses."""
import dataclasses

import jax
import pytest

from repro.configs.base import ModelConfig
from repro.core.prune_grow import BlastSpec


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_cfg(**overrides) -> ModelConfig:
    base = dict(
        name="tiny", family="dense", num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
        vocab_size=64, mlp_kind="glu", mlp_act="silu",
        norm_kind="rmsnorm", remat=False, compute_dtype="float32",
        chunk_size=8,
        blast=BlastSpec(enabled=True, b_in=16, b_out=16, s_max=0.75,
                        total_steps=20, step_size=5, dense_last=1),
    )
    base.update(overrides)
    return ModelConfig(**base)
