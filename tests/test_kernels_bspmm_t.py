"""Transposed BSpMM (backward) kernel + the trainable packed matmul."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing, topk
from repro.core.prune_grow import BlastSpec, generate_mask
from repro.kernels import bspmm_t, ops


def _packed(key, K, N, bi, bo, s, selection="balanced"):
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (K, N), jnp.float32)
    g = jax.random.normal(k2, (K, N), jnp.float32)
    spec = BlastSpec(b_in=bi, b_out=bo, s_max=s, total_steps=1,
                     selection=selection)
    m = generate_mask(spec, w, g, 1)
    wm = topk.apply_block_mask(w, m, bi, bo)
    return wm, packing.pack(wm, m, bi, bo)


SHAPES = [
    (16, 32, 32, 8, 8, 0.0),
    (32, 64, 96, 16, 16, 0.5),
    (64, 128, 64, 32, 16, 0.75),
    (8, 256, 128, 64, 32, 0.9),
]


@pytest.mark.parametrize("m,k,n,bi,bo,s", SHAPES)
def test_bspmm_t_vs_dense(m, k, n, bi, bo, s):
    key = jax.random.PRNGKey(hash((m, k, n)) % 2**31)
    dy = jax.random.normal(key, (m, n), jnp.float32)
    wm, p = _packed(key, k, n, bi, bo, s)
    want = dy @ wm.T
    got_k = bspmm_t.bspmm_t(dy, p, blk_m=min(m, 16), interpret=True)
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(want),
                               atol=2e-4, rtol=1e-4)
    got_x = ops.bspmm_t_xla(dy, p)
    np.testing.assert_allclose(np.asarray(got_x), np.asarray(want),
                               atol=2e-4, rtol=1e-4)


def test_bspmm_t_global_selection_padding():
    """Unbalanced masks pack with zero padding at idx 0 — the scatter
    kernel must stay exact with duplicate idx entries."""
    key = jax.random.PRNGKey(3)
    dy = jax.random.normal(key, (16, 64), jnp.float32)
    wm, p = _packed(key, 32, 64, 8, 8, 0.7, selection="global")
    want = dy @ wm.T
    got = bspmm_t.bspmm_t(dy, p, blk_m=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=1e-4)


def test_first_visit_flags():
    idx = np.asarray([[0, 2], [0, 1]])
    flags = bspmm_t.first_visit_flags(idx, kb=4)
    np.testing.assert_array_equal(flags, [[1, 1], [0, 1]])


def test_trainable_packed_grads():
    """custom_vjp: grads match the dense-matmul reference exactly on
    kept blocks and dX everywhere."""
    key = jax.random.PRNGKey(0)
    m, k, n, b = 16, 32, 32, 8
    x = jax.random.normal(key, (m, k), jnp.float32)
    wm, p = _packed(key, k, n, b, b, 0.5)
    c = jax.random.normal(jax.random.PRNGKey(9), (m, n))

    f = ops.make_bspmm_trainable(p.idx, p.kb)
    loss_packed = lambda x, blocks: (f(x, blocks) * c).sum()
    loss_dense = lambda x, w: ((x @ w) * c).sum()

    dx_p, dblocks = jax.grad(loss_packed, argnums=(0, 1))(x, p.blocks)
    dx_d, dw_d = jax.grad(loss_dense, argnums=(0, 1))(x, wm)
    np.testing.assert_allclose(np.asarray(dx_p), np.asarray(dx_d),
                               atol=2e-4, rtol=1e-4)
    # block grads match the dense grad at kept positions
    dw_blocks_dense = packing.pack(
        jnp.asarray(dw_d),
        jnp.ones((k // b, n // b), bool), b, b)  # dense grid pack
    # compare per kept block via unpack of the grad-packed structure
    dw_unpacked = packing.unpack(
        packing.PackedBCSC(blocks=dblocks, idx=p.idx, kb=p.kb))
    kept = np.asarray(topk.expand_mask(
        jnp.ones((k // b, n // b), bool), b, b))
    # only where the mask kept blocks: reconstruct mask from idx/unpack
    wm_np = np.asarray(wm)
    mask_elem = np.asarray(packing.unpack(
        packing.PackedBCSC(blocks=jnp.ones_like(p.blocks), idx=p.idx,
                           kb=p.kb))) > 0
    np.testing.assert_allclose(np.asarray(dw_unpacked)[mask_elem],
                               np.asarray(dw_d)[mask_elem],
                               atol=2e-4, rtol=1e-4)
    del dw_blocks_dense, kept, wm_np
