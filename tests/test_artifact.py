"""Validated serving artifacts (serving/artifact.py): seal/validate/
load round-trip is bitwise, every seeded corruption class is caught by
the layered defense (bytes -> structure -> canaries) with its TYPED
error before a token could be served, and a property-style bit-flip
sweep over every manifest region detects 100%. Also the export-side
satellite: ``pack_params`` no longer packs an unbalanced mask silently.
"""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg

from repro.core import packing, sparse_mlp as sm, topk
from repro.models import registry
from repro.serving import artifact, export
from repro.serving.faults import ARTIFACT_FAULTS, FaultPlan


def _masks(cfg, params, keep_frac=0.5):
    masks = {}
    for path in registry.sparse_paths(cfg):
        w = sm.get_path(params, path)
        bi, bo = sm.block_dims_for(cfg.blast, path)

        def mk(wi):
            s = topk.block_norms(wi, bi, bo)
            kb = wi.shape[-2] // bi
            return topk.topk_mask_per_col(s, max(1, int(kb * keep_frac)))

        fn = mk
        for _ in range(w.ndim - 2):
            fn = jax.vmap(fn)
        masks[path] = fn(w)
    return masks


@pytest.fixture(scope="module")
def sealed(tmp_path_factory):
    cfg = tiny_cfg()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    packed = export.pack_params(cfg, params, _masks(cfg, params),
                                dtype=jnp.float32)
    d = str(tmp_path_factory.mktemp("artifact") / "model")
    manifest = artifact.seal(cfg, packed, d)
    return cfg, packed, d, manifest


def test_seal_validate_load_roundtrip(sealed):
    cfg, packed, d, manifest = sealed
    assert manifest["format"] == artifact.FORMAT
    assert manifest["fingerprint"] == artifact.fingerprint(cfg)
    assert artifact.validate(d, cfg)["fingerprint"] == \
        manifest["fingerprint"]
    loaded, m2 = artifact.load(d, cfg, run_canaries=True)
    flat0 = {k: np.asarray(jax.device_get(v))
             for k, v in artifact._flatten_params(packed)[0].items()}
    flat1 = {k: np.asarray(jax.device_get(v))
             for k, v in artifact._flatten_params(loaded)[0].items()}
    assert set(flat0) == set(flat1)
    for k in flat0:
        np.testing.assert_array_equal(flat0[k], flat1[k])
    # packed metadata (static pytree fields) survives the round-trip
    p0 = {k: v for k, v in artifact._flatten_params(packed)[1].items()}
    p1 = {k: v for k, v in artifact._flatten_params(loaded)[1].items()}
    assert p0 == p1


def test_canaries_are_deterministic(sealed):
    cfg, packed, d, manifest = sealed
    assert manifest["canaries"], "seal produced no canaries"
    for c in manifest["canaries"]:
        toks, logits = artifact.canary_run(cfg, packed, c["prompt"],
                                           len(c["tokens"]))
        assert toks.tolist() == c["tokens"]


def test_corruption_sweep_every_class_typed(sealed, tmp_path):
    """THE acceptance sweep: every injector in ARTIFACT_FAULTS corrupts
    a fresh copy; validate/load must raise exactly the typed error the
    injector promises — 100% detection, zero silent loads."""
    cfg, _, d, _ = sealed
    caught = {}
    for kind in ARTIFACT_FAULTS:
        cp = str(tmp_path / kind)
        shutil.copytree(d, cp)
        plan = FaultPlan()
        expected = plan.on_artifact(cp, kind)
        assert f"artifact:{kind}" in plan.fired
        with pytest.raises(expected) as ei:
            artifact.load(cp, cfg, run_canaries=True)
        assert isinstance(ei.value, artifact.ArtifactError)
        caught[kind] = type(ei.value).__name__
    assert len(caught) == len(ARTIFACT_FAULTS)        # 100% detection
    # the *_signed kinds re-sign the checksums: they MUST get past the
    # byte layer and be caught by the deeper layer they target
    for kind, name in caught.items():
        if kind.endswith("_signed"):
            assert name != "ArtifactChecksumError", (kind, name)


def test_bitflip_sweep_all_regions(sealed, tmp_path):
    """Property-style: flip ONE bit in every stored array region (and
    one byte of the manifest itself); ``validate`` catches each."""
    cfg, _, d, manifest = sealed
    regions = sorted(manifest["checksums"])
    misses = []
    for n, region in enumerate(regions):
        cp = str(tmp_path / f"flip{n}")
        shutil.copytree(d, cp)
        data = dict(np.load(os.path.join(cp, "arrays.npz")))
        a = data[region]
        buf = bytearray(a.tobytes())
        buf[len(buf) // 2] ^= 0x10
        data[region] = np.frombuffer(bytes(buf), a.dtype).reshape(a.shape)
        np.savez(os.path.join(cp, "arrays.npz"), **data)
        try:
            artifact.validate(cp, cfg)
            misses.append(region)
        except artifact.ArtifactError:
            pass
    assert not misses, f"undetected bit flips in: {misses}"
    # a torn manifest is an IO error, not a crash
    cp = str(tmp_path / "manifest")
    shutil.copytree(d, cp)
    with open(os.path.join(cp, "manifest.json"), "r+") as f:
        f.seek(10)
        f.write("#")
    with pytest.raises(artifact.ArtifactIOError):
        artifact.validate(cp, cfg)


def test_validate_rejects_wrong_config(sealed):
    cfg, _, d, _ = sealed
    other = tiny_cfg(d_ff=128)
    with pytest.raises(artifact.ArtifactConfigError):
        artifact.validate(d, other)


def test_missing_artifact_is_io_error(tmp_path):
    with pytest.raises(artifact.ArtifactIOError):
        artifact.validate(str(tmp_path / "nope"))


# ------------------------------------------- export unbalanced satellite
def _unbalance(masks):
    """Drop one kept block from one column of the first mask, making it
    unbalanced; returns the edited path."""
    path = next(iter(masks))
    m = np.asarray(jax.device_get(masks[path])).copy()
    kept = np.argwhere(m[..., 0])          # indices into (lead..., Kb)
    m[tuple(kept[0]) + (0,)] = False
    masks[path] = jnp.asarray(m)
    return path


def test_pack_params_unbalanced_warns_and_reports():
    """An unbalanced mask (global top-k style) used to pack silently
    with hidden zero padding; now it warns with the pad fraction,
    reports per path, and can be made fatal."""
    cfg = tiny_cfg()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    masks = _masks(cfg, params)
    path = _unbalance(masks)
    report: dict = {}
    with pytest.warns(export.UnbalancedMaskWarning, match="unbalanced"):
        packed = export.pack_params(cfg, params, masks,
                                    dtype=jnp.float32,
                                    pad_report=report)
    assert path in report and 0.0 < report[path] < 1.0
    assert report[path] == pytest.approx(
        packing.pad_fraction(masks[path]))
    # packing stays numerically exact despite the padding
    p = sm.get_path(packed, path)
    assert not packing.structure_violations(p)
    with pytest.raises(ValueError, match="unbalanced"):
        export.pack_params(cfg, params, masks, dtype=jnp.float32,
                           unbalanced="raise")
    with pytest.warns(export.UnbalancedMaskWarning):
        export.pack_params(cfg, params, masks, dtype=jnp.float32)


def test_seal_records_pad_fractions(tmp_path):
    cfg = tiny_cfg()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    masks = _masks(cfg, params)
    _unbalance(masks)
    report: dict = {}
    with pytest.warns(export.UnbalancedMaskWarning):
        packed = export.pack_params(cfg, params, masks,
                                    dtype=jnp.float32,
                                    pad_report=report)
    assert report
    d = str(tmp_path / "padded")
    manifest = artifact.seal(cfg, packed, d, pad=report)
    assert manifest["pad"] == report
    assert artifact.validate(d, cfg)["pad"] == report
