"""Zero-downtime weight hot-swap (serving/hotswap.py): the bitwise
mid-stream oracle (old-generation lanes identical to a no-swap run,
new admissions identical to a pure-new-weights run, zero requests
dropped), canary gating (a corrupt artifact never flips), automatic
rollback on a post-flip quarantine spike, crash recovery composing
with the multi-generation window, and the live ``/metrics`` endpoint
satellite."""
import asyncio
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg

from repro.core import sparse_mlp as sm, topk
from repro.models import registry
from repro.obs.metrics import parse_prometheus_text
from repro.obs.trace import Tracer
from repro.serving import artifact, export, hotswap
from repro.serving.engine import Engine
from repro.serving.faults import EngineCrashError, FaultPlan
from repro.serving.frontend import AsyncEngine
from repro.serving.recovery import Supervisor


def _masks(cfg, params):
    masks = {}
    for path in registry.sparse_paths(cfg):
        w = sm.get_path(params, path)
        bi, bo = sm.block_dims_for(cfg.blast, path)

        def mk(wi):
            s = topk.block_norms(wi, bi, bo)
            return topk.topk_mask_per_col(
                s, max(1, (wi.shape[-2] // bi) // 2))

        fn = mk
        for _ in range(w.ndim - 2):
            fn = jax.vmap(fn)
        masks[path] = fn(w)
    return masks


@pytest.fixture(scope="module")
def world(tmp_path_factory):
    """Two packed param sets (old/new weights) + sealed artifacts."""
    cfg = tiny_cfg()
    p_old = registry.init_params(cfg, jax.random.PRNGKey(0))
    p_new = registry.init_params(cfg, jax.random.PRNGKey(7))
    masks = _masks(cfg, p_old)
    packed_old = export.pack_params(cfg, p_old, masks, dtype=jnp.float32)
    packed_new = export.pack_params(cfg, p_new, masks, dtype=jnp.float32)
    d = tmp_path_factory.mktemp("artifacts")
    art_old, art_new = str(d / "old"), str(d / "new")
    artifact.seal(cfg, packed_old, art_old)
    artifact.seal(cfg, packed_new, art_new)
    return cfg, packed_old, packed_new, art_old, art_new


def _prompts(cfg, lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, int(n)).astype(np.int32)
            for n in lens]


def _drain(eng, out=None):
    out = {} if out is None else out
    steps = 0
    while (len(eng.scheduler) or eng.active_lanes or eng._preempted
           or eng._pending_results):
        for r in eng.step():
            out[r.uid] = r
        steps += 1
        assert steps < 500
    return out


def _reference(cfg, params, prompts, n_tok):
    eng = Engine(cfg, params, max_batch=4, max_len=48, slab_k=4,
                 page_size=8)
    for p in prompts:
        eng.submit(p, n_tok)
    return _drain(eng)


# ------------------------------------------------------ bitwise oracle
def test_mid_stream_swap_bitwise_oracle(world):
    """THE acceptance oracle: swap mid-decode while two lanes stream
    and two more admit after the flip. Old-generation streams are
    bitwise-identical to a no-swap run, new admissions to a run that
    served the new weights from the start; zero requests dropped."""
    cfg, packed_old, packed_new, _, art_new = world
    prompts = _prompts(cfg, (6, 8, 5, 7))
    base_old = _reference(cfg, packed_old, prompts, 16)
    base_new = _reference(cfg, packed_new, prompts, 16)

    eng = Engine(cfg, packed_old, max_batch=4, max_len=48, slab_k=4,
                 page_size=8)
    for p in prompts[:2]:
        eng.submit(p, 16)
    out, step, rep = {}, 0, None
    while (len(eng.scheduler) or eng.active_lanes or eng._preempted
           or eng._pending_results or step < 2):
        if step == 1:
            rep = eng.swap_weights(art_new, monitor_steps=3)
            for p in prompts[2:]:            # admitted POST-flip
                eng.submit(p, 16)
        for r in eng.step():
            assert r.error is None, r.error
            out[r.uid] = r
        step += 1

    assert sorted(out) == [0, 1, 2, 3]       # zero dropped requests
    for uid in (0, 1):                       # old gen: bitwise no-swap
        assert out[uid].generated.tolist() == \
            base_old[uid].generated.tolist()
    for uid in (2, 3):                       # new gen: bitwise new-run
        assert out[uid].generated.tolist() == \
            base_new[uid].generated.tolist()
    assert rep.state == hotswap.COMMITTED
    assert rep.from_gen == 0 and rep.to_gen == 1
    assert rep.canary["token_mismatches"] == 0
    assert eng.stats["weight_swaps"] == 1
    assert eng.stats["swap_rollbacks"] == 0
    assert eng.stats["swap_canary_tokens"] > 0
    # the old generation was freed once its last lane retired
    assert len(eng._gen_params) == 1 and eng._gen in eng._gen_params


def test_swap_idle_engine_and_double_swap(world):
    """Swapping an idle engine works, a second swap chains (gen 2),
    and a swap during an open monitoring window is refused."""
    cfg, packed_old, packed_new, art_old, art_new = world
    eng = Engine(cfg, packed_old, max_batch=2, max_len=48, slab_k=4,
                 page_size=8)
    rep1 = eng.swap_weights(art_new, monitor_steps=1)
    with pytest.raises(RuntimeError, match="monitoring window"):
        eng.swap_weights(art_old)
    eng.submit(_prompts(cfg, (6,))[0], 8)
    out = _drain(eng)
    assert rep1.state == hotswap.COMMITTED
    rep2 = eng.swap_weights(art_old, monitor_steps=1)
    eng.submit(_prompts(cfg, (6,))[0], 8)
    _drain(eng, out)
    assert rep2.to_gen == 2 and rep2.state == hotswap.COMMITTED
    # after the round-trip the engine serves the ORIGINAL weights again
    base = _reference(cfg, packed_old, _prompts(cfg, (6,)), 8)
    assert out[1].generated.tolist() == base[0].generated.tolist()


# ------------------------------------------------------- canary gating
@pytest.mark.slow
def test_corrupt_artifact_never_flips(world, tmp_path):
    """Every corruption class from the artifact chaos catalogue is
    rejected at validate/canary time: the swap raises its typed error,
    the serving weights and generation are untouched, and the stream in
    flight finishes bitwise-clean on the old weights."""
    import shutil
    cfg, packed_old, _, _, art_new = world
    prompts = _prompts(cfg, (6,))
    base = _reference(cfg, packed_old, prompts, 8)

    for kind in ("block_bitflip", "idx_oob_signed",
                 "canary_weights_signed"):
        cp = str(tmp_path / kind)
        shutil.copytree(art_new, cp)
        plan = FaultPlan()
        expected = plan.on_artifact(cp, kind)
        tr = Tracer()
        eng = Engine(cfg, packed_old, max_batch=2, max_len=48,
                     slab_k=4, page_size=8, tracer=tr)
        eng.submit(prompts[0], 8)
        eng.step()
        with pytest.raises(expected):
            eng.swap_weights(cp)
        assert eng._gen == 0 and eng.params is packed_old
        assert eng._swap_monitor is None
        out = _drain(eng)
        assert out[0].generated.tolist() == base[0].generated.tolist()
        assert eng.stats["weight_swaps"] == 0
        reasons = [p["reason"] for p in tr.postmortems]
        if kind == "canary_weights_signed":
            assert eng.stats["swap_canary_failures"] == 1
            assert "swap.canary_failure" in reasons
        else:
            assert "swap.validate_failure" in reasons


# -------------------------------------------------- automatic rollback
def test_quarantine_spike_rolls_back(world):
    """A post-flip quarantine spike on the NEW generation triggers
    automatic rollback: the engine returns to the previous weights (as
    a fresh generation), the report and postmortem record the cause,
    and untouched old-generation lanes stream on bitwise-clean."""
    cfg, packed_old, _, _, art_new = world
    prompts = _prompts(cfg, (6, 8))
    base = _reference(cfg, packed_old, prompts, 16)

    tr = Tracer()
    eng = Engine(cfg, packed_old, max_batch=4, max_len=48, slab_k=4,
                 page_size=8, tracer=tr)
    eng.submit(prompts[0], 16)
    eng.step()
    rep = eng.swap_weights(art_new, monitor_steps=8, quarantine_limit=0)
    bad_gen = eng._gen
    eng.submit(prompts[1], 16)
    eng.step()
    lane = next(i for i in eng.active_lanes
                if eng.lanes[i].gen == bad_gen)
    eng._mirror["poison"][lane] = np.inf    # the new weights "are bad"
    eng._dirty = True
    out = _drain(eng)
    assert rep.state == hotswap.ROLLED_BACK
    assert rep.rollback_reason == "quarantine_spike"
    assert eng.params is packed_old         # rolled back, new gen id
    assert eng._gen == rep.rollback_gen == 2
    assert eng.stats["swap_rollbacks"] == 1
    assert eng.stats["swap_quarantines"] == 1
    assert out[0].error is None
    assert out[0].generated.tolist() == base[0].generated.tolist()
    assert out[1].error is not None         # the poisoned new-gen lane
    pm = [p for p in tr.postmortems if p["reason"] == "swap.rollback"]
    assert pm and pm[0]["meta"]["cause"] == "quarantine_spike"
    # old-gen quarantines must NOT count against a later swap's window
    assert eng._swap_monitor is None


def test_old_gen_quarantine_does_not_rollback(world):
    """An OLD-generation lane dying inside the monitoring window is not
    evidence against the new weights — the swap still commits."""
    cfg, packed_old, _, _, art_new = world
    prompts = _prompts(cfg, (6, 8))
    eng = Engine(cfg, packed_old, max_batch=4, max_len=48, slab_k=4,
                 page_size=8)
    eng.submit(prompts[0], 16)
    eng.step()
    old_lane = eng.active_lanes[0]
    rep = eng.swap_weights(art_new, monitor_steps=4, quarantine_limit=0)
    eng._mirror["poison"][old_lane] = np.inf
    eng._dirty = True
    out = _drain(eng)
    while eng._swap_monitor is not None:    # idle steps tick the window
        eng.step()
    assert out[0].error is not None
    assert rep.state == hotswap.COMMITTED
    assert eng.stats["swap_rollbacks"] == 0
    assert eng.stats["swap_quarantines"] == 0


# ------------------------------------------- crash x swap composition
@pytest.mark.slow
def test_crash_mid_window_recovers_per_generation(world):
    """Chaos composition: the stepper crashes while lanes from TWO
    generations are in flight. The supervisor's relaunch pins each lane
    to its admission-time generation, so every stream still finishes
    bitwise-identical to its own reference run."""
    cfg, packed_old, packed_new, _, art_new = world
    prompts = _prompts(cfg, (6, 8, 5, 7))
    base_old = _reference(cfg, packed_old, prompts, 16)
    base_new = _reference(cfg, packed_new, prompts, 16)

    eng = Engine(cfg, packed_old, max_batch=4, max_len=48, slab_k=4,
                 page_size=8)
    for p in prompts[:2]:
        eng.submit(p, 16)
    eng.step()
    rep = eng.swap_weights(art_new, monitor_steps=50)
    for p in prompts[2:]:
        eng.submit(p, 16)
    eng.step()                      # both generations now decoding
    gens = {eng.lanes[i].gen for i in eng.active_lanes}
    assert gens == {0, 1}, "window did not overlap generations"
    # kill the stepper mid-window; device lost => every lane relaunches
    # through the generation-pinned path
    eng.install_faults(FaultPlan().crash(eng._step_idx,
                                         device_lost=True))
    out = {}
    try:
        eng.step()
        raise AssertionError("crash did not fire")
    except EngineCrashError as e:
        Supervisor(eng).recover(e)
    assert set(eng._gen_pins.values()) == {0, 1}
    _drain(eng, out)
    for uid in (0, 1):
        assert out[uid].error is None
        assert out[uid].generated.tolist() == \
            base_old[uid].generated.tolist()
    for uid in (2, 3):
        assert out[uid].error is None
        assert out[uid].generated.tolist() == \
            base_new[uid].generated.tolist()
    assert rep.state in (hotswap.FLIPPED, hotswap.COMMITTED)
    assert len(eng._gen_params) == 1    # pins released, old gen freed


# ------------------------------------- front door + /metrics satellite
@pytest.mark.slow
def test_async_swap_and_metrics_endpoint(world):
    """The asyncio front door hot-swaps between steps without dropping
    a stream, and the live ``/metrics`` endpoint serves the registry as
    Prometheus text that round-trips through the repo's parser."""
    cfg, packed_old, packed_new, _, art_new = world
    prompts = _prompts(cfg, (6, 8))
    base_old = _reference(cfg, packed_old, prompts, 12)
    base_new = _reference(cfg, packed_new, prompts, 12)

    async def drive():
        eng = Engine(cfg, packed_old, max_batch=4, max_len=48,
                     slab_k=4, page_size=8)
        async with AsyncEngine(eng, metrics_port=0) as front:
            s0 = await front.submit_async(prompts[0], 12)
            await s0.__anext__()              # s0 is mid-decode
            rep = await front.swap_weights_async(art_new,
                                                 monitor_steps=2)
            s1 = await front.submit_async(prompts[1], 12)
            r0, r1 = await s0.result(), await s1.result()
            host, port = front.metrics_addr
            url = f"http://{host}:{port}/metrics"
            text = urllib.request.urlopen(url, timeout=10) \
                .read().decode()
            with pytest.raises(urllib.error.HTTPError):   # 404
                urllib.request.urlopen(f"http://{host}:{port}/nope",
                                       timeout=10)
            return eng, rep, r0, r1, text, url

    eng, rep, r0, r1, text, url = asyncio.run(drive())
    assert r0.generated.tolist() == base_old[0].generated.tolist()
    assert r1.generated.tolist() == base_new[1].generated.tolist()
    assert rep.state in (hotswap.FLIPPED, hotswap.COMMITTED)
    parsed = parse_prometheus_text(text)
    assert parsed["blast_weight_swaps"] == 1.0
    assert parsed["blast_weight_generation"] == 1.0
    assert parsed["blast_generated_tokens"] == \
        eng.stats["generated_tokens"]
    assert parsed["blast_swap_canary_tokens"] > 0
    # the endpoint went down with the front door
    with pytest.raises(urllib.error.URLError):
        urllib.request.urlopen(url, timeout=2)


def test_async_swap_rejects_corrupt_artifact(world, tmp_path):
    import shutil
    cfg, packed_old, _, _, art_new = world
    cp = str(tmp_path / "bad")
    shutil.copytree(art_new, cp)
    expected = FaultPlan().on_artifact(cp, "idx_bitflip")

    async def drive():
        eng = Engine(cfg, packed_old, max_batch=2, max_len=48,
                     slab_k=4, page_size=8)
        async with AsyncEngine(eng) as front:
            s = await front.submit_async(_prompts(cfg, (6,))[0], 8)
            with pytest.raises(expected):
                await front.swap_weights_async(cp)
            await s.result()
        return eng

    eng = asyncio.run(drive())
    assert eng._gen == 0 and eng.stats["weight_swaps"] == 0
