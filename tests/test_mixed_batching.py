"""Stall-free mixed batching (engine ``mixed=True`` + serving/step.py
``make_mixed_step`` + scheduler ``prefill_token_budget``):

  * greedy tokens are BITWISE-identical mixed vs phased vs the solo
    ``serve_loop`` oracle — across ragged continuous admission,
    mid-slab eviction/readmission, eos mid-stream, truncation at the
    slot cap, and prefix-cache partial hits;
  * decode never stalls for an arriving prompt: under continuous
    arrivals ``stalled_decode_steps`` is structurally 0 in mixed mode
    while the phased engine racks them up;
  * a long prompt is admitted CHUNK-GRANULARLY under the token budget —
    running lanes keep emitting tokens between its prefill chunks
    instead of waiting for a blocking prefill loop;
  * prefix-cached admissions landing in the same step share ONE
    batched tail-prefill call (phased) or fuse into the decode steps
    (mixed) — never a per-lane chunk loop each;
  * TTFT / inter-token latency are recorded per request.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import tiny_cfg
from repro.models import registry
from repro.serving import engine, serve_loop
from repro.serving.scheduler import FIFOScheduler

KW = dict(max_len=32, prefill_chunk=4, slab_k=4, page_size=4)


@pytest.fixture(scope="module")
def model():
    cfg = tiny_cfg()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=(int(p),))
            .astype(np.int32) for p in lens]


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("slab_k", [1, 4])
def test_mixed_bitwise_parity_ragged_admission_eviction(model, slab_k):
    """6 ragged requests over 2 lanes (continuous admission, mid-run
    eviction + readmission onto recycled pages): the mixed engine must
    emit exactly the phased engine's tokens, which match each request's
    solo oracle generation."""
    cfg, params = model
    prompts = _prompts(cfg, [6, 3, 5, 7, 4, 6], seed=7)
    budgets = (3, 9, 5, 2, 7, 4)

    def run(mixed):
        eng = engine.Engine(cfg, params, max_batch=2, mixed=mixed,
                            **dict(KW, slab_k=slab_k))
        uids = [eng.submit(p, n) for p, n in zip(prompts, budgets)]
        return uids, eng.run()

    uids0, phased = run(False)
    uids1, mix = run(True)
    assert uids0 == uids1
    for u, p, n in zip(uids0, prompts, budgets):
        np.testing.assert_array_equal(mix[u].tokens, phased[u].tokens)
        assert mix[u].truncated == phased[u].truncated
        want, _ = serve_loop.generate(cfg, params, jnp.asarray(p)[None],
                                      max_new_tokens=n, max_len=32)
        np.testing.assert_array_equal(mix[u].tokens, np.asarray(want)[0])


def _drive_continuous(cfg, params, prompts, budgets, *, mixed, **kw):
    """Submit one request per engine step (arrivals land while other
    lanes decode), drain, and finalize stats like ``run`` would."""
    eng = engine.Engine(cfg, params, mixed=mixed, **kw)
    uids = [eng.submit(prompts[0], budgets[0])]
    res, k, guard = {}, 1, 0
    while k < len(prompts) or eng.active_lanes or len(eng.scheduler):
        if k < len(prompts):
            uids.append(eng.submit(prompts[k], budgets[k]))
            k += 1
        for r in eng.step():
            res[r.uid] = r
        guard += 1
        assert guard < 400, "engine failed to drain"
    eng.finalize_stats()
    return uids, res, eng.stats


def test_mixed_decode_never_stalls_under_continuous_arrivals(model):
    """Prompts arriving mid-decode: the phased engine's blocking
    admission prefill stalls the running lanes (counter > 0); the mixed
    engine fuses those chunks into the decode step (counter == 0) and
    still emits bitwise-identical tokens. Budgets are RAGGED — equal
    budgets would let admission groups finish in lockstep, so no lane
    would ever be mid-decode when the next prompt admits."""
    cfg, params = model
    prompts = _prompts(cfg, [6, 7, 5, 8, 6], seed=3)
    budgets = (8, 4, 9, 3, 7)
    kw = dict(KW, max_batch=2, slab_k=2)
    u0, phased, st0 = _drive_continuous(cfg, params, prompts, budgets,
                                        mixed=False, **kw)
    u1, mix, st1 = _drive_continuous(cfg, params, prompts, budgets,
                                     mixed=True, **kw)
    assert u0 == u1
    for u in u0:
        np.testing.assert_array_equal(mix[u].tokens, phased[u].tokens)
    assert st0["stalled_decode_steps"] > 0      # phased: decode waited
    assert st1["stalled_decode_steps"] == 0     # mixed: never
    assert st1["mixed_steps"] > 0               # prefill rode along
    assert st1["decode_tokens"] == st0["decode_tokens"]


def test_token_budget_admits_long_prompt_chunk_granularly(model):
    """A 24-token prompt under prefill_token_budget=6 with a decode
    lane running: the prompt must enter over several fused steps (4
    prefill tokens each: budget 6 - 1 decode token, capped by the
    4-token chunk) while the running lane KEEPS EMITTING between those
    chunks — and the tokens still match the phased engine."""
    cfg, params = model
    long_p, short_p = _prompts(cfg, [24, 5], seed=11)
    kw = dict(max_len=40, prefill_chunk=4, slab_k=2, page_size=4,
              max_batch=2)

    def emitted(eng, uid):
        lanes = [i for i in eng.active_lanes
                 if eng.lanes[i].req.uid == uid]
        return len(eng.lanes[lanes[0]].generated) if lanes else None

    def run(mixed):
        eng = engine.Engine(cfg, params, mixed=mixed,
                            prefill_token_budget=6, **kw)
        u_short = eng.submit(short_p, 12)
        eng.step()                       # short prompt is decoding
        u_long = eng.submit(long_p, 4)
        grew = 0
        eng.step()                       # admits the long prompt
        while eng._prefilling:           # mixed only: incremental entry
            before = emitted(eng, u_short)
            eng.step()
            after = emitted(eng, u_short)
            grew += int(before is not None and after is not None
                        and after > before)
        res = eng.run()
        return res[u_short].tokens, res[u_long].tokens, eng.stats, grew

    s0, l0, st0, _ = run(False)
    s1, l1, st1, grew = run(True)
    np.testing.assert_array_equal(s0, s1)
    np.testing.assert_array_equal(l0, l1)
    # 24 tokens at <= 4 per fused step: at least 6 fused steps, decode
    # advancing alongside (never stalled)
    assert st1["mixed_steps"] >= 6
    assert grew >= 5
    assert st1["stalled_decode_steps"] == 0
    assert st0["stalled_decode_steps"] > 0


def test_mixed_eos_mid_stream_parity(model):
    cfg, params = model
    prompts = _prompts(cfg, [5, 7], seed=4)
    free, _ = engine.generate(cfg, params, prompts, max_new_tokens=10,
                              **dict(KW, slab_k=1))
    eos = int(free[1][prompts[1].size + 4])

    def run(mixed):
        eng = engine.Engine(cfg, params, max_batch=2, eos_id=eos,
                            mixed=mixed, **KW)
        uids = [eng.submit(p, 10) for p in prompts]
        return uids, eng.run()

    uids, phased = run(False)
    uids1, mix = run(True)
    assert uids == uids1
    for u in uids:
        np.testing.assert_array_equal(mix[u].tokens, phased[u].tokens)
    assert mix[uids[1]].generated[-1] == eos


def test_mixed_truncation_at_slot_cap_parity(model):
    """Lanes that run out of cache slots truncate at exactly the phased
    engine's token. max_batch=1 keeps phased admission groups singleton
    (offset 0), matching mixed's per-lane admission headroom."""
    cfg, params = model
    prompts = _prompts(cfg, [6, 3], seed=5)

    def run(mixed):
        eng = engine.Engine(cfg, params, max_batch=1, max_len=10,
                            prefill_chunk=4, slab_k=8, page_size=4,
                            mixed=mixed)
        uids = [eng.submit(p, 16) for p in prompts]
        return uids, eng.run(), eng.stats["truncated"]

    uids, phased, tr0 = run(False)
    uids1, mix, tr1 = run(True)
    assert tr0 == tr1 == 2
    for u in uids:
        assert mix[u].truncated and phased[u].truncated
        np.testing.assert_array_equal(mix[u].tokens, phased[u].tokens)


# ------------------------------------------------------------ prefix cache
def test_mixed_prefix_cache_partial_hits_parity(model):
    """Mixed batching composed with the radix-tree prefix cache: full,
    partial and disjoint hits, CoW divergence inside the boundary page
    — bitwise parity with the phased shared engine AND sharing-off."""
    cfg, params = model
    rng = np.random.default_rng(19)
    sys_p = rng.integers(0, cfg.vocab_size, size=(9,)).astype(np.int32)
    prompts = [np.concatenate([sys_p, [5]]).astype(np.int32),
               np.concatenate([sys_p, [7, 3]]).astype(np.int32),
               np.concatenate([sys_p[:5], rng.integers(
                   0, cfg.vocab_size, size=(4,)).astype(np.int32)]),
               rng.integers(0, cfg.vocab_size, size=(7,))
               .astype(np.int32)]
    budgets = (4, 6, 3, 5)
    kw = dict(KW, slab_k=2, max_batch=2, n_pages=24)

    def run(mixed, pc):
        eng = engine.Engine(cfg, params, prefix_cache=pc, mixed=mixed,
                            **kw)
        if pc:
            eng.submit(sys_p, 1)
            eng.run()
            eng.reset_stats()
        uids = [eng.submit(p, n) for p, n in zip(prompts, budgets)]
        res = eng.run()
        return [res[u].tokens for u in uids], eng.stats

    off, _ = run(False, False)
    phased_on, st0 = run(False, True)
    mixed_on, st1 = run(True, True)
    for a, b, c in zip(off, phased_on, mixed_on):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)
    assert st1["prefix_hits"] > 0
    assert st1["prefill_tokens_skipped"] > 0
    assert st1["stalled_decode_steps"] == 0
    assert (st1["prefill_tokens"] + st1["prefill_tokens_skipped"]
            == st1["prompt_tokens"])


def test_admit_shared_batches_cross_request_tail_prefill(model):
    """Satellite: two prefix-cached admissions landing in the SAME
    admission round share one prefill call per chunk round instead of a
    per-lane chunk loop each — ``prefill_chunks`` counts jitted calls,
    so two 4-token tails through one batched call cost ONE chunk, not
    two. Tokens stay bitwise-identical to sharing-off."""
    cfg, params = model
    rng = np.random.default_rng(23)
    sys_p = rng.integers(0, cfg.vocab_size, size=(12,)).astype(np.int32)
    p1 = np.concatenate([sys_p, [3, 9, 1]]).astype(np.int32)
    p2 = np.concatenate([sys_p, [8, 2, 4]]).astype(np.int32)
    kw = dict(KW, slab_k=2, max_batch=2, n_pages=24)
    eng = engine.Engine(cfg, params, prefix_cache=True, **kw)
    eng.submit(sys_p, 1)
    eng.run()                            # warm the tree
    eng.reset_stats()
    ua, ub = eng.submit(p1, 4), eng.submit(p2, 4)
    eng.step()
    assert eng.stats["admitted"] == 2    # same admission round
    # both 3-token uncovered tails fit one 4-wide chunk: ONE batched
    # call for the round, not one per lane
    assert eng.stats["prefill_chunks"] == 1
    assert eng.stats["prefill_tokens"] == 6
    res = eng.run()
    off, _ = engine.generate(cfg, params, [p1, p2], max_new_tokens=4,
                             **dict(kw, prefix_cache=False))
    np.testing.assert_array_equal(res[ua].tokens, off[0])
    np.testing.assert_array_equal(res[ub].tokens, off[1])


# ------------------------------------------------------- budget scheduler
def test_plan_chunks_spends_decode_first_then_fifo():
    s = FIFOScheduler(max_batch=4, max_len=32, prefill_token_budget=8)
    # 3 decode tokens spent first; 5 left: lane 7 gets the 4-token
    # chunk cap, lane 9 the single remaining token
    assert s.plan_chunks([(7, 10), (9, 6)], n_decode=3, chunk_cap=4) \
        == {7: 4, 9: 1}
    # decode saturates the budget: prompts wait (no stall, no chunk)
    assert s.plan_chunks([(7, 10)], n_decode=8, chunk_cap=4) == {}
    # no decode lanes: full budget to the head prompt, FIFO order
    assert s.plan_chunks([(1, 3), (2, 9)], n_decode=0, chunk_cap=4) \
        == {1: 3, 2: 4}
    # remaining-tokens cap wins over chunk cap
    assert s.plan_chunks([(5, 2)], n_decode=0, chunk_cap=4) == {5: 2}
    # None budget: chunk-cap-only (the phased tail-prefill shape)
    s2 = FIFOScheduler(max_batch=4, max_len=32)
    assert s2.plan_chunks([(1, 9), (2, 9)], n_decode=0, chunk_cap=4) \
        == {1: 4, 2: 4}


def test_mixed_requires_paged(model):
    cfg, params = model
    with pytest.raises(ValueError, match="requires paged"):
        engine.Engine(cfg, params, max_batch=1, max_len=16,
                      paged=False, mixed=True)


# ------------------------------------------------------------ observability
@pytest.mark.parametrize("mixed", [False, True])
def test_ttft_and_itl_recorded_per_request(model, mixed):
    cfg, params = model
    eng = engine.Engine(cfg, params, max_batch=2, mixed=mixed, **KW)
    uids = [eng.submit(p, 6) for p in _prompts(cfg, [5, 7], seed=2)]
    res = eng.run()
    for u in uids:
        assert res[u].ttft_s > 0.0       # submit -> first token
    st = eng.stats
    assert st["ttft_p95_s"] >= st["ttft_p50_s"] > 0.0
    assert st["itl_p95_s"] >= st["itl_p50_s"] >= 0.0
    assert len(eng._ttft) == 2
    assert len(eng._itl) == 2 * 5        # budget-1 decode gaps each
