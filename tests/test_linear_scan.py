"""Chunked linear attention == step-by-step recurrence (RWKV6 'bonus'
and Mamba2/SSD 'full' modes), including the decay-floor numerics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.linear_scan import (chunked_linear_attention,
                                      decay_floor, recurrent_step)


def _data(seed, B=2, S=64, H=3, dk=8, dv=8, scale=2.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, S, H, dk)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, dk)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, dv)) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, dk)) * scale)
    u = jax.random.normal(ks[4], (H, dk)) * 0.3
    return q, k, v, logw, u


def _ref(q, k, v, logw, u, mode, chunk):
    B, S, H, dk = q.shape
    st = jnp.zeros((B, H, dk, v.shape[-1]))
    ys = []
    for t in range(S):
        y, st = recurrent_step(q[:, t], k[:, t], v[:, t], logw[:, t],
                               st, u=u, chunk=chunk, include_diag=mode)
        ys.append(y)
    return jnp.stack(ys, 1), st


@pytest.mark.parametrize("mode,use_u", [("bonus", True), ("full", False)])
@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_matches_recurrence(mode, use_u, chunk):
    q, k, v, logw, u = _data(0)
    uu = u if use_u else None
    y_ref, st_ref = _ref(q, k, v, logw, uu, mode, chunk)
    y, st = chunked_linear_attention(q, k, v, logw, u=uu, chunk=chunk,
                                     include_diag=mode)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=2e-4, rtol=1e-3)


def test_extreme_decays_no_nan():
    """Two-sided-clamp bug regression: extreme decays must stay finite
    AND correct (found during development — EXPERIMENTS.md §Perf notes)."""
    q, k, v, logw, u = _data(3, scale=4.0)   # decays down to e^-e^8
    y, st = chunked_linear_attention(q, k, v, logw, u=u, chunk=16,
                                     include_diag="bonus")
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(st).all())
    y_ref, _ = _ref(q, k, v, u=u, logw=logw, mode="bonus", chunk=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=1e-3)


def test_state_continuation():
    q, k, v, logw, u = _data(1)
    y_full, st_full = chunked_linear_attention(q, k, v, logw, u=u,
                                               chunk=8,
                                               include_diag="bonus")
    y1, st1 = chunked_linear_attention(q[:, :32], k[:, :32], v[:, :32],
                                       logw[:, :32], u=u, chunk=8,
                                       include_diag="bonus")
    y2, st2 = chunked_linear_attention(q[:, 32:], k[:, 32:], v[:, 32:],
                                       logw[:, 32:], u=u, chunk=8,
                                       initial_state=st1,
                                       include_diag="bonus")
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               atol=1e-5)


def test_scalar_per_head_decay_broadcast():
    """Mamba2-style scalar decay: logw constant across dk."""
    q, k, v, logw, _ = _data(2)
    logw = jnp.broadcast_to(logw[..., :1], logw.shape)
    y, st = chunked_linear_attention(q, k, v, logw, chunk=16,
                                     include_diag="full")
    y_ref, st_ref = _ref(q, k, v, logw, None, "full", 16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=1e-3)


def test_decay_floor_value():
    assert decay_floor(16) == pytest.approx(-70.0 / 16)
