"""Checkpointer: roundtrip, atomicity, keep-k, latest discovery,
crc32 integrity manifest, corrupt/torn fallback, crash-window recovery,
async write-failure surfacing."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.checkpointing.checkpoint import Checkpointer
from repro.training import step as ts
from repro.training.faults import CheckpointCorruptionError


def test_roundtrip(tmp_path):
    cfg = tiny_cfg()
    state = ts.init_state(cfg, jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), keep=2)
    ck.save(1, state, blocking=True)
    restored = ck.restore_state(state)
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(state.masks),
                    jax.tree_util.tree_leaves(restored.masks)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_and_latest(tmp_path):
    cfg = tiny_cfg()
    state = ts.init_state(cfg, jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, state, blocking=True)
    assert ck.steps() == [3, 4]
    assert ck.latest_step() == 4


def test_no_tmp_left_behind(tmp_path):
    cfg = tiny_cfg()
    state = ts.init_state(cfg, jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(7, state, blocking=True)
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


def test_async_save(tmp_path):
    cfg = tiny_cfg()
    state = ts.init_state(cfg, jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path))
    ck.save(5, state, blocking=False)
    ck.wait()
    assert ck.latest_step() == 5


def _dict_state(step, seed=0):
    r = np.random.default_rng(seed)
    return {"step": np.int32(step),
            "w": r.standard_normal((8, 8)).astype(np.float32),
            "b": r.standard_normal(8).astype(np.float32)}


def _bitflip(d, step):
    f = os.path.join(d, f"step_{step:08d}", "arrays.npz")
    with open(f, "r+b") as fh:
        fh.seek(0, os.SEEK_END)
        off = fh.tell() // 2
        fh.seek(off)
        b = fh.read(1)
        fh.seek(off)
        fh.write(bytes([b[0] ^ 1]))


def test_async_write_failure_surfaces(tmp_path, monkeypatch):
    """A background write that dies must not die silently: the captured
    exception re-raises on wait() AND on the next save()."""
    ck = Checkpointer(str(tmp_path))
    boom = RuntimeError("disk full")

    def bad_savez(*a, **kw):
        raise boom

    monkeypatch.setattr(np, "savez", bad_savez)
    ck.save(1, _dict_state(1), blocking=False)
    with pytest.raises(RuntimeError, match="disk full"):
        ck.wait()
    # error is cleared after being raised once
    ck.wait()
    ck.save(2, _dict_state(2), blocking=False)
    ck._thread.join()        # error captured before unpatching savez
    monkeypatch.undo()
    with pytest.raises(RuntimeError, match="disk full"):
        ck.save(3, _dict_state(3), blocking=True)
    # the failed saves left nothing behind; a clean save works
    ck.save(4, _dict_state(4), blocking=True)
    assert ck.latest_intact_step() == 4


def test_crc_bitflip_detected_and_fallback(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    for s in (1, 2, 3):
        ck.save(s, _dict_state(s, seed=s), blocking=True)
    _bitflip(str(tmp_path), 3)
    assert not ck.verify(3)
    assert ck.verify(2)
    with pytest.raises(CheckpointCorruptionError):
        ck.restore(_dict_state(0), step=3)
    got = ck.restore(_dict_state(0))     # step=None: newest INTACT
    assert int(got["step"]) == 2
    assert ck.fallbacks == 1
    np.testing.assert_array_equal(got["w"], _dict_state(2, seed=2)["w"])


def test_torn_checkpoint_ignored(tmp_path):
    """A directory with garbage/missing files never satisfies verify
    and restore falls back past it."""
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(1, _dict_state(1), blocking=True)
    torn = tmp_path / "step_00000005"
    torn.mkdir()
    (torn / "meta.json").write_text("{not json")
    assert ck.steps() == [1, 5]
    assert not ck.verify(5)
    assert ck.latest_intact_step() == 1
    got = ck.restore(_dict_state(0))
    assert int(got["step"]) == 1


def test_leftover_tmp_and_old_recovered(tmp_path):
    """Crash-window recovery: an orphaned .old (final rename never
    happened) is promoted back; stale .tmp dirs are dropped; neither
    suffix is ever listed by steps()."""
    ck = Checkpointer(str(tmp_path))
    ck.save(4, _dict_state(4), blocking=True)
    # simulate a writer killed mid-swap: final parked at .old, new tmp
    final = tmp_path / "step_00000004"
    os.replace(final, str(final) + ".old")
    stale = tmp_path / "step_00000009.tmp"
    stale.mkdir()
    (stale / "junk").write_text("x")
    ck2 = Checkpointer(str(tmp_path))
    assert ck2.steps() == [4]
    assert ck2.verify(4)
    assert not (tmp_path / "step_00000009.tmp").exists()
    assert not (tmp_path / "step_00000004.old").exists()


def test_gc_never_deletes_newest_intact(tmp_path):
    """keep-k retention with the newest k checkpoints corrupt: the
    newest INTACT one is protected from GC and restore reaches it."""
    ck = Checkpointer(str(tmp_path), keep=2)
    fl = {"corrupt": set()}

    def hook(path, step):
        if step in fl["corrupt"]:
            f = os.path.join(path, "arrays.npz")
            with open(f, "r+b") as fh:
                fh.seek(0, os.SEEK_END)
                off = fh.tell() // 2
                fh.seek(off)
                b = fh.read(1)
                fh.seek(off)
                fh.write(bytes([b[0] ^ 1]))

    ck.fault_hook = hook
    fl["corrupt"] = {3, 4}
    for s in (1, 2, 3, 4):
        ck.save(s, _dict_state(s, seed=s), blocking=True)
    # keep=2 would retain only {3, 4} — both corrupt; step 2 must
    # survive as the newest intact checkpoint
    assert 2 in ck.steps()
    assert ck.latest_intact_step() == 2
    got = ck.restore(_dict_state(0))
    assert int(got["step"]) == 2


def test_no_intact_checkpoint_raises(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(1, _dict_state(1), blocking=True)
    _bitflip(str(tmp_path), 1)
    with pytest.raises(CheckpointCorruptionError, match="no intact"):
        ck.restore(_dict_state(0))


def test_overwrite_same_step_has_no_crash_window(tmp_path, monkeypatch):
    """Re-saving an existing step: if the process dies between parking
    the old dir and renaming the new one in, the next Checkpointer
    promotes the parked .old — the previous intact checkpoint is never
    destroyed before its replacement is in place."""
    d = str(tmp_path)
    ck = Checkpointer(d)
    ck.save(5, _dict_state(5, seed=1), blocking=True)
    orig = dict(np.load(os.path.join(d, "step_00000005", "arrays.npz")))

    real_replace = os.replace

    def crashy_replace(src, dst):
        if src.endswith(".tmp"):       # die before tmp -> final rename
            raise KeyboardInterrupt("killed mid-swap")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", crashy_replace)
    with pytest.raises(KeyboardInterrupt):
        ck.save(5, _dict_state(5, seed=2), blocking=True)
    monkeypatch.undo()
    # final is gone (parked at .old) — recovery promotes it back
    ck2 = Checkpointer(d)
    assert ck2.verify(5)
    got = ck2.restore(_dict_state(0))
    np.testing.assert_array_equal(got["w"], orig["w"])


def test_legacy_checkpoint_without_manifest_restores(tmp_path):
    """Pre-manifest checkpoints (no 'checksums' in meta.json) still
    verify via a load test and restore."""
    ck = Checkpointer(str(tmp_path))
    ck.save(3, _dict_state(3), blocking=True)
    mp = os.path.join(str(tmp_path), "step_00000003", "meta.json")
    with open(mp, "w") as f:
        json.dump({"step": 3}, f)
    assert ck.verify(3)
    got = ck.restore(_dict_state(0))
    assert int(got["step"]) == 3
