"""Checkpointer: roundtrip, atomicity, keep-k, latest discovery."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from conftest import tiny_cfg
from repro.checkpointing.checkpoint import Checkpointer
from repro.training import step as ts


def test_roundtrip(tmp_path):
    cfg = tiny_cfg()
    state = ts.init_state(cfg, jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), keep=2)
    ck.save(1, state, blocking=True)
    restored = ck.restore_state(state)
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(state.masks),
                    jax.tree_util.tree_leaves(restored.masks)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_and_latest(tmp_path):
    cfg = tiny_cfg()
    state = ts.init_state(cfg, jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, state, blocking=True)
    assert ck.steps() == [3, 4]
    assert ck.latest_step() == 4


def test_no_tmp_left_behind(tmp_path):
    cfg = tiny_cfg()
    state = ts.init_state(cfg, jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(7, state, blocking=True)
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


def test_async_save(tmp_path):
    cfg = tiny_cfg()
    state = ts.init_state(cfg, jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path))
    ck.save(5, state, blocking=False)
    ck.wait()
    assert ck.latest_step() == 5
