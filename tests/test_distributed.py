"""Distribution correctness: sharding rules + sharded-vs-single-device
equivalence (the latter in a subprocess so the forced device count never
leaks into other tests)."""
import json
import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P


def test_spec_for_divisibility():
    import jax
    from repro.distributed.sharding import spec_for
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # kv=4 heads on a 1-wide model axis: divisible -> sharded
    assert spec_for((4, 16), ("kv_heads", "head_dim"), mesh) == \
        P("model", None)


def test_spec_for_fallback_replicates():
    import jax
    from repro.distributed.sharding import spec_for
    if len(jax.devices()) != 1:
        pytest.skip("needs single-device run")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 3 not divisible by nothing... size-1 axes always divide
    assert spec_for((3,), ("ff",), mesh) == P("model")


_EQUIV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
import sys
sys.path.insert(0, "tests")
from conftest import tiny_cfg
from repro.distributed import sharding as shd
from repro.distributed.context import DistContext
from repro.models import registry
from repro.optim import adamw
from repro.training import step as ts

cfg = tiny_cfg(num_heads=4, num_kv_heads=2, d_model=64, d_ff=128,
               head_dim=16)
opt = adamw.AdamWConfig(total_steps=20, warmup_steps=1)
batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                 cfg.vocab_size),
    "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                 cfg.vocab_size),
}
state = ts.init_state(cfg, jax.random.PRNGKey(0))

# single-device reference
step1 = jax.jit(ts.make_train_step(cfg, opt))
_, m1 = step1(state, batch)

# 2x4 mesh sharded
mesh = jax.make_mesh((2, 4), ("data", "model"))
dist = DistContext(mesh=mesh)
p_shd = shd.param_sharding_tree(registry.param_specs(cfg), mesh)
rep = NamedSharding(mesh, P())
m_shd = shd.mask_sharding_tree(ts.abstract_state(cfg).masks,
                               registry.axes_tree(cfg),
                               registry.sparse_paths(cfg), mesh)
state_shd = ts.TrainState(step=rep, params=p_shd,
                          opt_state={"m": p_shd, "v": p_shd},
                          masks=m_shd, rng=rep)
batch_shd = {k: shd.batch_sharding(mesh, v.ndim, v.shape[0])
             for k, v in batch.items()}
with mesh:
    step2 = jax.jit(ts.make_train_step(cfg, opt, dist=dist),
                    in_shardings=(state_shd, batch_shd),
                    out_shardings=(state_shd, None))
    _, m2 = step2(state, batch)
print(json.dumps({"loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
                  "gn1": float(m1["grad_norm"]),
                  "gn2": float(m2["grad_norm"])}))
"""


@pytest.mark.slow
def test_sharded_equals_single_device(tmp_path):
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", _EQUIV_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    vals = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(vals["loss1"] - vals["loss2"]) < 1e-3, vals
    assert abs(vals["gn1"] - vals["gn2"]) / max(vals["gn1"], 1) < 2e-2


@pytest.mark.slow
def test_dryrun_cell_on_host_devices():
    """Full dry-run entry on a small forced topology happens in the
    dedicated dryrun sweep; here we assert the module at least lowers a
    decode cell on 512 host devices end-to-end."""
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "internvl2-2b", "--shape", "decode_32k", "--out", ""],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    assert "dry-run OK" in out.stdout
