"""Paged KV cache: shared page pool + block-table attention
(serving/engine.py paged=True, models/attention.py paged_*_attention,
serving/pages.py, kernels/paged_attention.py):

  * greedy decode through the paged path is BITWISE-identical to the
    contiguous engine and the ``serve_loop`` oracle for every slab size
    K ∈ {1, 4, 16}, including ragged admission and mid-slab eviction /
    readmission whose frontiers cross page boundaries;
  * a prompt longer than any contiguous per-lane extent (up to pool
    capacity) is admitted and completes — the ``max_batch × max_len``
    memory cap is gone, total context is bounded by pool pages;
  * admission is gated on FREE PAGES (a group that would overdraw the
    pool waits in FIFO order) and ``Engine.submit`` rejects requests
    that could never fit, with a page-units error;
  * the block-table gather reads strictly fewer pages than a dense
    ``max_len`` read at short live lengths;
  * the Pallas blocked-gather decode kernel (interpret mode) matches
    the XLA gather oracle, standalone and through the engine.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import tiny_cfg
from repro.models import attention as attn
from repro.models import registry
from repro.serving import engine, serve_loop
from repro.serving.pages import PagePool


@pytest.fixture(scope="module")
def model():
    cfg = tiny_cfg()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=(int(p),))
            .astype(np.int32) for p in lens]


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("slab_k", [1, 4, 16])
def test_paged_bitwise_parity_with_oracle_and_contiguous(model, slab_k):
    cfg, params = model
    B, P, NEW = 3, 8, 6
    prompts = _prompts(cfg, [P] * B)
    want, _ = serve_loop.generate(cfg, params,
                                  jnp.asarray(np.stack(prompts)),
                                  max_new_tokens=NEW)
    dense, _ = engine.generate(cfg, params, prompts, max_new_tokens=NEW,
                               prefill_chunk=4, slab_k=slab_k,
                               paged=False)
    paged, _ = engine.generate(cfg, params, prompts, max_new_tokens=NEW,
                               prefill_chunk=4, slab_k=slab_k,
                               paged=True, page_size=4)
    np.testing.assert_array_equal(np.stack(paged), np.asarray(want))
    np.testing.assert_array_equal(np.stack(paged), np.stack(dense))


@pytest.mark.parametrize("slab_k", [1, 4, 16])
def test_paged_ragged_eviction_readmission_across_page_boundary(
        model, slab_k):
    """6 ragged requests over 2 lanes, page_size=4: frontiers cross page
    boundaries mid-slab, lanes are evicted and readmitted onto recycled
    pages — every request must match the per-token contiguous engine."""
    cfg, params = model
    prompts = _prompts(cfg, [6, 3, 5, 7, 4, 6], seed=7)
    budgets = (3, 9, 5, 2, 7, 4)

    def run(paged, k, **kw):
        eng = engine.Engine(cfg, params, max_batch=2, max_len=32,
                            prefill_chunk=4, slab_k=k, paged=paged, **kw)
        uids = [eng.submit(p, n) for p, n in zip(prompts, budgets)]
        return uids, eng.run()

    uids1, base = run(False, 1)
    uidsp, res = run(True, slab_k, page_size=4, n_pages=16)
    assert uids1 == uidsp
    for u in uids1:
        np.testing.assert_array_equal(res[u].tokens, base[u].tokens)
        assert res[u].truncated == base[u].truncated


def test_paged_truncation_parity_with_contiguous(model):
    """Lanes that hit the slot cap mid-slab truncate at exactly the
    contiguous engine's token, even when the cap is page-interior."""
    cfg, params = model
    prompts = _prompts(cfg, [6, 3], seed=5)

    def run(paged):
        eng = engine.Engine(cfg, params, max_batch=2, max_len=10,
                            prefill_chunk=4, slab_k=8, paged=paged,
                            **({"page_size": 4} if paged else {}))
        uids = [eng.submit(p, 16) for p in prompts]
        return uids, eng.run(), eng.stats["truncated"]

    uids, base, tr_d = run(False)
    uidsp, res, tr_p = run(True)
    assert tr_d == tr_p == 2
    for u in uids:
        assert res[u].truncated
        np.testing.assert_array_equal(res[u].tokens, base[u].tokens)


# ----------------------------------------------------- capacity semantics
def test_long_prompt_beyond_contiguous_lane_extent(model):
    """Pool of 64 slots over 2 lanes: a contiguous cache with the same
    memory would cap every lane at 32 slots. The paged engine admits a
    40-token prompt (+8 decode) in ONE lane and completes it exactly —
    total context is bounded by pool pages, not max_batch × max_len."""
    cfg, params = model
    eng = engine.Engine(cfg, params, max_batch=2, max_len=60,
                        prefill_chunk=8, slab_k=4, paged=True,
                        page_size=4, n_pages=16)
    long_p = _prompts(cfg, [40], seed=3)[0]
    uid = eng.submit(long_p, 8)
    res = eng.run()
    assert res[uid].generated.size == 8 and not res[uid].truncated
    want, _ = serve_loop.generate(cfg, params, jnp.asarray(long_p)[None],
                                  max_new_tokens=8, max_len=60)
    np.testing.assert_array_equal(res[uid].tokens, np.asarray(want)[0])


def test_submit_rejects_oversized_request_in_page_units(model):
    cfg, params = model
    eng = engine.Engine(cfg, params, max_batch=2, max_len=60,
                        prefill_chunk=8, slab_k=4, paged=True,
                        page_size=4, n_pages=8)
    with pytest.raises(ValueError, match=r"10 pages .* only 8 pages"):
        eng.submit(np.ones(20, np.int32), 20)
    with pytest.raises(ValueError, match="cannot fit"):
        eng.submit(np.ones(60, np.int32), 4)
    # a feasible request still goes through
    eng.submit(np.ones(8, np.int32), 4)
    assert len(eng.scheduler) == 1


def test_zero_budget_request_rejected(model):
    """max_new_tokens=0 must be rejected at submit: prefill writes the
    full group width, so a zero budget would under-pin pages (cost is
    width + budget - 1 slots) and scatter into pool page 0 — which may
    belong to a LIVE lane (cross-lane KV corruption)."""
    cfg, params = model
    eng = engine.Engine(cfg, params, max_batch=2, max_len=32,
                        prefill_chunk=4, slab_k=4, paged=True,
                        page_size=4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(np.ones(5, np.int32), 0)


def test_admission_gated_on_free_pages(model):
    """3 requests over 3 free lanes but a pool that only fits one at a
    time: admission serialises on pages (strict FIFO), all complete."""
    cfg, params = model
    eng = engine.Engine(cfg, params, max_batch=3, max_len=32,
                        prefill_chunk=4, slab_k=2, paged=True,
                        page_size=4, n_pages=4)  # 16 slots total
    prompts = _prompts(cfg, [8, 8, 8], seed=9)
    uids = [eng.submit(p, 5) for p in prompts]
    eng.step()
    assert eng.stats["admitted"] == 1 and len(eng.scheduler) == 2
    res = eng.run()
    assert sorted(res) == sorted(uids)
    for uid, p in zip(uids, prompts):
        want, _ = serve_loop.generate(cfg, params, jnp.asarray(p)[None],
                                      max_new_tokens=5, max_len=32)
        np.testing.assert_array_equal(res[uid].tokens,
                                      np.asarray(want)[0])


def test_page_reads_scale_with_frontier_not_max_len(model):
    """Short live contexts under a huge max_len: the block-table gather
    must touch strictly fewer pages than a dense max_len read — and the
    paged peak cache bytes must undercut the contiguous slab."""
    cfg, params = model
    prompts = _prompts(cfg, [8, 8], seed=1)
    _, st = engine.generate(cfg, params, prompts, max_new_tokens=8,
                            max_len=256, prefill_chunk=4, slab_k=4,
                            paged=True, page_size=4, n_pages=16)
    assert st["pages_read"] > 0
    assert st["pages_read"] < st["pages_read_dense_equiv"]
    assert st["peak_kv_bytes"] < st["kv_bytes_contiguous_equiv"]


# ----------------------------------------------------------- pool plumbing
def test_page_pool_free_list():
    pool = PagePool(6, 4)
    a = pool.alloc(3)
    assert a == [0, 1, 2] and pool.free_pages == 3 and pool.in_use == 3
    pool.release(a)
    assert pool.free_pages == 6
    b = pool.alloc(2)
    assert b == [0, 1]              # freed pages recycled, low-first
    assert pool.peak_in_use == 3
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(5)
    assert pool.slots_for(9) == 3


def test_paged_write_drops_parked_and_masked_lanes():
    """A parked lane (slot >= max_pages*ps) and a lane_mask'ed lane must
    NOT write — a clamped index would corrupt pool page 0, which may
    belong to another lane."""
    pool = jnp.zeros((3, 4, 1, 2), jnp.float32)
    bt = jnp.asarray([[1, 2], [0, 0]], jnp.int32)
    vals = jnp.ones((2, 1, 2), jnp.float32)
    out = attn.paged_write(pool, bt, jnp.asarray([8, 8]), vals)  # parked
    assert float(jnp.abs(out).sum()) == 0.0
    out = attn.paged_write(pool, bt, jnp.asarray([0, 0]), vals,
                           lane_mask=jnp.asarray([True, False]))
    assert float(jnp.abs(out[1]).sum()) == 1.0 * 2   # lane 0 -> page 1
    assert float(jnp.abs(out[0]).sum()) == 0.0       # lane 1 dropped


def test_block_table_state_roundtrips_through_slab(model):
    cfg, params = model
    eng = engine.Engine(cfg, params, max_batch=2, max_len=16,
                        prefill_chunk=4, slab_k=2, paged=True,
                        page_size=4)
    eng.submit(_prompts(cfg, [5], seed=2)[0], 6)
    eng.step()
    bt = eng.block_tables
    assert bt.shape == (2, 4)
    # lane 0 owns ceil(min(5+6-1, 16)/4) = 3 distinct pool pages
    owned = bt[0][:3]
    assert len(set(owned.tolist())) == 3
    eng.run()
    assert eng.pool.free_pages == eng.pool.n_pages   # all released


# ------------------------------------------------------------ pallas kernel
def test_paged_flash_decode_kernel_matches_xla_gather():
    """The blocked-gather Pallas kernel (interpret mode) against the
    gather + dense-core oracle, with ragged offsets, garbage in
    unallocated pages, and a sliding window crossing page boundaries."""
    from repro.kernels import paged_attention as pk
    cfg = tiny_cfg()
    rng = np.random.default_rng(0)
    b, kvh, g, hd, ps, n_pages, r = 2, 2, 1, 16, 4, 6, 2
    q4 = jnp.asarray(rng.normal(size=(b, kvh, g, hd)), jnp.float32)
    pool_k = jnp.asarray(rng.normal(size=(n_pages, ps, kvh, hd)),
                         jnp.float32)
    pool_v = jnp.asarray(rng.normal(size=(n_pages, ps, kvh, hd)),
                         jnp.float32)
    bt = jnp.asarray([[3, 1], [0, 5]], jnp.int32)
    offsets = jnp.asarray([0, 2], jnp.int32)
    posv = jnp.asarray([6, 7], jnp.int32)
    posb = (posv - offsets)[:, None]
    kpos = attn._cache_positions(r * ps, offsets)
    for window in (0, 3):
        bias = pk.mask_bias(posb, kpos, window)
        got = pk.paged_flash_decode(q4, pool_k, pool_v, bt, bias,
                                    scale=1.0 / np.sqrt(hd),
                                    interpret=True)
        # oracle: gather + masked softmax (attention.py dense core)
        gk = attn.gather_pages(pool_k, bt, r)
        gv = attn.gather_pages(pool_v, bt, r)
        q = q4.reshape(b, 1, kvh * g, hd)
        want = attn._scores_to_out(cfg, q, gk, gv, posb, kpos,
                                   causal=True, window=window)
        np.testing.assert_allclose(
            np.asarray(got).reshape(b, 1, kvh * g, hd),
            np.asarray(want), rtol=1e-5, atol=1e-5)


def test_kernel_tolerates_mixed_read_buckets():
    """Mixed batching admits lanes whose live contexts differ wildly,
    all read under ONE shared ``read_pages`` bucket. A short lane's
    block-table entries past its allocation point at pool page 0 —
    which here BELONGS to the long lane — so the kernel must let the
    bias masking zero those pages out entirely: the short lane's output
    under the wide shared bucket must equal its own narrow-bucket
    (R=1) result, and both lanes must match the XLA gather oracle."""
    from repro.kernels import paged_attention as pk
    cfg = tiny_cfg()
    rng = np.random.default_rng(5)
    kvh, g, hd, ps, n_pages, r = 2, 1, 16, 4, 8, 4
    q4 = jnp.asarray(rng.normal(size=(2, kvh, g, hd)), jnp.float32)
    pool_k = jnp.asarray(rng.normal(size=(n_pages, ps, kvh, hd)),
                         jnp.float32)
    pool_v = jnp.asarray(rng.normal(size=(n_pages, ps, kvh, hd)),
                         jnp.float32)
    # lane 0: ONE live page (page 2); its table rows 1.. default to 0,
    # aliasing the long lane's first page. lane 1: four live pages.
    bt = jnp.asarray([[2, 0, 0, 0], [0, 1, 5, 7]], jnp.int32)
    offsets = jnp.asarray([0, 0], jnp.int32)
    posv = jnp.asarray([2, 14], jnp.int32)        # frontiers 3 vs 15
    posb = posv[:, None]
    kpos = attn._cache_positions(r * ps, offsets)
    bias = pk.mask_bias(posb, kpos, 0)
    got = pk.paged_flash_decode(q4, pool_k, pool_v, bt, bias,
                                scale=1.0 / np.sqrt(hd), interpret=True)
    gk = attn.gather_pages(pool_k, bt, r)
    gv = attn.gather_pages(pool_v, bt, r)
    want = attn._scores_to_out(cfg, q4.reshape(2, 1, kvh * g, hd),
                               gk, gv, posb, kpos, causal=True, window=0)
    np.testing.assert_allclose(np.asarray(got).reshape(2, 1, kvh * g, hd),
                               np.asarray(want), rtol=1e-5, atol=1e-5)
    # short lane alone under its OWN narrow bucket: identical output —
    # the aliased page-0 reads contributed nothing
    bias1 = pk.mask_bias(posb[:1], attn._cache_positions(ps, offsets[:1]),
                         0)
    solo = pk.paged_flash_decode(q4[:1], pool_k, pool_v, bt[:1, :1],
                                 bias1, scale=1.0 / np.sqrt(hd),
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got)[0], np.asarray(solo)[0],
                               rtol=1e-6, atol=1e-6)


def test_pallas_interp_engine_token_parity(model):
    """attn_backend='pallas_interp' through the whole engine: greedy
    tokens match the XLA gather path exactly."""
    cfg, params = model
    prompts = _prompts(cfg, [6, 9, 4], seed=4)
    kw = dict(max_new_tokens=6, prefill_chunk=4, slab_k=4, paged=True,
              page_size=4)
    got_x, _ = engine.generate(cfg, params, prompts,
                               attn_backend="xla", **kw)
    got_p, _ = engine.generate(cfg, params, prompts,
                               attn_backend="pallas_interp", **kw)
    for a, b in zip(got_x, got_p):
        np.testing.assert_array_equal(a, b)
