"""STE masking semantics (DESIGN.md §2): forward masks, backward passes
the DENSE gradient (for the grow step), optimizer sees masked grads."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparse_mlp as sm, topk
from repro.core.prune_grow import BlastSpec


def test_ste_forward_masks_backward_dense(rng):
    w = jax.random.normal(rng, (32, 32))
    mask = jnp.zeros((2, 2), bool).at[0, 0].set(True)
    y = sm.apply_mask_ste(w, mask, 16, 16)
    # forward masked
    assert float(jnp.abs(np.asarray(y)[16:, :]).max()) == 0.0
    # backward dense: d/dw sum(y * c) = c everywhere (not masked)
    c = jax.random.normal(rng, (32, 32))
    g = jax.grad(lambda w: (sm.apply_mask_ste(w, mask, 16, 16) * c).sum()
                 )(w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(c), atol=1e-6)


def test_mask_grads_zeroes_pruned():
    spec = BlastSpec(b_in=16, b_out=16)
    grads = {"layers": {"mlp": {"w_gate": jnp.ones((32, 32))}}}
    masks = {"layers/mlp/w_gate":
             jnp.zeros((2, 2), bool).at[0, 0].set(True)}
    out = sm.mask_grads(masks, grads, spec)
    g = np.asarray(out["layers"]["mlp"]["w_gate"])
    assert g[:16, :16].min() == 1.0 and g[16:, :].max() == 0.0


def test_glu_mlp_mask_equivalence(rng):
    """glu_mlp with masks == glu_mlp on pre-masked weights."""
    spec = BlastSpec(b_in=8, b_out=8, s_max=0.5)
    d, f = 16, 32
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (4, d))
    wg = jax.random.normal(ks[1], (d, f))
    wu = jax.random.normal(ks[2], (d, f))
    wd = jax.random.normal(ks[3], (f, d))
    masks = {
        "w_gate": jnp.asarray([[True, False, True, False],
                               [False, True, False, True]]),
        "w_up": jnp.ones((2, 4), bool),
        "w_down": jnp.asarray([[True, False], [False, True],
                               [True, True], [False, False]]),
    }
    y1 = sm.glu_mlp(x, wg, wu, wd, masks=masks, spec=spec)
    wg_m = topk.apply_block_mask(wg, masks["w_gate"], 8, 8)
    wd_m = topk.apply_block_mask(wd, masks["w_down"], 8, 8)
    y2 = sm.glu_mlp(x, wg_m, wu, wd_m)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_block_dims_orientation():
    spec = BlastSpec(b_in=128, b_out=16)
    assert sm.block_dims_for(spec, "layers/mlp/w_gate") == (128, 16)
    assert sm.block_dims_for(spec, "layers/mlp/w_down") == (16, 128)
    assert sm.block_dims_for(spec, "encoder/mlp/w_out") == (16, 128)


def test_tree_sparsity():
    masks = {"a": jnp.zeros((4, 4), bool),
             "b": jnp.ones((4, 4), bool)}
    assert float(sm.tree_sparsity(masks)) == 0.5
