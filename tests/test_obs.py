"""Unified observability layer (obs/): typed metrics registry with the
anti-drift reset guarantee, zero-overhead request-span tracing (no span
objects allocated when disabled, no bit changed when enabled — serving
AND training), Prometheus exposition round-trip, Chrome/Perfetto
export, and the crash flight recorder's postmortem contents."""
import json

import jax
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.data.pipeline import SyntheticLM
from repro.models import registry
from repro.obs import export as obs_export
from repro.obs import trace as trace_mod
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               parse_prometheus_text)
from repro.obs.trace import NULL_TRACER, Tracer
from repro.optim import adamw
from repro.serving.engine import Engine
from repro.serving.faults import FaultPlan, LaneFaultError
from repro.training import train_loop
from repro.training.faults import TrainFaultPlan


@pytest.fixture(scope="module")
def model():
    cfg = tiny_cfg()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


def _drain(eng):
    out = {}
    steps = 0
    while (len(eng.scheduler) or eng.active_lanes or eng._preempted
           or eng._pending_results):
        for r in eng.step():
            out[r.uid] = r
        steps += 1
        assert steps < 500
    eng.finalize_stats()
    return out


# ------------------------------------------------------ metrics registry
def test_registry_kinds_and_reset():
    reg = MetricsRegistry()
    c = reg.counter("reqs", "requests")
    g = reg.gauge("depth")
    h = reg.histogram("lat_s")
    c.inc()
    c.inc(3)
    g.set(7.5)
    h.observe(0.1)
    h.observe(0.3)
    assert isinstance(c, Counter) and isinstance(g, Gauge)
    assert isinstance(h, Histogram)
    assert reg.counter("reqs") is c          # get-or-create
    snap = reg.snapshot()
    assert snap["reqs"] == 4 and snap["depth"] == 7.5
    assert snap["lat_s"]["count"] == 2
    assert snap["lat_s"]["sum"] == pytest.approx(0.4)
    reg.reset()
    snap = reg.snapshot()
    assert snap["reqs"] == 0 and snap["depth"] == 0
    assert snap["lat_s"]["count"] == 0


def test_histogram_reset_keeps_list_identity():
    """The engine exposes ``Histogram.samples`` directly (``_ttft``);
    reset must clear IN PLACE so held references stay live."""
    h = Histogram("x")
    ref = h.samples
    h.observe(1.0)
    h.reset()
    h.observe(2.0)
    assert ref == [2.0] and h.samples is ref


def test_histogram_percentile_matches_numpy():
    h = Histogram("x")
    vals = [0.5, 0.1, 0.9, 0.3, 0.7, 0.2]
    for v in vals:
        h.observe(v)
    for q in (50, 95, 99):
        assert h.percentile(q) == pytest.approx(
            float(np.percentile(vals, q)))


def test_stats_view_is_a_dict_facade():
    reg = MetricsRegistry()
    reg.counter("a")
    view = reg.view()
    view["a"] += 2                        # counter through the view
    view["b"] = 5                         # auto-registers a Counter
    view["r"] = 1.5                       # float auto-registers a Gauge
    reg.histogram("h").observe(1.0)
    assert view["a"] == 2 and view["b"] == 5
    assert reg["b"].kind == "counter" and reg["r"].kind == "gauge"
    assert "h" not in view                # histograms not in the facade
    with pytest.raises(KeyError):
        view["h"]
    d = dict(view)
    assert d == {"a": 2, "b": 5, "r": 1.5}
    view.update({"a": 9})
    assert view["a"] == 9
    reg.reset()                           # auto-registered keys too
    assert dict(view) == {"a": 0, "b": 0, "r": 0}


def test_engine_reset_stats_round_trips_every_metric(model):
    """THE anti-drift regression (the bug class that bit PR 6 and
    PR 7): mutate EVERY registered scalar and histogram, reset, and
    require every one of them back at its zero — including stats
    auto-registered at finalize time. No hand-kept key list exists to
    go stale."""
    cfg, params = model
    eng = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                 page_size=4)
    for p in _prompts(cfg, (6, 5)):
        eng.submit(p, 6)
    _drain(eng)                           # populates + finalizes
    for name in eng.metrics.names():
        m = eng.metrics[name]
        if isinstance(m, Histogram):
            m.observe(1.0)
        else:
            m.set(m.get() + 1)            # force every scalar nonzero
    assert any(v for v in dict(eng.stats).values())
    eng.reset_stats()
    for name in eng.metrics.names():
        m = eng.metrics[name]
        if isinstance(m, Histogram):
            assert m.samples == [], name
        else:
            assert m.get() == 0, name


# ------------------------------------------------------------ exposition
def test_prometheus_text_round_trip():
    reg = MetricsRegistry(namespace="blast")
    reg.counter("decode_tokens", "tokens emitted").inc(41)
    reg.gauge("queue_depth_peak").set(3)
    h = reg.histogram("ttft_s", "time to first token")
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    text = reg.prometheus_text()
    assert "# TYPE blast_decode_tokens counter" in text
    assert "# HELP blast_decode_tokens tokens emitted" in text
    assert "# TYPE blast_ttft_s summary" in text
    parsed = parse_prometheus_text(text)
    assert parsed["blast_decode_tokens"] == 41
    assert parsed["blast_queue_depth_peak"] == 3
    assert parsed["blast_ttft_s_count"] == 3
    assert parsed["blast_ttft_s_sum"] == pytest.approx(0.6)
    assert parsed["blast_ttft_s"]['quantile="0.5"'] == pytest.approx(0.2)


def test_prometheus_parser_rejects_malformed():
    with pytest.raises(ValueError):
        parse_prometheus_text("not a sample line at all\n")
    with pytest.raises(ValueError):
        parse_prometheus_text("# BOGUS comment kind\n")


# --------------------------------------------------------------- tracing
def test_tracer_records_and_spans_for():
    clock = iter(float(i) for i in range(100))
    tr = Tracer(capacity=8, clock=lambda: next(clock))
    tr.span_at("decode.slab", 1.0, 2.0, k=4, uids=[1, 2])
    tr.event("request.finish", uid=1, tokens=5)
    with tr.span("ckpt.save", step=3):
        pass
    assert [s.name for s in tr.records] == [
        "decode.slab", "request.finish", "ckpt.save"]
    assert tr.records[0].dur == 1.0
    mine = tr.spans_for(1)
    assert [s["name"] for s in mine] == ["decode.slab",
                                         "request.finish"]
    assert tr.spans_for(99) == []
    # bounded ring: old spans fall off, never an unbounded list
    for i in range(20):
        tr.event("e", t=float(i))
    assert len(tr.records) == 8


def test_span_ctx_records_error_name():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("ckpt.restore", step=1):
            raise RuntimeError("boom")
    assert tr.records[-1].attrs["error"] == "RuntimeError"


def test_postmortem_payload_and_file(tmp_path):
    tr = Tracer(postmortem_dir=str(tmp_path))
    tr.event("request.queued", t=0.0, uid=7)
    pm = tr.postmortem("watchdog_crash", error="EngineCrashError")
    assert pm["reason"] == "watchdog_crash"
    assert pm["meta"]["error"] == "EngineCrashError"
    assert [s["name"] for s in pm["spans"]] == ["request.queued"]
    assert tr.postmortems == [pm]
    files = sorted(p.name for p in tmp_path.iterdir())
    assert files == ["postmortem_0000_watchdog_crash.json"]
    with open(tmp_path / files[0]) as f:
        assert json.load(f)["reason"] == "watchdog_crash"


def test_chrome_trace_export():
    tr = Tracer()
    tr.span_at("decode.slab", 1.0, 2.0, k=4, uids=[0, 1])
    tr.event("request.finish", t=2.5, uid=1, tokens=5)
    doc = tr.chrome_trace()
    ev = doc["traceEvents"]
    assert len(ev) == 2
    slab, fin = ev
    assert slab["ph"] == "X" and slab["dur"] == pytest.approx(1e6)
    assert slab["ts"] == pytest.approx(1e6)
    assert fin["ph"] == "i" and fin["s"] == "t"
    assert fin["tid"] == 2                # uid 1 -> row 2 (0 = engine)
    json.dumps(doc)                       # valid JSON all the way down
    # the exporter also takes already-serialized dicts (postmortems)
    again = obs_export.to_chrome_trace([s.to_dict()
                                        for s in tr.records])
    assert again["traceEvents"] == ev


# -------------------------------------------- zero-overhead: allocation
def test_disabled_tracing_allocates_no_spans(model, monkeypatch):
    """With no tracer installed the hot path must never construct a
    Span (or call any recording method): count every Span.__init__
    while a full workload runs against NULL_TRACER."""
    calls = []
    orig = trace_mod.Span

    class CountingSpan(orig):
        def __init__(self, *a, **kw):
            calls.append(a)
            super().__init__(*a, **kw)

    monkeypatch.setattr(trace_mod, "Span", CountingSpan)
    cfg, params = model
    eng = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                 page_size=4)
    assert eng.tracer is NULL_TRACER
    for p in _prompts(cfg, (6, 5, 7)):
        eng.submit(p, 8)
    _drain(eng)
    assert calls == []


# ----------------------------------------------- bitwise parity oracles
def test_serving_parity_tracing_on_vs_off(model):
    """THE serving oracle: the same workload with tracing enabled emits
    bitwise-identical tokens (spans attach at existing host syncs only;
    no device-graph change), and the trace actually covers the whole
    request lifecycle."""
    cfg, params = model
    prompts = _prompts(cfg, (7, 5, 9), seed=4)

    def run(tracer):
        eng = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                     page_size=4, tracer=tracer)
        uids = [eng.submit(p, 12) for p in prompts]
        return uids, _drain(eng)

    uids0, base = run(None)
    tr = Tracer()
    uids1, got = run(tr)
    for u0, u1 in zip(uids0, uids1):
        assert got[u1].tokens.tolist() == base[u0].tokens.tolist()
    names = {s.name for s in tr.records}
    assert {"request.queued", "request.admitted", "prefill.chunks",
            "decode.slab", "request.finish"} <= names
    # every request has a queued -> admitted -> finish timeline
    for u in uids1:
        mine = [s["name"] for s in tr.spans_for(u)]
        assert mine[0] == "request.queued"
        assert "request.admitted" in mine
        assert mine[-1] == "request.finish"


def test_training_parity_tracing_on_vs_off():
    """THE training oracle: identical TrainState leaves with tracing on
    vs off, and the tracer carries train.step spans plus the routed
    structured events."""
    cfg = tiny_cfg()

    def run(tracer):
        src = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=16,
                          seed=3)
        opt = adamw.AdamWConfig(peak_lr=2e-2, warmup_steps=5,
                                total_steps=60, weight_decay=0.0)
        loop = train_loop.TrainLoopConfig(total_steps=8, log_every=4)
        return train_loop.train(cfg, opt, src, loop,
                                log_fn=lambda m: None, tracer=tracer)

    state_a, hist_a = run(None)
    tr = Tracer()
    state_b, hist_b = run(tr)
    leaves = lambda st: jax.tree_util.tree_leaves(  # noqa: E731
        {"step": st.step, "params": st.params,
         "opt_state": st.opt_state, "masks": st.masks, "rng": st.rng})
    for a, b in zip(leaves(state_a), leaves(state_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    steps = [s for s in tr.records if s.name == "train.step"]
    assert len(steps) == 8
    assert [s.attrs["step"] for s in steps] == list(range(8))
    assert all(s.dur > 0 for s in steps)


def test_training_events_route_through_tracer():
    """Satellite: straggler/anomaly/rewind history events and the span
    stream share ONE schema — every structured history event appears as
    a train.* span with the same fields."""
    cfg = tiny_cfg()
    src = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=16,
                      seed=3)
    opt = adamw.AdamWConfig(peak_lr=2e-2, warmup_steps=5,
                            total_steps=60, weight_decay=0.0)
    loop = train_loop.TrainLoopConfig(total_steps=10, log_every=5)
    tr = Tracer()
    reg = MetricsRegistry(namespace="blast_train")
    _, hist = train_loop.train(
        cfg, opt, src, loop, log_fn=lambda m: None, tracer=tr,
        metrics=reg, faults=TrainFaultPlan().nan_grads(4))
    events = [h for h in hist if "event" in h]
    # every history event has a matching train.* span, same fields
    by_name = {}
    for s in tr.records:
        by_name.setdefault(s.name, []).append(s)
    for h in events:
        spans = by_name.get("train." + h["event"])
        assert spans, f"no span for history event {h['event']!r}"
        assert any(all(s.attrs.get(k) == v for k, v in h.items()
                       if k != "event") for s in spans)
    # the guard's own anomaly event fired for the injected NaN step
    anom = by_name.get("train.anomaly")
    assert anom and anom[0].attrs["verdict"] == "skip"
    assert anom[0].attrs["step"] == 4
    # injected registry scraped the loop's counters
    assert reg.counter("skipped_steps").get() == 1
    assert parse_prometheus_text(reg.prometheus_text())[
        "blast_train_skipped_steps"] == 1


# ------------------------------------------------------- flight recorder
def test_flight_recorder_captures_poisoned_lane(model):
    """A quarantined request's full timeline — queued, admitted, and
    the quarantine itself — is retrievable from the ring by uid."""
    cfg, params = model
    prompts = _prompts(cfg, (7, 5, 9), seed=4)
    tr = Tracer()
    eng = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                 page_size=4, tracer=tr,
                 faults=FaultPlan(seed=0).poison_logits(2, 0))
    uids = [eng.submit(p, 12) for p in prompts]
    got = _drain(eng)
    victim = uids[0]
    assert isinstance(got[victim].error, LaneFaultError)
    mine = [s["name"] for s in tr.spans_for(victim)]
    assert mine[0] == "request.queued"
    assert "request.admitted" in mine
    assert mine[-1] == "request.quarantined"
    q = tr.spans_for(victim)[-1]["attrs"]
    assert q["error"] == "LaneFaultError" and q["lane"] == 0
    # survivors finished normally in the same ring
    for u in uids[1:]:
        assert [s["name"] for s in tr.spans_for(u)][-1] \
            == "request.finish"
