"""Per-architecture smoke tests (task spec f): reduced config, one
forward + one train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import registry
from repro.optim import adamw
from repro.training import step as ts


def _batch(cfg, rng, B=2, S=32):
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            rng, (B, S, cfg.d_model)) * 0.02
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            rng, (B, cfg.num_patches, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = registry.init_params(cfg, rng)
    masks = registry.init_masks(cfg, params)
    batch = _batch(cfg, rng)
    kw = {k: v for k, v in batch.items()
          if k in ("frames", "patch_embeds")}
    logits, aux = registry.forward(cfg, params, batch["tokens"],
                                   masks=masks, **kw)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    opt = adamw.AdamWConfig(total_steps=10, warmup_steps=0)
    step_fn = ts.make_train_step(cfg, opt)
    state = ts.init_state(cfg, rng)
    state2, metrics = jax.jit(step_fn)(state, _batch(cfg, rng))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2.step) == 1
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda a, b: a or b,
        jax.tree_util.tree_map(
            lambda a, b: bool(jnp.any(a != b)),
            state.params, state2.params))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = registry.init_params(cfg, rng)
    B, MAX = 2, 16
    kw = dict(enc_len=MAX) if cfg.family == "audio" else {}
    cache = registry.init_cache(cfg, B, MAX, **kw)
    tok = jax.random.randint(rng, (B, 1), 0, cfg.vocab_size)
    logits, cache2 = registry.decode_step(cfg, params, cache, tok,
                                          jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)
