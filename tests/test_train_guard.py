"""Anomaly-guarded training: the NaN-skip parity oracle (a poisoned
step under skip policy is bitwise-identical to a run that never applies
that step's update), host-side spike detection, schedule-aware
thresholds, automatic rewind-and-replay, divergence, and structured
straggler telemetry."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.training import step as ts, train_loop
from repro.training.faults import TrainFaultPlan, TrainingDivergedError
from repro.training.guard import AnomalyGuard, GuardConfig


def _run(cfg, steps, faults=None, state=None, guard=None, **loop_kw):
    src = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=16,
                      seed=3)
    opt = adamw.AdamWConfig(peak_lr=2e-2, warmup_steps=5,
                            total_steps=60, weight_decay=0.0)
    kw = dict(total_steps=steps, log_every=5, **loop_kw)
    if guard is not None:
        kw["guard"] = guard
    loop = train_loop.TrainLoopConfig(**kw)
    return train_loop.train(cfg, opt, src, loop, faults=faults,
                            state=state, log_fn=lambda m: None)


def _state_leaves(state):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(
        {"step": state.step, "params": state.params,
         "opt_state": state.opt_state, "masks": state.masks,
         "rng": state.rng})]


def _metrics_entries(hist):
    return [h for h in hist if "event" not in h]


def test_nan_skip_parity_oracle():
    """The headline device-tier oracle: a run with NaN gradients
    injected at step k (anomaly guard skips the update) is
    bitwise-identical — every leaf of the final TrainState — to a run
    where step k's update is simply never applied."""
    cfg = tiny_cfg()
    state_a, hist_a = _run(cfg, 18, faults=TrainFaultPlan().nan_grads(9))
    state_b, _ = _run(cfg, 18, faults=TrainFaultPlan().force_skip(9))
    for a, b in zip(_state_leaves(state_a), _state_leaves(state_b)):
        np.testing.assert_array_equal(a, b)
    m = _metrics_entries(hist_a)[-1]
    assert m["skipped_steps"] == 1
    assert m["anomaly_steps"] == 1
    # sanity: the skip is not a no-op of the whole run — a clean run
    # (step 9 applied) ends in a different state
    state_c, _ = _run(cfg, 18)
    assert any(not np.array_equal(a, c) for a, c in
               zip(_state_leaves(state_a), _state_leaves(state_c)))


def test_inf_grads_skipped_too():
    cfg = tiny_cfg()
    state_a, _ = _run(cfg, 18,
                      faults=TrainFaultPlan().nan_grads(9, kind="inf"))
    state_b, _ = _run(cfg, 18, faults=TrainFaultPlan().force_skip(9))
    for a, b in zip(_state_leaves(state_a), _state_leaves(state_b)):
        np.testing.assert_array_equal(a, b)


def test_loss_spike_detected_host_side():
    """A loss spike with healthy gradients: the device check stays
    green (no skip), the host EMA/z-score detector counts a spike."""
    cfg = tiny_cfg()
    _, hist = _run(cfg, 18,
                   faults=TrainFaultPlan().loss_spike(14, 1e3))
    m = _metrics_entries(hist)[-1]
    assert m["spike_steps"] == 1
    assert m["skipped_steps"] == 0
    assert m["anomaly_steps"] == 1


def test_guard_threshold_widens_after_refresh():
    """Schedule-aware tolerance: the same loss deviation that trips the
    detector in steady state is tolerated right after a prune-grow
    refresh (the sparsifier just zeroed whole blocks)."""
    cfg = GuardConfig(z_threshold=10.0, warmup_steps=5,
                      refresh_window=3, refresh_relax=100.0)

    def warm(g):
        for s in range(8):
            assert g.observe(s, 1.0, False) == "ok"

    g_in = AnomalyGuard(cfg, step_size=10)
    warm(g_in)
    # step 11: 1 step after the refresh at 10 -> widened threshold
    assert g_in.observe(11, 5.0, False) == "ok"

    g_out = AnomalyGuard(cfg, step_size=10)
    warm(g_out)
    # step 15: outside the window -> same deviation is a spike
    assert g_out.observe(15, 5.0, False) == "spike"


def test_rewind_after_consecutive_anomalies(tmp_path):
    """K consecutive NaN steps trigger an automatic rewind to the
    newest intact checkpoint; the replay (which crosses the prune-grow
    refresh at step 10) ends bitwise-identical to a clean run."""
    cfg = tiny_cfg()
    plan = TrainFaultPlan().nan_grads(11).nan_grads(12).nan_grads(13)
    state_a, hist = _run(cfg, 20, faults=plan,
                         ckpt_dir=str(tmp_path / "ck"), ckpt_every=5)
    rewinds = [h for h in hist if h.get("event") == "rewind"]
    assert len(rewinds) == 1
    assert rewinds[0]["step"] == 13 and rewinds[0]["to_step"] == 10
    m = _metrics_entries(hist)[-1]
    assert m["rewinds"] == 1
    assert m["steps_replayed"] == 3
    state_c, _ = _run(cfg, 20)
    for a, c in zip(_state_leaves(state_a), _state_leaves(state_c)):
        np.testing.assert_array_equal(a, c)


def test_diverged_raises_when_rewind_cannot_help(tmp_path):
    """Deterministic anomalies (grad-norm limit impossibly tight) with
    checkpointing enabled: no intact checkpoint to rewind to at the
    first trip -> structured TrainingDivergedError, not a silent
    garbage run."""
    cfg = tiny_cfg()
    with pytest.raises(TrainingDivergedError):
        _run(cfg, 20, ckpt_dir=str(tmp_path / "ck"), ckpt_every=5,
             guard=GuardConfig(grad_norm_limit=1e-12,
                               max_consecutive=3, max_rewinds=1))


def test_guard_skips_every_step_without_ckpt():
    """Device-tier skip semantics are a true identity: with every step
    anomalous (and no checkpointing, so rewind is unavailable), the
    final params equal the initial params bitwise."""
    cfg = tiny_cfg(blast=dataclasses.replace(tiny_cfg().blast,
                                             enabled=False))
    state0 = ts.init_state(cfg, jax.random.PRNGKey(0))
    p0 = [np.asarray(x) for x in
          jax.tree_util.tree_leaves(jax.tree_util.tree_map(
              jnp.copy, state0.params))]
    state, hist = _run(cfg, 12, state=state0,
                       guard=GuardConfig(grad_norm_limit=1e-12))
    for a, b in zip(p0, [np.asarray(x) for x in
                         jax.tree_util.tree_leaves(state.params)]):
        np.testing.assert_array_equal(a, b)
    m = _metrics_entries(hist)[-1]
    assert m["skipped_steps"] == 12
    assert any(h.get("event") == "rewind_unavailable" for h in hist)


def test_straggler_emits_structured_event():
    cfg = tiny_cfg()
    _, hist = _run(cfg, 14, faults=TrainFaultPlan().slow_step(8, 0.5),
                   straggler_factor=2.0)
    ev = [h for h in hist if h.get("event") == "straggler"]
    assert ev and ev[0]["step"] == 8
    assert ev[0]["sec_per_step"] > 2.0 * ev[0]["median_s"]
    assert _metrics_entries(hist)[-1]["straggler_steps"] >= 1


def test_guard_disabled_compiles_out():
    """guard=None removes the device check entirely (metrics still
    carry a constant-zero anomaly flag)."""
    cfg = tiny_cfg()
    _, hist = _run(cfg, 8, guard=None)
    assert all(m["anomaly"] == 0 for m in _metrics_entries(hist))
