"""Preemption with host KV offload: offload -> evict -> restore must be
bitwise greedy-identical to an uninterrupted run with ZERO re-prefilled
tokens (the whole point — KV round-trips through host RAM instead of
being recomputed), prefix-cache-shared pages are pinned through the
preemption (never offloaded while another reader holds them), and
``preempt=True`` auto-preempts lower-priority lanes for a page-blocked
urgent head."""
import jax
import numpy as np
import pytest

from conftest import tiny_cfg

from repro.models import registry
from repro.serving.engine import Engine
from repro.serving.offload import HostKVStore
from repro.serving.pages import PagePool
from repro.serving.scheduler import BATCH, INTERACTIVE, SLAScheduler


@pytest.fixture(scope="module")
def model():
    cfg = tiny_cfg()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


def _drain(eng, preempt_uid=None):
    """Drive the engine to completion; when ``preempt_uid`` is set,
    force-preempt that request's lane the first time it is seen live
    (mid-decode). Returns ({uid: tokens}, stats)."""
    out, done = {}, preempt_uid is None
    while len(eng.scheduler) or eng.active_lanes or eng._preempted:
        for r in eng.step():
            out[r.uid] = r.generated.tolist()
        if not done:
            live = [i for i in eng.active_lanes
                    if eng._mirror["live"][i]
                    and i not in eng._prefilling
                    and eng.lanes[i].req.uid == preempt_uid]
            if live:
                eng.preempt(live[0])
                done = True
    eng.finalize_stats()
    return out, eng.stats


# ------------------------------------------------------------ parity
def test_forced_preempt_restore_bitwise_parity(model):
    """Acceptance criterion: forced mid-run offload/restore of a lane
    is bitwise-identical to the uninterrupted run, with >=1 preemption
    and zero re-prefilled tokens (prefill_tokens EQUAL across runs),
    and every offloaded page restored."""
    cfg, params = model
    prompts = _prompts(cfg, (7, 5, 9))

    def make():
        eng = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                     page_size=4)
        uids = [eng.submit(p, 12) for p in prompts]
        return eng, uids

    eng0, uids0 = make()
    base, st0 = _drain(eng0)

    eng1, uids1 = make()
    got, st1 = _drain(eng1, preempt_uid=uids1[0])
    assert [got[u] for u in uids1] == [base[u] for u in uids0]
    assert st1["preemptions"] >= 1 and st1["restores"] >= 1
    assert st1["prefill_tokens"] == st0["prefill_tokens"]   # no re-prefill
    assert st1["restored_pages"] == st1["offloaded_pages"] > 0
    assert st1["offload_bytes_peak"] > 0
    # pool fully drained afterwards: no leaked references
    assert eng1.pool.free_pages == eng1.pool.n_pages
    assert len(eng1._offload) == 0


def test_preempt_with_prefix_shared_pages_pins_not_offloads(model):
    """A preempted lane whose block table holds radix-tree-shared pages
    keeps them PINNED on-device (refcount held, never offloaded) and
    only round-trips its exclusive pages; greedy tokens stay
    bitwise-identical."""
    cfg, params = model
    rng = np.random.default_rng(1)
    shared = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)
    prompts = [np.concatenate(
        [shared, rng.integers(1, cfg.vocab_size, k).astype(np.int32)])
        for k in (3, 5)]

    def run(preempt_second):
        eng = Engine(cfg, params, max_batch=1, max_len=48, slab_k=4,
                     page_size=4, prefix_cache=True)
        uids = [eng.submit(p, 10) for p in prompts]
        out, st = _drain(eng, preempt_uid=uids[1] if preempt_second
                         else None)
        return [out[u] for u in uids], st

    base, _ = run(False)
    got, st = run(True)
    assert got == base
    assert st["preemptions"] >= 1
    # the second prompt's matched prefix pages stayed on-device
    assert st["preempt_pinned_pages"] >= 1
    assert st["restored_pages"] == st["offloaded_pages"]


def test_auto_preempt_under_page_pressure(model):
    """``preempt=True``: a page-blocked interactive head preempts the
    batch lane hogging the pool (offload, not evict-and-re-prefill),
    and both requests finish with the same tokens as the run that just
    waited — same total prefill tokens, >=1 preemption."""
    cfg, params = model
    rng = np.random.default_rng(2)
    p_batch = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)
    p_inter = rng.integers(1, cfg.vocab_size, 6).astype(np.int32)

    def run(preempt):
        eng = Engine(cfg, params, max_batch=2, max_len=32, slab_k=2,
                     page_size=4, n_pages=8, preempt=preempt,
                     scheduler=SLAScheduler(2, 32, aging_s=None))
        # batch request pins 7 of 8 pages for its whole extent
        ub = eng.submit(p_batch, 20, priority=BATCH)
        out, stepped, ui = {}, 0, None
        while len(eng.scheduler) or eng.active_lanes or eng._preempted:
            for r in eng.step():
                out[r.uid] = r.generated.tolist()
            stepped += 1
            if stepped == 2:   # arrives mid-decode, needs 3 pages
                ui = eng.submit(p_inter, 4, priority=INTERACTIVE)
            assert stepped < 500
        eng.finalize_stats()
        return out[ub], out[ui], eng.stats

    b_tok, i_tok, st = run(True)
    b0, i0, st0 = run(False)
    assert (b_tok, i_tok) == (b0, i0)
    assert st["preemptions"] >= 1 and st["restores"] >= 1
    assert st0["preemptions"] == 0
    assert st["prefill_tokens"] == st0["prefill_tokens"]


def test_engine_keeps_injected_scheduler(model):
    """Regression: ``scheduler or FIFOScheduler(...)`` dropped every
    injected scheduler — an EMPTY scheduler is falsy (``__len__ == 0``
    at construction, always), so the engine silently ran plain FIFO and
    SLA ordering never reached admission."""
    cfg, params = model
    sched = SLAScheduler(2, 32, aging_s=None)
    eng = Engine(cfg, params, max_batch=2, max_len=32, page_size=4,
                 scheduler=sched)
    assert eng.scheduler is sched
    # and the injected scheduler really orders admission: a later
    # interactive jumps a queued batch request
    rng = np.random.default_rng(3)
    eng.submit(rng.integers(1, cfg.vocab_size, 4).astype(np.int32), 2,
               priority=BATCH)
    eng.submit(rng.integers(1, cfg.vocab_size, 4).astype(np.int32), 2,
               priority=INTERACTIVE)
    assert eng.scheduler.head().priority == INTERACTIVE


def test_preempt_requires_paged_and_live(model):
    cfg, params = model
    with pytest.raises(ValueError, match="preempt=True requires"):
        Engine(cfg, params, max_batch=1, max_len=32, paged=False,
               preempt=True)
    eng = Engine(cfg, params, max_batch=1, max_len=32, page_size=4)
    with pytest.raises(AssertionError):
        eng.preempt(0)                     # no lane there


# ------------------------------------------------- feasibility satellite
def test_submit_feasibility_unified_at_submit(model):
    """Slot- and page-infeasibility BOTH reject synchronously at
    submit — through ``Engine.submit`` and through the scheduler the
    engine installed its hook on — with the same messages, and the
    boundary-feasible request passes."""
    cfg, params = model
    # pool of 4 pages x 4 slots = 16 slots; max_len 64 so the slot gate
    # is NOT what stops a 20-slot extent — the page gate must
    eng = Engine(cfg, params, max_batch=1, max_len=64, page_size=4,
                 n_pages=4)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(np.ones(64, np.int32), 4)           # slot boundary
    with pytest.raises(ValueError, match="oversized request"):
        eng.submit(np.ones(10, np.int32), 8)           # 17 slots > pool
    # the SAME rejections through the scheduler directly (uid plumbing
    # bypassed) — one gate, one message
    from repro.serving.scheduler import Request
    with pytest.raises(ValueError, match="max_len"):
        eng.scheduler.submit(Request(99, np.ones(64, np.int32), 4))
    with pytest.raises(ValueError, match="oversized request"):
        eng.scheduler.submit(Request(99, np.ones(10, np.int32), 8))
    assert len(eng.scheduler) == 0                     # nothing queued
    eng.submit(np.ones(9, np.int32), 8)                # exactly 16 slots
    assert len(eng.scheduler) == 1


# ------------------------------------------------------ offload store
def test_offload_store_bookkeeping():
    store = HostKVStore()
    k = np.zeros((2, 3, 4, 2, 8), np.float32)
    v = np.ones_like(k)
    store.save(7, [0, 2, 3], k, v)
    assert 7 in store and len(store) == 1
    assert store.nbytes == k.nbytes + v.nbytes
    assert store.bytes_peak == store.nbytes
    with pytest.raises(AssertionError):
        store.save(7, [0], k[:, :1], v[:, :1])   # double offload
    rec = store.pop(7)
    assert rec.logical == [0, 2, 3]
    assert rec.nbytes == k.nbytes + v.nbytes
    assert store.pop(7) is None and store.nbytes == 0
    assert store.bytes_peak > 0
    store.reset_peaks()
    assert store.bytes_peak == 0


def test_pool_exclusive_classification():
    pool = PagePool(4, 4)
    a, b = pool.alloc(2)
    assert pool.exclusive(a) and pool.exclusive(b)
    pool.retain([a])                  # second reader
    assert not pool.exclusive(a)
    pool.cache_add([b])               # prefix cache holds it
    assert not pool.exclusive(b)
    pool.release([a])
    assert pool.exclusive(a)
