"""Loop-weighted HLO cost model validation (the roofline backbone —
EXPERIMENTS.md §Roofline methodology)."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import hlo_cost


def _analyze(fn, *args):
    return hlo_cost.analyze_text(
        jax.jit(fn).lower(*args).compile().as_text())


def test_plain_matmul_flops_exact():
    r = _analyze(lambda a, b: a @ b, jnp.ones((64, 32)),
                 jnp.ones((32, 16)))
    assert r["flops"] == 2 * 64 * 32 * 16


def test_scan_flops_weighted_by_trip_count():
    x = jnp.ones((128, 128))
    r = _analyze(lambda x: jax.lax.scan(
        lambda c, _: (c @ c, None), x, None, length=10)[0], x)
    assert r["flops"] == pytest.approx(10 * 2 * 128 ** 3, rel=1e-3)


def test_xla_cost_analysis_undercounts_scans():
    """The reason this module exists: XLA counts loop bodies once."""
    x = jnp.ones((128, 128))
    f = jax.jit(lambda x: jax.lax.scan(
        lambda c, _: (c @ c, None), x, None, length=10)[0])
    xla = f.lower(x).compile().cost_analysis()
    if isinstance(xla, list):      # older jax returned one dict per device
        xla = xla[0]
    assert xla["flops"] < 2.1 * 2 * 128 ** 3   # ~1 body, not 10


def test_nested_scan_weights_multiply():
    x = jnp.ones((32, 32))

    def inner(c):
        return jax.lax.scan(lambda c, _: (c @ c, None), c, None,
                            length=4)[0]

    def outer(x):
        return jax.lax.scan(lambda c, _: (inner(c), None), x, None,
                            length=3)[0]

    r = _analyze(outer, x)
    assert r["flops"] == pytest.approx(12 * 2 * 32 ** 3, rel=1e-3)


def test_scan_memory_not_charged_full_stack():
    """Per-trip dynamic-slice must charge the slice, not the stack."""
    ws = jnp.ones((100, 64, 64))   # 100 x 16 KiB stacked weights
    x = jnp.ones((8, 64))

    def f(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    r = _analyze(f, x, ws)
    stack_bytes = ws.size * 4
    # full-stack charging would be >= 100 * stack = 163 MB; windowed
    # charging is ~100 x (slice + activations) ~= 2 MB
    assert r["bytes_accessed"] < 10 * stack_bytes


def test_collectives_weighted(tmp_path):
    import os
    import subprocess
    import sys
    # collective inside a scan on 8 fake devices, counted x trips
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, sys
sys.path.insert(0, "src")
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline import hlo_cost
mesh = jax.make_mesh((2, 4), ("data", "model"))
def step(x, w):
    return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]
xs = jnp.ones((16, 256)); ws = jnp.ones((6, 256, 256))
with mesh:
    f = jax.jit(step, in_shardings=(
        NamedSharding(mesh, P("data", None)),
        NamedSharding(mesh, P(None, "model", None))))
    r = hlo_cost.analyze_text(f.lower(xs, ws).compile().as_text())
ar = r["collectives"]["bytes"]["all-reduce"]
assert ar == 6 * (16 // 2) * 256 * 4, ar
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=300)
    assert out.returncode == 0 and "OK" in out.stdout, out.stderr[-1500:]
