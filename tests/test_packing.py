"""Balanced-BCSC pack/unpack roundtrips (property-based)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypo import given, settings, strategies as st

from repro.core import packing, topk
from repro.core.prune_grow import BlastSpec, generate_mask


@given(kb=st.integers(2, 8), nb=st.integers(1, 6),
       bi=st.sampled_from([4, 8]), bo=st.sampled_from([4, 8]),
       s=st.floats(0.0, 0.9), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(kb, nb, bi, bo, s, seed):
    spec = BlastSpec(b_in=bi, b_out=bo, s_max=s, total_steps=10)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(k1, (kb * bi, nb * bo))
    g = jax.random.normal(k2, (kb * bi, nb * bo))
    m = generate_mask(spec, w, g, 10)
    wm = topk.apply_block_mask(w, m, bi, bo)
    p = packing.pack(wm, m, bi, bo)
    np.testing.assert_array_equal(np.asarray(packing.unpack(p)),
                                  np.asarray(wm))


def test_pack_unbalanced_pads():
    """Global-selection masks (unbalanced) pack with zero padding."""
    w = jnp.arange(64.0).reshape(8, 8)
    mask = jnp.zeros((2, 2), bool).at[0, 0].set(True).at[1, 0].set(True)
    wm = topk.apply_block_mask(w, mask, 4, 4)
    p = packing.pack(wm, mask, 4, 4)           # col0: 2 blocks, col1: 0
    assert p.nnz == 2
    np.testing.assert_array_equal(np.asarray(packing.unpack(p)),
                                  np.asarray(wm))


def test_pack_stacked_layers_experts():
    spec = BlastSpec(b_in=4, b_out=4, s_max=0.5, total_steps=1)
    w = jax.random.normal(jax.random.PRNGKey(0), (3, 2, 16, 16))
    g = jax.random.normal(jax.random.PRNGKey(1), (3, 2, 16, 16))
    gen = jax.vmap(jax.vmap(lambda wi, gi: generate_mask(spec, wi, gi, 1)))
    m = gen(w, g)
    wm = topk.apply_block_mask(w, m, 4, 4)
    p = packing.pack_stacked(wm, m, 4, 4, nnz=2)
    assert p.blocks.shape[:2] == (3, 2)
    un = jax.vmap(jax.vmap(packing.unpack))(p)
    np.testing.assert_allclose(np.asarray(un), np.asarray(wm))


def test_storage_bytes_reduction():
    """95% sparsity -> ~20x fewer weight bytes (paper Fig. 7)."""
    spec = BlastSpec(b_in=8, b_out=8, s_max=0.95, total_steps=1)
    w = jax.random.normal(jax.random.PRNGKey(0), (512, 512))
    m = generate_mask(spec, w, w, 1)
    wm = topk.apply_block_mask(w, m, 8, 8)
    p = packing.pack(wm, m, 8, 8)
    dense_bytes = w.size * 4
    ratio = dense_bytes / packing.storage_bytes(p)
    assert ratio > 10.0
