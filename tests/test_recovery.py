"""Crash recovery (serving/recovery.py) + the watchdog front end: an
engine whose stepper thread dies — injected host crash, device loss,
page-alloc failure, or a hung step past the watchdog deadline — is
rebuilt by the supervisor and every surviving request completes
bitwise-identical to an uninterrupted run. Live lanes with trusted
device state come back from host-offloaded KV with ZERO re-prefilled
tokens; the rest re-prefill deterministically. The chaos parity oracle
composes a NaN lane + a mid-run crash + a corrupted offload record in
one run."""
import asyncio

import jax
import numpy as np
import pytest

from conftest import tiny_cfg

from repro.models import registry
from repro.obs.trace import Tracer
from repro.serving.engine import Engine
from repro.serving.faults import (EngineCrashError, FaultPlan,
                                  LaneFaultError, RequestCancelledError)
from repro.serving.frontend import AsyncEngine
from repro.serving.recovery import Supervisor


@pytest.fixture(scope="module")
def model():
    cfg = tiny_cfg()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


def _drain(eng):
    out, steps = {}, 0
    while (len(eng.scheduler) or eng.active_lanes or eng._preempted
           or eng._pending_results):
        for r in eng.step():
            out[r.uid] = r
        steps += 1
        assert steps < 500
    eng.finalize_stats()
    return out


def _drain_with_recovery(eng):
    """Drive to completion, recovering in place whenever a step dies —
    the synchronous stand-in for the watchdog loop."""
    out, steps = {}, 0
    while (len(eng.scheduler) or eng.active_lanes or eng._preempted
           or eng._pending_results):
        try:
            for r in eng.step():
                out[r.uid] = r
        except Exception as e:
            Supervisor(eng).recover(e)
        steps += 1
        assert steps < 500
    eng.finalize_stats()
    return out


def _pool_consistent(eng):
    pool = eng.pool
    return (pool.free_pages + pool.referenced + pool.cached_idle
            == pool.n_pages)


def _assert_parity(got, uids, base, buids):
    for u1, u0 in zip(uids, buids):
        assert got[u1].ok, got[u1].error
        assert got[u1].generated.tolist() == base[u0].generated.tolist()
        np.testing.assert_array_equal(got[u1].prompt, base[u0].prompt)


# ----------------------------------------------- supervisor, synchronous
def test_host_crash_salvages_kv_zero_reprefill(model):
    """A host-side crash leaves device arrays intact: every live lane's
    KV is salvaged to host RAM and restored at its exact frontier —
    bitwise-identical results with ZERO extra prefill tokens."""
    cfg, params = model
    prompts = _prompts(cfg, (7, 5, 9), seed=0)

    def make(plan):
        eng = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                     page_size=4, faults=plan)
        return eng, [eng.submit(p, 12) for p in prompts]

    eng0, uids0 = make(None)
    base = _drain(eng0)

    eng, uids = make(FaultPlan().crash(2, device_lost=False))
    got = _drain_with_recovery(eng)
    _assert_parity(got, uids, base, uids0)
    st = eng.stats
    assert st["recoveries"] == 1 and st["engine_crashes"] == 0
    assert st["recovered_zero_reprefill"] >= 1         # salvage worked
    assert st["re_prefilled_tokens"] == 0              # nobody relaunched
    assert st["prefill_tokens"] == eng0.stats["prefill_tokens"]
    assert _pool_consistent(eng) and eng.pool.referenced == 0
    assert len(eng._offload) == 0


def test_device_loss_relaunches_deterministically(model):
    """Device loss: no KV survives, every live lane relaunches as
    prompt+emitted at the queue head — results still bitwise-identical
    (greedy decode is deterministic), re-prefill is paid and counted."""
    cfg, params = model
    prompts = _prompts(cfg, (7, 5, 9), seed=1)

    def make(plan):
        eng = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                     page_size=4, faults=plan)
        return eng, [eng.submit(p, 12) for p in prompts]

    eng0, uids0 = make(None)
    base = _drain(eng0)

    eng, uids = make(FaultPlan().crash(2, device_lost=True))
    got = _drain_with_recovery(eng)
    _assert_parity(got, uids, base, uids0)
    st = eng.stats
    assert st["recoveries"] == 1
    assert st["recovered_zero_reprefill"] == 0
    assert st["re_prefilled_tokens"] > 0
    assert st["prefill_tokens"] > eng0.stats["prefill_tokens"]
    assert eng._recovered_prefix == {}        # every split resolved
    assert _pool_consistent(eng) and eng.pool.referenced == 0


def test_alloc_failure_recovers_and_survives_repeat(model):
    """A page-allocation crash recovers like any other, and a SECOND
    crash chains: the relaunch prompt folds prior emissions, results
    still re-split at the original prompt boundary."""
    cfg, params = model
    prompts = _prompts(cfg, (7, 5), seed=2)

    def make(plan):
        eng = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                     page_size=4, faults=plan)
        return eng, [eng.submit(p, 12) for p in prompts]

    eng0, uids0 = make(None)
    base = _drain(eng0)

    plan = (FaultPlan().fail_alloc(0)              # crash during admit
            .crash(2, device_lost=True)            # then lose the device
            .crash(4, device_lost=True))           # and again
    eng, uids = make(plan)
    got = _drain_with_recovery(eng)
    _assert_parity(got, uids, base, uids0)
    assert len(plan.fired) >= 2                    # alloc + >=1 crash
    assert eng.stats["recoveries"] == len(plan.fired)
    assert eng.stats["faults_injected"] == len(plan.fired)
    assert _pool_consistent(eng) and eng.pool.referenced == 0


def test_recovery_preserves_queued_and_preempted(model):
    """Work that was NOT on a lane survives recovery untouched: queued
    requests stay queued (host state), a preempted record's host KV
    restores after the rebuild — still zero re-prefill for it."""
    cfg, params = model
    prompts = _prompts(cfg, (7, 5, 6), seed=3)

    def make(plan):
        eng = Engine(cfg, params, max_batch=1, max_len=48, slab_k=4,
                     page_size=4, faults=plan)
        return eng, [eng.submit(p, 10) for p in prompts]

    eng0, uids0 = make(None)
    base = _drain(eng0)

    eng, uids = make(None)
    out = {}
    for r in eng.step():                      # uid0 starts decoding
        out[r.uid] = r
    [i] = eng.active_lanes
    eng.preempt(i)                            # uid0 frozen in host RAM
    # crash at the top of the NEXT step — before the restore pass, so
    # the record is still frozen when the supervisor runs
    eng.install_faults(FaultPlan().crash(eng._step_idx))
    try:
        eng.step()
        raise AssertionError("crash did not fire")
    except EngineCrashError as e:
        Supervisor(eng).recover(e)
    assert len(eng._preempted) == 1           # the record survived
    assert len(eng.scheduler) == 2            # so did the queue
    out.update(_drain(eng).items())
    _assert_parity(out, uids, base, uids0)
    assert eng.stats["restores"] >= 1         # uid0 came back from host
    # nobody re-prefilled: total prefill matches the fault-free run
    assert eng.stats["re_prefilled_tokens"] == 0
    assert eng.stats["prefill_tokens"] == eng0.stats["prefill_tokens"]
    assert _pool_consistent(eng) and eng.pool.referenced == 0


# -------------------------------------------------- watchdog front end
def test_watchdog_recovers_hung_step(model):
    """A step stalled past ``watchdog_s`` is condemned, torn down, and
    recovered — streams pause, then complete bitwise-identical; the
    salvage restores >=1 lane with zero re-prefill (the acceptance
    criterion, also recorded by the chaos bench)."""
    cfg, params = model
    prompts = _prompts(cfg, (7, 5, 9), seed=4)

    def make(plan):
        eng = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                     page_size=4, faults=plan)
        return eng, [eng.submit(p, 12) for p in prompts]

    eng0, uids0 = make(None)
    base = _drain(eng0)

    tr = Tracer()

    async def drive():
        eng = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                     page_size=4, tracer=tr,
                     faults=FaultPlan().stall(2, seconds=30.0))
        # the deadline must be generous enough that a REAL (slow but
        # progressing) step never trips it — only the 30s stall does
        front = AsyncEngine(eng, watchdog_s=2.0, max_recoveries=1)
        async with front:
            streams = [await front.submit_async(p, 12) for p in prompts]
            results = [await s.result() for s in streams]
        return eng, front, {r.uid: r for r in results}

    eng, front, got = asyncio.run(drive())
    _assert_parity(got, sorted(got), base, uids0)
    st = eng.stats
    assert st["watchdog_hangs"] == 1 and st["recoveries"] == 1
    assert st["recovered_zero_reprefill"] >= 1
    assert st["re_prefilled_tokens"] == 0
    assert len(front.recovery_log) == 1
    assert front.recovery_log[0]["salvaged_lanes"] >= 1
    assert front.recovery_log[0]["latency_s"] < 10.0
    assert _pool_consistent(eng) and eng.pool.referenced == 0
    # the flight recorder dumped the hang: watchdog first, then the
    # supervisor, each carrying the condemned step's victim timelines
    reasons = [p["reason"] for p in tr.postmortems]
    assert reasons[:2] == ["watchdog_hang", "supervisor_recover"]
    pm = tr.postmortems[0]
    assert pm["spans"], "empty flight-recorder ring at the crash"
    pm_uids = {s["attrs"].get("uid") for s in pm["spans"]} | {
        u for s in pm["spans"]
        for u in (s["attrs"].get("uids") or ())}
    hung = set(tr.postmortems[1]["meta"]["active_uids"])
    assert hung and hung <= pm_uids


@pytest.mark.slow
def test_chaos_parity_oracle(model):
    """THE acceptance oracle: one seeded plan arms a NaN lane, a
    mid-run engine-thread crash, and a corrupted offloaded page — the
    non-faulted requests stream bitwise-identical to the fault-free
    run, the two faulted ones fail with structured errors, and the page
    pool balances after recovery."""
    cfg, params = model
    prompts = _prompts(cfg, (7, 5, 9, 6), seed=5)

    eng0 = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                  page_size=4)
    uids0 = [eng0.submit(p, 12) for p in prompts]
    base = _drain(eng0)

    tr = Tracer()

    async def drive():
        # step 2: lane 1's logits poisoned (quarantine); step 4: the
        # stepper thread dies host-side (salvage both live lanes to
        # host RAM); the FIRST salvage record is bit-flipped, so that
        # lane fails its checksum at restore — three faults, one run
        plan = (FaultPlan(seed=5).poison_logits(2, 1)
                .crash(4, device_lost=False)
                .corrupt_offload(nth_save=0))
        eng = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                     page_size=4, faults=plan, tracer=tr)
        # no stall in this plan: hang detection stays off (watchdog_s
        # None) and the monitor only has to recover the dead stepper
        front = AsyncEngine(eng, max_recoveries=2)
        async with front:
            streams = [await front.submit_async(p, 12) for p in prompts]
            results = {}
            for s in streams:
                try:
                    res = await s.result()
                except Exception as e:         # structured failure
                    results[s.uid] = e
                else:
                    results[res.uid] = res
        return eng, plan, results

    eng, plan, got = asyncio.run(drive())
    assert len(plan.fired) == 3                # all three faults fired
    failed = {u: r for u, r in got.items()
              if isinstance(r, Exception)}
    # exactly two victims: the poisoned lane and the corrupted record
    assert len(failed) == 2
    assert all(isinstance(e, LaneFaultError) for e in failed.values())
    assert sum("checksum" in e.reason for e in failed.values()) == 1
    survivors = sorted(u for u in got if u not in failed)
    _assert_parity(got, survivors, base, survivors)
    st = eng.stats
    assert st["faults_injected"] == 3
    assert st["lanes_quarantined"] == 2
    assert st["recoveries"] == 1 and st["engine_crashes"] == 1
    # free + referenced + cached_idle == n_pages after the dust settles
    assert _pool_consistent(eng) and eng.pool.referenced == 0
    assert len(eng._offload) == 0
    # flight recorder: the stepper crash produced postmortems whose
    # frozen ring holds EVERY victim's span timeline — the poisoned
    # lane's quarantine landed before the crash, so it is in the dump
    assert [p["reason"] for p in tr.postmortems][:2] == [
        "watchdog_crash", "supervisor_recover"]
    pm = tr.postmortems[0]
    assert pm["spans"]
    pm_uids = {s["attrs"].get("uid") for s in pm["spans"]} | {
        u for s in pm["spans"]
        for u in (s["attrs"].get("uids") or ())}
    assert set(failed) <= pm_uids
    quarantined = [s for s in pm["spans"]
                   if s["name"] == "request.quarantined"]
    assert quarantined and quarantined[0]["attrs"]["uid"] in failed


# -------------------------------------------------- front-end satellites
def test_stream_cancel_is_safe_and_isolated(model):
    """``TokenStream.cancel``: the cancelled stream ends with its error
    swallowed, its lane and pages free, the OTHER stream is
    bitwise-identical to a run where the cancelled request never
    interfered — and cancelling twice (or after completion) is a
    no-op."""
    cfg, params = model
    prompts = _prompts(cfg, (7, 5), seed=6)

    eng0 = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                  page_size=4)
    uids0 = [eng0.submit(p, 20) for p in prompts]
    base = _drain(eng0)

    async def drive():
        eng = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                     page_size=4)
        async with AsyncEngine(eng) as front:
            s0 = await front.submit_async(prompts[0], 20)
            s1 = await front.submit_async(prompts[1], 20)
            await s0.__anext__()               # s0 is mid-decode
            await s0.cancel()
            await s0.cancel()                  # twice: no-op
            with pytest.raises(RequestCancelledError):
                await s0.result()
            r1 = await s1.result()
            await s1.cancel()                  # after completion: no-op
            assert (await s1.result()) is r1
            return eng, r1

    eng, r1 = asyncio.run(drive())
    assert r1.generated.tolist() == base[uids0[1]].generated.tolist()
    assert eng.stats["cancelled"] == 1
    assert _pool_consistent(eng) and eng.pool.referenced == 0


def test_aclose_finalizes_orphan_streams(model):
    """Satellite: ``aclose`` must leave NO stream hanging — anything
    still unfinished at teardown (inbox entries that never submitted,
    streams orphaned by a dead stepper) fails with
    ``RequestCancelledError`` instead of awaiting forever."""
    cfg, params = model

    async def drive():
        eng = Engine(cfg, params, max_batch=1, max_len=48, slab_k=4,
                     page_size=4)
        front = AsyncEngine(eng).start()
        s = await front.submit_async(np.ones(4, np.int32), 4)
        await s.result()
        await front.aclose()        # clean shutdown: everything drained
        # orphan a stream + an unsubmitted inbox entry AFTER the
        # stepper is gone (the states a dead stepper leaves behind —
        # nothing will ever finish them except the aclose sweep)
        from repro.serving.frontend import TokenStream
        loop = asyncio.get_running_loop()
        orphan, inboxed = TokenStream(loop), TokenStream(loop)
        orphan._front = inboxed._front = front
        front._streams[999] = orphan
        front._inbox.append(
            (np.ones(4, np.int32), 4, 0, None, inboxed))
        await front.aclose()        # safe to call twice; sweeps both
        for stream in (orphan, inboxed):
            with pytest.raises(RequestCancelledError):
                await stream.result()
            with pytest.raises(RequestCancelledError):
                await stream._submitted
            with pytest.raises(StopAsyncIteration):
                await stream.__anext__()

    asyncio.run(drive())


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_crash_without_recovery_budget_fails_streams(model):
    """max_recoveries=0 keeps the legacy contract: the first crash
    fails every open stream with the structured error instead of
    recovering."""
    cfg, params = model

    async def drive():
        eng = Engine(cfg, params, max_batch=1, max_len=48, slab_k=4,
                     page_size=4,
                     faults=FaultPlan().crash(1, device_lost=False))
        async with AsyncEngine(eng) as front:
            s = await front.submit_async(np.ones(6, np.int32), 12)
            with pytest.raises(EngineCrashError):
                await s.result()

    asyncio.run(drive())
