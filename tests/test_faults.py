"""Fault injection + graceful degradation (serving/faults.py): a
poisoned lane fails ONLY its own request while every other lane stays
bitwise-identical to the fault-free run, load shedding bounds the
admission queue with a retry-after hint, offload records are
capacity-gated and checksum-verified, SLA deadlines cancel mid-decode
without perturbing the survivors, and reset_stats covers every new
counter."""
import jax
import numpy as np
import pytest

from conftest import tiny_cfg

from repro.models import registry
from repro.serving.engine import Engine
from repro.serving.faults import (BackpressureError, DeadlineExceededError,
                                  FaultPlan, LaneFaultError,
                                  OffloadCapacityError,
                                  OffloadCorruptionError,
                                  RequestCancelledError)
from repro.serving.offload import HostKVStore


@pytest.fixture(scope="module")
def model():
    cfg = tiny_cfg()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


def _drain(eng):
    """Drive to completion; {uid: GenResult} (failed ones included)."""
    out = {}
    steps = 0
    while (len(eng.scheduler) or eng.active_lanes or eng._preempted
           or eng._pending_results):
        for r in eng.step():
            out[r.uid] = r
        steps += 1
        assert steps < 500
    eng.finalize_stats()
    return out


def _pool_consistent(eng):
    pool = eng.pool
    return (pool.free_pages + pool.referenced + pool.cached_idle
            == pool.n_pages)


# ------------------------------------------------------- lane quarantine
@pytest.mark.parametrize("mixed", [False, True])
@pytest.mark.parametrize("kind", ["nan", "inf"])
def test_poison_quarantines_only_its_lane(model, mixed, kind):
    """Acceptance core: non-finite logits on one lane fail ONLY that
    request (structured ``LaneFaultError``); every other request's
    tokens are bitwise-identical to the fault-free run — including the
    request admitted into the freed lane afterwards."""
    cfg, params = model
    prompts = _prompts(cfg, (7, 5, 9), seed=4)

    def make(plan):
        eng = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                     page_size=4, mixed=mixed, faults=plan)
        uids = [eng.submit(p, 12) for p in prompts]
        return eng, uids

    eng0, uids0 = make(None)
    base = _drain(eng0)

    # lanes 0/1 admit at step 0; poison lane 0's first decode of step 2
    plan = FaultPlan(seed=0).poison_logits(2, 0, kind=kind)
    eng1, uids1 = make(plan)
    got = _drain(eng1)

    bad = got[uids1[0]]
    assert not bad.ok and isinstance(bad.error, LaneFaultError)
    assert bad.error.uid == uids1[0] and bad.error.lane == 0
    for u1, u0 in zip(uids1[1:], uids0[1:]):
        assert got[u1].ok
        assert got[u1].generated.tolist() == base[u0].generated.tolist()
    assert eng1.stats["faults_injected"] == 1
    assert eng1.stats["lanes_quarantined"] == 1
    assert plan.fired == [f"poison:{kind}@2:lane0"]
    # nothing leaked: the quarantined lane's pages all came back
    assert _pool_consistent(eng1)
    assert eng1.pool.referenced == 0


def test_poisoned_lane_never_donates_to_prefix_cache(model):
    """A quarantined lane's KV is untrusted: its pages free WITHOUT
    parking in the radix tree, so a later identical prompt gets no
    prefix hit from it."""
    cfg, params = model
    [p] = _prompts(cfg, (8,), seed=5)
    eng = Engine(cfg, params, max_batch=1, max_len=48, slab_k=4,
                 page_size=4, prefix_cache=True,
                 faults=FaultPlan().poison_logits(1, 0))
    u0 = eng.submit(p, 8)
    got = _drain(eng)
    assert isinstance(got[u0].error, LaneFaultError)
    assert eng.pool.cached_idle == 0          # nothing donated
    u1 = eng.submit(p, 8)                     # same prompt again
    got = _drain(eng)
    assert got[u1].ok
    assert eng.stats["prefix_hits"] == 0


def test_alloc_failure_is_an_engine_crash(model):
    """An injected page-allocation failure raises out of ``step`` (the
    watchdog's recovery domain, exercised in test_recovery.py) — the
    engine does not half-admit."""
    cfg, params = model
    plan = FaultPlan().fail_alloc(0)
    eng = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                 page_size=4, faults=plan)
    eng.submit(_prompts(cfg, (6,), seed=6)[0], 4)
    with pytest.raises(RuntimeError, match="injected page allocation"):
        eng.step()
    assert "alloc_fail@0" in plan.fired


# ----------------------------------------------------------- load shedding
def test_load_shedding_bounds_queue_with_retry_after(model):
    cfg, params = model
    eng = Engine(cfg, params, max_batch=1, max_len=48, slab_k=4,
                 page_size=4, admission_queue_limit=2)
    ps = _prompts(cfg, (4, 4, 4, 4), seed=7)
    eng.submit(ps[0], 2)
    eng.submit(ps[1], 2)
    for p in ps[2:]:
        with pytest.raises(BackpressureError) as ei:
            eng.submit(p, 2)
        assert ei.value.queue_depth == 2 and ei.value.limit == 2
        assert 0.05 <= ei.value.retry_after_s <= 60.0
    assert len(eng.scheduler) == 2            # the bound held
    assert eng.stats["shed_requests"] == 2
    got = _drain(eng)                          # admitted work unharmed
    assert all(r.ok for r in got.values()) and len(got) == 2
    # queue drained -> capacity again: the retry eventually succeeds
    eng.submit(ps[2], 2)
    assert all(r.ok for r in _drain(eng).values())


# ------------------------------------------------------ offload store gates
def test_offload_capacity_gate():
    store = HostKVStore(capacity_bytes=1000)
    k = np.zeros((1, 2, 4, 1, 8), np.float32)       # 256B, x2 = 512B
    store.save(1, [0, 1], k, np.ones_like(k))
    with pytest.raises(OffloadCapacityError) as ei:
        store.save(2, [0, 1], k, np.ones_like(k))   # 1024 > 1000
    assert ei.value.used == 512 and ei.value.capacity == 1000
    assert 2 not in store and len(store) == 1       # nothing half-saved
    store.pop(1)
    store.save(2, [0, 1], k, np.ones_like(k))       # fits after the pop


def test_offload_checksum_catches_bit_flip():
    store = HostKVStore()
    plan = FaultPlan().corrupt_offload(nth_save=0, bit=3)
    store.fault_hook = plan.on_offload_save
    k = np.arange(64, dtype=np.float32).reshape(1, 2, 4, 1, 8)
    store.save(9, [0, 1], k, np.ones_like(k))
    with pytest.raises(OffloadCorruptionError) as ei:
        store.pop(9)
    assert ei.value.uid == 9 and ei.value.logical == [0]
    assert 9 not in store        # the poisoned record is gone for good
    # an uncorrupted record still round-trips
    store.save(10, [0, 1], k, np.ones_like(k))
    rec = store.pop(10)
    np.testing.assert_array_equal(rec.k, k)


def test_preempt_restore_catches_corrupted_page(model):
    """A preempted lane whose offloaded KV is corrupted in host RAM
    fails structurally at restore — and ONLY that request; the other
    lane's tokens stay bitwise-identical to the fault-free run."""
    cfg, params = model
    prompts = _prompts(cfg, (7, 5), seed=8)

    def run(plan):
        eng = Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                     page_size=4, faults=plan)
        uids = [eng.submit(p, 12) for p in prompts]
        preempted = False
        out, steps = {}, 0
        while (len(eng.scheduler) or eng.active_lanes or eng._preempted
               or eng._pending_results):
            for r in eng.step():
                out[r.uid] = r
            if not preempted:
                live = [i for i in eng.active_lanes
                        if eng._mirror["live"][i]
                        and i not in eng._prefilling
                        and eng.lanes[i].req.uid == uids[0]]
                if live:
                    eng.preempt(live[0])
                    preempted = True
            steps += 1
            assert steps < 500
        eng.finalize_stats()
        return eng, uids, out

    _, uids0, base = run(None)
    plan = FaultPlan().corrupt_offload(nth_save=0)
    eng, uids, got = run(plan)
    assert "bitflip:save0" in plan.fired
    bad = got[uids[0]]
    assert isinstance(bad.error, LaneFaultError)
    assert "checksum" in bad.error.reason
    assert got[uids[1]].generated.tolist() == \
        base[uids0[1]].generated.tolist()
    assert eng.stats["lanes_quarantined"] == 1
    assert eng.stats["faults_injected"] == 1
    assert _pool_consistent(eng) and eng.pool.referenced == 0
    assert len(eng._offload) == 0


# -------------------------------------------------- deadline mid-decode
def test_deadline_expiry_cancels_mid_decode(model):
    """Satellite: a request whose SLA deadline passes mid-decode is
    cancelled at the next host sync (``DeadlineExceededError``), its
    lane and pages free, and the surviving lanes' tokens are
    bitwise-unchanged."""
    cfg, params = model
    prompts = _prompts(cfg, (7, 5), seed=9)

    def run(enforce, deadline):
        eng = Engine(cfg, params, max_batch=2, max_len=64, slab_k=4,
                     page_size=4, enforce_deadlines=enforce)
        u0 = eng.submit(prompts[0], 24, deadline_s=deadline)
        u1 = eng.submit(prompts[1], 24)
        return eng, (u0, u1), _drain(eng)

    _, (b0, b1), base = run(False, None)
    # an already-expired deadline: the cancel lands at the FIRST sync
    # after admission — mid-slab, tokens already decoded on-device
    eng, (u0, u1), got = run(True, 1e-6)
    assert isinstance(got[u0].error, DeadlineExceededError)
    assert isinstance(got[u0].error, RequestCancelledError)  # taxonomy
    assert len(got[u0].generated) < 24        # cancelled mid-decode
    assert got[u1].ok
    assert got[u1].generated.tolist() == base[b1].generated.tolist()
    assert eng.stats["deadline_cancelled"] == 1
    assert eng.stats["cancelled"] == 1
    assert _pool_consistent(eng) and eng.pool.referenced == 0
    # without enforcement the deadline is observability-only
    assert base[b0].ok and len(base[b0].generated) == 24


# -------------------------------------------------------------- cancel
def test_cancel_everywhere_and_idempotent(model):
    """``Engine.cancel`` reaches a request queued, decoding, or frozen
    preempted; frees everything; returns False the second time."""
    cfg, params = model
    prompts = _prompts(cfg, (6, 5, 4), seed=10)
    eng = Engine(cfg, params, max_batch=1, max_len=48, slab_k=4,
                 page_size=4)
    u0, u1, u2 = (eng.submit(p, 10) for p in prompts)
    got = {}

    def take(results):
        got.update((r.uid, r) for r in results)

    assert eng.cancel(u2)                     # still queued
    assert not eng.cancel(u2)                 # idempotent
    take(eng.step())                          # u0 decoding on lane 0
    [i] = eng.active_lanes
    eng.preempt(i)                            # u0 frozen in host RAM
    assert eng.cancel(u0)                     # preempted
    assert len(eng._offload) == 0             # record dropped
    take(eng.step())                          # u1 takes the lane
    assert eng.cancel(u1)                     # active
    got.update(_drain(eng).items())
    assert all(isinstance(r.error, RequestCancelledError)
               for r in got.values()) and len(got) == 3
    assert eng.stats["cancelled"] == 3
    assert _pool_consistent(eng) and eng.pool.referenced == 0
    assert not eng.cancel(u1)                 # already finished


# --------------------------------------------------- stats coverage
def test_reset_stats_covers_fault_counters(model):
    """Regression (mirrors the PR 5 observability test): every fault /
    recovery / shedding counter exists, moves under real activity, and
    is cleared by reset_stats."""
    cfg, params = model
    new_keys = ("faults_injected", "lanes_quarantined", "recoveries",
                "recovered_zero_reprefill", "re_prefilled_tokens",
                "shed_requests", "cancelled", "deadline_cancelled",
                "watchdog_hangs", "engine_crashes")
    eng = Engine(cfg, params, max_batch=1, max_len=48, slab_k=4,
                 page_size=4, admission_queue_limit=1,
                 faults=FaultPlan().poison_logits(1, 0))
    for k in new_keys:
        assert k in eng.stats, k
    ps = _prompts(cfg, (5, 4, 4), seed=11)
    eng.submit(ps[0], 8)
    with pytest.raises(BackpressureError):
        eng.submit(ps[1], 2)
        eng.submit(ps[2], 2)
    _drain(eng)
    assert eng.stats["faults_injected"] == 1
    assert eng.stats["lanes_quarantined"] == 1
    assert eng.stats["shed_requests"] == 1
    # the counters real activity can't cheaply reach here are covered
    # by writing them directly — reset must clear ALL of them
    for k in new_keys:
        eng.stats[k] = eng.stats[k] or 3
    eng.reset_stats()
    for k in new_keys:
        assert eng.stats[k] == 0, k
    eng.finalize_stats()
    assert eng.stats["offload_capacity_bytes"] == 0    # unbounded
