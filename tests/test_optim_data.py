"""AdamW + data-pipeline unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypo import given, settings, strategies as st

from repro.data.pipeline import SyntheticLM
from repro.optim import adamw


def test_adamw_minimizes_quadratic():
    c = adamw.AdamWConfig(peak_lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw.init(params)
    for i in range(200):
        g = {"w": 2 * params["w"]}
        params, opt, _ = adamw.update(c, g, opt, params, i)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == 200.0
    assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5


def test_lr_schedule_shape():
    c = adamw.AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                          end_lr_frac=0.1)
    assert float(adamw.lr_at(c, 0)) == 0.0
    assert float(adamw.lr_at(c, 10)) == 1.0
    assert abs(float(adamw.lr_at(c, 100)) - 0.1) < 1e-6


def test_moment_masking():
    from repro.core.prune_grow import BlastSpec
    spec = BlastSpec(b_in=4, b_out=4)
    opt = {"m": {"layers": {"mlp": {"w_gate": jnp.ones((8, 8))}}},
           "v": {"layers": {"mlp": {"w_gate": jnp.ones((8, 8))}}}}
    masks = {"layers/mlp/w_gate":
             jnp.ones((2, 2), bool).at[0, 0].set(False)}
    out = adamw.mask_moments(opt, masks, spec)
    m = np.asarray(out["m"]["layers"]["mlp"]["w_gate"])
    assert m[:4, :4].max() == 0.0 and m[4:, 4:].min() == 1.0


@given(step=st.integers(0, 1000), rank=st.integers(0, 3))
@settings(max_examples=20, deadline=None)
def test_data_deterministic(step, rank):
    src = SyntheticLM(256, seq_len=16, global_batch=8, seed=7)
    a = src.batch(step, rank=rank, world=4)
    b = src.batch(step, rank=rank, world=4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_data_ranks_disjoint_seeds():
    src = SyntheticLM(256, seq_len=16, global_batch=8, seed=7)
    a = src.batch(0, rank=0, world=4)
    b = src.batch(0, rank=1, world=4)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_memmap_source(tmp_path):
    from repro.data.pipeline import MemmapTokens
    path = str(tmp_path / "toks.bin")
    np.arange(10_000, dtype=np.uint16).tofile(path)
    src = MemmapTokens(path, vocab_size=65_536, seq_len=32,
                       global_batch=4, seed=0)
    b = src.batch(3)
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
