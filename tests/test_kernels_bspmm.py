"""Pallas BSpMM + fused Sparse-MLP kernels vs the pure-jnp oracle
(ref.py), swept over shapes / dtypes / sparsities / block sizes in
interpret mode (task spec: per-kernel allclose vs ref)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing, topk
from repro.core.prune_grow import BlastSpec, generate_mask
from repro.kernels import bspmm as pk, ops, ref


def _packed(key, K, N, bi, bo, s, dtype):
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (K, N), jnp.float32)
    g = jax.random.normal(k2, (K, N), jnp.float32)
    spec = BlastSpec(b_in=bi, b_out=bo, s_max=s, total_steps=1)
    m = generate_mask(spec, w, g, 1)
    wm = topk.apply_block_mask(w, m, bi, bo).astype(dtype)
    return packing.pack(wm, m, bi, bo)


SHAPES = [
    # (M, K, N, bi, bo, sparsity)
    (16, 32, 32, 8, 8, 0.0),
    (32, 64, 96, 16, 16, 0.5),
    (64, 128, 64, 32, 16, 0.75),
    (8, 256, 128, 64, 32, 0.9),
    (128, 64, 64, 16, 64, 0.5),
]


@pytest.mark.parametrize("m,k,n,bi,bo,s", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bspmm_vs_ref(m, k, n, bi, bo, s, dtype):
    key = jax.random.PRNGKey(hash((m, k, n, bi, bo)) % 2**31)
    x = jax.random.normal(key, (m, k), jnp.float32).astype(dtype)
    p = _packed(key, k, n, bi, bo, s, dtype)
    want = ref.bspmm_ref(x, p).astype(jnp.float32)
    got = pk.bspmm(x, p, blk_m=min(m, 16), interpret=True
                   ).astype(jnp.float32)
    tol = 2e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)
    got_xla = ops.bspmm_xla(x, p).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got_xla), np.asarray(want),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("act", ["silu", "gelu", "relu"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_glu_vs_ref(act, dtype):
    key = jax.random.PRNGKey(7)
    m, k, n, bi, bo = 32, 64, 64, 16, 16
    x = jax.random.normal(key, (m, k), jnp.float32).astype(dtype)
    pg = _packed(jax.random.PRNGKey(1), k, n, bi, bo, 0.5, dtype)
    pu = _packed(jax.random.PRNGKey(2), k, n, bi, bo, 0.75, dtype)
    want = ref.fused_glu_ref(x, pg, pu, act=act).astype(jnp.float32)
    got = pk.fused_glu(x, pg, pu, act=act, blk_m=16, interpret=True
                       ).astype(jnp.float32)
    tol = 5e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("s_gate,s_up", [(0.75, 0.25), (0.25, 0.75)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_glu_mismatched_nnz_pad_branch(s_gate, s_up, dtype):
    """Regression for the ``pad_nnz`` alignment branch: when gate and up
    carry different per-column block counts, the sparser operand is
    zero-block padded (idx 0) — the fused kernel must stay exact in both
    directions (interpret mode)."""
    key = jax.random.PRNGKey(11)
    m, k, n, bi, bo = 32, 64, 64, 16, 16
    x = jax.random.normal(key, (m, k), jnp.float32).astype(dtype)
    pg = _packed(jax.random.PRNGKey(3), k, n, bi, bo, s_gate, dtype)
    pu = _packed(jax.random.PRNGKey(4), k, n, bi, bo, s_up, dtype)
    assert pg.nnz != pu.nnz, "setup must exercise the alignment branch"
    want = ref.fused_glu_ref(x, pg, pu).astype(jnp.float32)
    got = pk.fused_glu(x, pg, pu, blk_m=16, interpret=True
                       ).astype(jnp.float32)
    tol = 5e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)
    # explicit alignment must be a no-op w.r.t. the kernel's own padding
    nnz = max(pg.nnz, pu.nnz)
    aligned = pk.fused_glu(x, packing.pad_nnz(pg, nnz),
                           packing.pad_nnz(pu, nnz), blk_m=16,
                           interpret=True).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(aligned), np.asarray(got))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_glu_joint_fast_path(dtype):
    """Joint gate/up structure (identical idx tables): mark_joint takes
    the single-X-stream kernel variant — results must be exact against
    ref AND bitwise-equal to the two-stream path in both backends."""
    key = jax.random.PRNGKey(21)
    m, k, n, bi, bo = 32, 64, 64, 16, 16
    x = jax.random.normal(key, (m, k), jnp.float32).astype(dtype)
    pg = _packed(jax.random.PRNGKey(5), k, n, bi, bo, 0.5, dtype)
    # same mask structure as the gate, different block values
    wu = jax.random.normal(jax.random.PRNGKey(6), pg.blocks.shape,
                           jnp.float32).astype(dtype)
    pu = packing.PackedBCSC(blocks=wu, idx=pg.idx, kb=pg.kb)
    jg, ju = packing.mark_joint(pg, pu)
    assert jg.joint and ju.joint
    for backend_pair in ("pallas", "xla"):
        if backend_pair == "pallas":
            two = pk.fused_glu(x, pg, pu, blk_m=16, interpret=True)
            one = pk.fused_glu(x, jg, ju, blk_m=16, interpret=True)
        else:
            two = ops.fused_glu(x, pg, pu, backend="xla")
            one = ops.fused_glu(x, jg, ju, backend="xla")
        np.testing.assert_array_equal(np.asarray(one), np.asarray(two))
        want = ref.fused_glu_ref(x, pg, pu).astype(jnp.float32)
        tol = 5e-5 if dtype == jnp.float32 else 0.15
        np.testing.assert_allclose(np.asarray(one, jnp.float32),
                                   np.asarray(want), atol=tol, rtol=tol)


def test_mark_joint_rejects_differing_structure():
    """mark_joint is a verified promise: different masks stay unmarked
    (and the fused kernel keeps the two-stream path)."""
    k, n, bi, bo = 64, 64, 16, 16
    pg = _packed(jax.random.PRNGKey(7), k, n, bi, bo, 0.5, jnp.float32)
    pu = _packed(jax.random.PRNGKey(8), k, n, bi, bo, 0.5, jnp.float32)
    assert not np.array_equal(np.asarray(pg.idx), np.asarray(pu.idx))
    g2, u2 = packing.mark_joint(pg, pu)
    assert not g2.joint and not u2.joint


def test_pack_params_marks_joint_pairs():
    """export.pack_params flags gate/up pairs that were pruned with the
    SAME mask (joint pruning) and leaves differing pairs unmarked."""
    import dataclasses as dc

    from conftest import tiny_cfg
    from repro.core import sparse_mlp as sm
    from repro.core.prune_grow import initial_mask
    from repro.models import registry
    from repro.serving import export

    cfg = tiny_cfg()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    masks = {}
    for path in registry.sparse_paths(cfg):
        w = sm.get_path(params, path)
        bi, bo = sm.block_dims_for(cfg.blast, path)
        pspec = dc.replace(cfg.blast, s_init=0.5, s_max=0.5, b_in=bi,
                           b_out=bo)
        fn = lambda wi: initial_mask(pspec, wi)
        for _ in range(w.ndim - 2):
            fn = jax.vmap(fn)
        masks[path] = fn(w)
    # joint pruning: force the up mask to equal the gate mask
    masks["layers/mlp/w_up"] = masks["layers/mlp/w_gate"]
    packed = export.pack_params(cfg, params, masks, dtype=jnp.float32)
    pg = sm.get_path(packed, "layers/mlp/w_gate")
    pu = sm.get_path(packed, "layers/mlp/w_up")
    pd = sm.get_path(packed, "layers/mlp/w_down")
    assert pg.joint and pu.joint and not pd.joint
    np.testing.assert_array_equal(np.asarray(pg.idx), np.asarray(pu.idx))


def test_sparse_mlp_full_eq1():
    """Paper Eq. (1) end-to-end: (silu(XWg) * XWu) Wd, packed."""
    key = jax.random.PRNGKey(0)
    m, d, f = 32, 64, 128
    x = jax.random.normal(key, (m, d))
    pg = _packed(jax.random.PRNGKey(1), d, f, 16, 16, 0.6, jnp.float32)
    pu = _packed(jax.random.PRNGKey(2), d, f, 16, 16, 0.6, jnp.float32)
    pd = _packed(jax.random.PRNGKey(3), f, d, 16, 16, 0.6, jnp.float32)
    want = ref.sparse_mlp_ref(x, pg, pu, pd)
    got = ops.sparse_mlp_apply(x, pg, pu, pd, backend="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-4, rtol=1e-3)


def test_flops_accounting():
    p = _packed(jax.random.PRNGKey(0), 128, 128, 16, 16, 0.75,
                jnp.float32)
    sparse = ops.flops_bspmm(64, p)
    dense = ops.flops_dense(64, 128, 128)
    assert sparse / dense == pytest.approx(0.25, abs=0.05)
