"""Block scoring + top-k selection invariants (paper §3.2 S())."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypo import given, settings, strategies as st

from repro.core import topk


@given(kb=st.integers(1, 8), nb=st.integers(1, 8),
       bi=st.sampled_from([2, 4, 8]), bo=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_block_norms_match_numpy(kb, nb, bi, bo, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(kb * bi, nb * bo)).astype(np.float32)
    got = np.asarray(topk.block_norms(jnp.asarray(w), bi, bo))
    want = np.zeros((kb, nb))
    for i in range(kb):
        for j in range(nb):
            want[i, j] = np.linalg.norm(
                w[i * bi:(i + 1) * bi, j * bo:(j + 1) * bo])
    np.testing.assert_allclose(got, want, rtol=1e-5)


@given(kb=st.integers(2, 16), nb=st.integers(1, 8),
       k=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_balanced_keeps_exactly_k_per_col(kb, nb, k, seed):
    k = min(k, kb)
    scores = jax.random.normal(jax.random.PRNGKey(seed), (kb, nb))
    m = topk.topk_mask_per_col(scores, k)
    assert np.asarray(m).sum(axis=0).tolist() == [k] * nb


@given(kb=st.integers(2, 12), nb=st.integers(1, 8),
       k=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_global_keeps_exactly_k(kb, nb, k, seed):
    k = min(k, kb * nb)
    scores = jax.random.normal(jax.random.PRNGKey(seed), (kb, nb))
    m = topk.topk_mask_global(scores, k)
    assert int(np.asarray(m).sum()) == k


def test_global_selects_largest():
    scores = jnp.asarray([[5.0, 1.0], [4.0, 3.0]])
    m = np.asarray(topk.topk_mask_global(scores, 2))
    assert m.tolist() == [[True, False], [True, False]]


def test_topk_leading_dims_independent():
    scores = jnp.stack([jnp.asarray([[1.0, 9.0], [2.0, 1.0]]),
                        jnp.asarray([[9.0, 1.0], [1.0, 2.0]])])
    m = np.asarray(topk.topk_mask_global(scores, 2))
    assert m.sum(axis=(1, 2)).tolist() == [2, 2]


@given(kb=st.integers(1, 4), nb=st.integers(1, 4),
       bi=st.sampled_from([2, 4]), bo=st.sampled_from([2, 4]))
@settings(max_examples=20, deadline=None)
def test_expand_apply(kb, nb, bi, bo):
    mask = jnp.arange(kb * nb).reshape(kb, nb) % 2 == 0
    w = jnp.ones((kb * bi, nb * bo))
    wm = np.asarray(topk.apply_block_mask(w, mask, bi, bo))
    frac = wm.mean()
    want = np.asarray(mask).mean()
    assert abs(frac - want) < 1e-6
