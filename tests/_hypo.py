"""Optional-``hypothesis`` shim for the property-based test modules.

When the real package is installed (``requirements-dev.txt``) this is a
straight re-export. On a bare environment it falls back to a minimal
deterministic sampler: ``@given`` runs ``max_examples`` random examples
drawn from a per-test seeded generator — weaker than hypothesis (no
shrinking, no edge-case bias) but it keeps every invariant executing
instead of failing at collection.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import zlib

    import numpy as np

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(
                rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(
                rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[
                int(rng.integers(len(elements)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    def settings(max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn
        return deco

    def given(**strats):
        def deco(fn):
            n = getattr(fn, "_fallback_max_examples", 20)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            # hide the drawn parameters from pytest's fixture resolution
            # (hypothesis rewrites the signature the same way)
            del wrapper.__wrapped__
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strats])
            return wrapper
        return deco
