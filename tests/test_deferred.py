"""Deferred-compressed DP gradient reduction (training/deferred.py) —
the partial-manual shard_map train step must match the GSPMD step."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses
import jax, jax.numpy as jnp, numpy as np
import sys
sys.path.insert(0, "tests")
from conftest import tiny_cfg
from repro.optim import adamw
from repro.training import step as ts, deferred

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = tiny_cfg(num_heads=4, num_kv_heads=2, d_model=64, d_ff=128,
               head_dim=16)
opt = adamw.AdamWConfig(total_steps=20, warmup_steps=0)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                      cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                      cfg.vocab_size)}
state = ts.init_state(cfg, jax.random.PRNGKey(0))
s_ref, m_ref = jax.jit(ts.make_train_step(cfg, opt))(state, batch)

state_d = dataclasses.replace(
    state, opt_state=deferred.init_opt_state(cfg, state.params, False))
with mesh:
    step = jax.jit(deferred.make_train_step_deferred(
        cfg, opt, mesh, microbatches=2, compress_grads=False))
    s_d, m_d = step(state_d, batch)

# uncompressed deferred must match the GSPMD step closely
dp = max(float(jnp.abs(a - b).max()) for a, b in
         zip(jax.tree_util.tree_leaves(s_ref.params),
             jax.tree_util.tree_leaves(s_d.params)))

state_c = dataclasses.replace(
    state, opt_state=deferred.init_opt_state(cfg, state.params, True))
with mesh:
    step_c = jax.jit(deferred.make_train_step_deferred(
        cfg, opt, mesh, microbatches=2, compress_grads=True))
    s_c, m_c = step_c(state_c, batch)

print(json.dumps({
    "loss_ref": float(m_ref["loss"]), "loss_d": float(m_d["loss"]),
    "loss_c": float(m_c["loss"]), "param_diff": dp,
    "sparsity_d": float(m_d["sparsity"]),
}))
"""


@pytest.mark.slow
def test_deferred_matches_gspmd_step():
    from repro.distributed.context import HAS_PARTIAL_MANUAL
    if not HAS_PARTIAL_MANUAL:
        pytest.skip("partial-manual shard_map (axis_names) unsupported "
                    "on this jax; the auto= spelling crashes XLA 0.4.x")
    env = dict(os.environ, PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    v = json.loads(out.stdout.strip().splitlines()[-1])
    assert v["loss_ref"] == pytest.approx(v["loss_d"], rel=1e-5)
    assert v["loss_ref"] == pytest.approx(v["loss_c"], rel=1e-5)
    assert v["param_diff"] < 5e-5, v
