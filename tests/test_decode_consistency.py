"""Decode-with-cache must reproduce the full forward's logits
(the KV cache / recurrent-state paths are exact, not approximate)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import registry

ARCHS = ["internvl2-2b", "gemma2-27b", "rwkv6-3b", "zamba2-1.2b",
         "qwen3-moe-235b-a22b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, rng):
    cfg = get_config(arch, smoke=True)
    # patches complicate position bookkeeping; drop them for this test
    if cfg.family == "vlm":
        cfg = dataclasses.replace(cfg, num_patches=0)
    if cfg.is_moe:
        # GShard capacity drops differ between prefill-sized and
        # decode-sized batches; disable drops for the exactness check
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.num_experts))
    params = registry.init_params(cfg, rng)
    B, S = 2, 16
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    full_logits, _ = registry.forward(cfg, params, tokens)

    cache = registry.init_cache(cfg, B, S, dtype=jnp.float32)
    got = []
    for t in range(S):
        logits, cache = registry.decode_step(cfg, params, cache,
                                             tokens[:, t:t + 1],
                                             jnp.int32(t))
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               atol=2e-2, rtol=2e-2)


def test_whisper_decode_matches_forward(rng):
    from repro.models import whisper as wmod
    cfg = get_config("whisper-large-v3", smoke=True)
    params = registry.init_params(cfg, rng)
    B, S = 2, 16
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    frames = jax.random.normal(rng, (B, S, cfg.d_model)) * 0.02
    full_logits, _ = registry.forward(cfg, params, tokens, frames=frames)

    cache = registry.init_cache(cfg, B, S, dtype=jnp.float32, enc_len=S)
    ck, cv = wmod.prefill_cross(cfg, params, frames, dtype=jnp.float32)
    cache = dict(cache, ck=ck, cv=cv)
    got = []
    for t in range(S):
        logits, cache = registry.decode_step(cfg, params, cache,
                                             tokens[:, t:t + 1],
                                             jnp.int32(t))
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               atol=2e-2, rtol=2e-2)
