"""Continuous-batching serving engine (serving/engine.py):

  * greedy parity — equal-length batches are BITWISE-identical to the
    token-by-token ``serve_loop.generate`` oracle;
  * ragged prompt lengths — right-aligned padding + position offsets
    reproduce each sequence's solo generation exactly;
  * slot eviction / reuse — sequences finishing at different steps free
    their lanes for queued requests;
  * admission under queue pressure — more requests than lanes drain
    FIFO and all complete.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.models import registry
from repro.serving import engine, serve_loop
from repro.serving.scheduler import FIFOScheduler, Request


@pytest.fixture(scope="module")
def model():
    cfg = tiny_cfg()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=(int(p),))
            .astype(np.int32) for p in lens]


def test_equal_length_bitwise_parity_with_oracle(model):
    cfg, params = model
    B, P, NEW = 3, 8, 6
    prompts = jnp.asarray(np.stack(_prompts(cfg, [P] * B)))
    want, _ = serve_loop.generate(cfg, params, prompts,
                                  max_new_tokens=NEW)
    got, stats = engine.generate(cfg, params, np.asarray(prompts),
                                 max_new_tokens=NEW, prefill_chunk=4)
    np.testing.assert_array_equal(np.stack(got), np.asarray(want))
    # chunked batched prefill, not a per-token Python loop:
    assert stats["prefill_chunks"] == -(-P // 4)
    assert stats["decode_steps"] == NEW - 1


def test_ragged_prompts_match_solo_generation(model):
    cfg, params = model
    NEW, MAXLEN = 5, 20
    prompts = _prompts(cfg, [5, 8, 3, 7])
    got, _ = engine.generate(cfg, params, prompts, max_new_tokens=NEW,
                             max_len=MAXLEN, prefill_chunk=4)
    for p, g in zip(prompts, got):
        want, _ = serve_loop.generate(cfg, params, jnp.asarray(p)[None],
                                      max_new_tokens=NEW, max_len=MAXLEN)
        np.testing.assert_array_equal(g, np.asarray(want)[0])


def test_slot_eviction_and_reuse(model):
    cfg, params = model
    eng = engine.Engine(cfg, params, max_batch=2, max_len=32,
                        prefill_chunk=4)
    # different budgets -> lanes free at different steps; 4 requests
    # over 2 lanes forces reuse of evicted slots
    prompts = _prompts(cfg, [6, 6, 4, 5])
    uids = [eng.submit(p, n) for p, n in zip(prompts, (3, 7, 4, 6))]
    res = eng.run()
    assert sorted(res) == sorted(uids)
    assert eng.stats["evicted"] == 4 and eng.stats["admitted"] == 4
    assert eng.active_lanes == [] and len(eng.scheduler) == 0
    for uid, p, n in zip(uids, prompts, (3, 7, 4, 6)):
        assert res[uid].generated.size == n
        want, _ = serve_loop.generate(cfg, params, jnp.asarray(p)[None],
                                      max_new_tokens=n, max_len=32)
        np.testing.assert_array_equal(res[uid].tokens,
                                      np.asarray(want)[0])


def test_admission_under_queue_pressure(model):
    cfg, params = model
    eng = engine.Engine(cfg, params, max_batch=2, max_len=24,
                        prefill_chunk=4)
    prompts = _prompts(cfg, [4, 4, 4, 4, 4])
    uids = [eng.submit(p, 4) for p in prompts]
    assert len(eng.scheduler) == 5
    eng.step()
    # only max_batch lanes admitted; the rest wait in the FIFO queue
    assert eng.stats["admitted"] == 2 and len(eng.scheduler) == 3
    res = eng.run()
    assert sorted(res) == sorted(uids)
    assert eng.stats["admitted"] == 5
    for uid, p in zip(uids, prompts):
        want, _ = serve_loop.generate(cfg, params, jnp.asarray(p)[None],
                                      max_new_tokens=4, max_len=24)
        np.testing.assert_array_equal(res[uid].tokens,
                                      np.asarray(want)[0])


def test_local_global_pattern_parity():
    """Paired local/global stacks (gemma2-style) through the engine:
    chunked prefill + ragged offsets must match the oracle too."""
    cfg = tiny_cfg(layer_pattern="local_global", sliding_window=4,
                   attn_logit_softcap=50.0, final_logit_softcap=30.0,
                   scale_embeddings=True, tie_embeddings=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(1))
    prompts = _prompts(cfg, [8, 8], seed=3)
    want, _ = serve_loop.generate(cfg, params,
                                  jnp.asarray(np.stack(prompts)),
                                  max_new_tokens=5)
    got, _ = engine.generate(cfg, params, prompts, max_new_tokens=5,
                             prefill_chunk=4)
    np.testing.assert_array_equal(np.stack(got), np.asarray(want))


def test_scheduler_rules():
    s = FIFOScheduler(max_batch=4, max_len=16)
    with pytest.raises(ValueError):      # prompt can never fit
        s.submit(Request(0, np.zeros(16, np.int32), 4))
    s.submit(Request(1, np.zeros(8, np.int32), 4))
    s.submit(Request(2, np.zeros(2, np.int32), 4))
    # running batch at frontier 4: head (plen 8) blocks FIFO order
    assert s.admit(n_free=2, frontier=4) == []
    assert len(s) == 2
    # fresh batch admits both
    got = s.admit(n_free=2, frontier=0)
    assert [r.uid for r in got] == [1, 2]


def test_engine_rejects_non_kv_families(model):
    cfg, _ = model
    bad = dataclasses.replace(cfg, family="ssm")
    with pytest.raises(NotImplementedError):
        engine.Engine(bad, {}, max_batch=1, max_len=8)
