"""Continuous-batching serving engine (serving/engine.py):

  * greedy parity — equal-length batches are BITWISE-identical to the
    token-by-token ``serve_loop.generate`` oracle for every slab size
    K ∈ {1, 4, 16};
  * ragged prompt lengths — right-aligned group prefill + per-lane
    position offsets reproduce each sequence's solo generation exactly;
  * per-lane frontiers — a freed lane resets its OWN frontier to 0 and
    admits the next request immediately (no waiting for batch drain);
  * mid-slab stops — eos, budget exhaustion, and cache-end truncation
    inside a slab are masked on-device and discarded on the host;
  * admission under queue pressure — more requests than lanes drain
    FIFO and all complete, identically across slab sizes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.models import registry
from repro.serving import engine, serve_loop
from repro.serving.scheduler import FIFOScheduler, Request


@pytest.fixture(scope="module")
def model():
    cfg = tiny_cfg()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=(int(p),))
            .astype(np.int32) for p in lens]


@pytest.mark.parametrize("slab_k", [1, 4, 16])
def test_equal_length_bitwise_parity_with_oracle(model, slab_k):
    cfg, params = model
    B, P, NEW = 3, 8, 6
    prompts = jnp.asarray(np.stack(_prompts(cfg, [P] * B)))
    want, _ = serve_loop.generate(cfg, params, prompts,
                                  max_new_tokens=NEW)
    got, stats = engine.generate(cfg, params, np.asarray(prompts),
                                 max_new_tokens=NEW, prefill_chunk=4,
                                 slab_k=slab_k)
    np.testing.assert_array_equal(np.stack(got), np.asarray(want))
    # chunked batched prefill, not a per-token Python loop:
    assert stats["prefill_chunks"] == -(-P // 4)
    # the host syncs once per SLAB: O(tokens/K) dispatches, not O(tokens)
    assert stats["decode_slabs"] == -(-(NEW - 1) // slab_k)
    assert stats["decode_tokens"] == B * (NEW - 1)


@pytest.mark.parametrize("slab_k", [1, 4])
def test_ragged_prompts_match_solo_generation(model, slab_k):
    cfg, params = model
    NEW, MAXLEN = 5, 20
    prompts = _prompts(cfg, [5, 8, 3, 7])
    got, _ = engine.generate(cfg, params, prompts, max_new_tokens=NEW,
                             max_len=MAXLEN, prefill_chunk=4,
                             slab_k=slab_k)
    for p, g in zip(prompts, got):
        want, _ = serve_loop.generate(cfg, params, jnp.asarray(p)[None],
                                      max_new_tokens=NEW, max_len=MAXLEN)
        np.testing.assert_array_equal(g, np.asarray(want)[0])


def test_slab_sizes_bitwise_identical_under_continuous_admission(model):
    """Ragged continuous-admission workload: 6 requests over 2 lanes
    with different budgets — the slab engine (K=4, 16) must emit exactly
    the per-token engine's (K=1) tokens for every request."""
    cfg, params = model
    prompts = _prompts(cfg, [6, 3, 5, 7, 4, 6], seed=7)
    budgets = (3, 9, 5, 2, 7, 4)

    def run(k):
        eng = engine.Engine(cfg, params, max_batch=2, max_len=32,
                            prefill_chunk=4, slab_k=k)
        uids = [eng.submit(p, n) for p, n in zip(prompts, budgets)]
        res = eng.run()
        return uids, res

    uids1, base = run(1)
    for k in (4, 16):
        uids, res = run(k)
        assert uids == uids1
        for u in uids:
            np.testing.assert_array_equal(res[u].tokens, base[u].tokens)
            assert res[u].truncated == base[u].truncated


def test_mid_slab_budget_exhaustion_and_lane_masking(model):
    """Budgets that end mid-slab (K=16 ≫ budgets): finished lanes are
    masked on-device, their trailing slab tokens discarded, and each
    request still matches its solo oracle generation."""
    cfg, params = model
    prompts = _prompts(cfg, [6, 6, 4], seed=2)
    budgets = (3, 7, 5)
    eng = engine.Engine(cfg, params, max_batch=3, max_len=32,
                        prefill_chunk=4, slab_k=16)
    uids = [eng.submit(p, n) for p, n in zip(prompts, budgets)]
    res = eng.run()
    for uid, p, n in zip(uids, prompts, budgets):
        assert res[uid].generated.size == n
        want, _ = serve_loop.generate(cfg, params, jnp.asarray(p)[None],
                                      max_new_tokens=n, max_len=32)
        np.testing.assert_array_equal(res[uid].tokens,
                                      np.asarray(want)[0])
    # all budgets fit in one slab: exactly one host sync for decode
    assert eng.stats["decode_slabs"] == 1


def test_mid_slab_eos(model):
    """A lane emitting eos inside a slab stops there — identical cut to
    the per-token engine, and the eos token itself is kept."""
    cfg, params = model
    prompts = _prompts(cfg, [5, 7], seed=4)
    # pick as eos a token the second request actually emits mid-stream
    free, _ = engine.generate(cfg, params, prompts, max_new_tokens=10,
                              max_len=32, slab_k=1)
    plen = prompts[1].size
    eos = int(free[1][plen + 4])

    def run(k):
        eng = engine.Engine(cfg, params, max_batch=2, max_len=32,
                            prefill_chunk=4, slab_k=k, eos_id=eos)
        uids = [eng.submit(p, 10) for p in prompts]
        return uids, eng.run()

    uids1, base = run(1)
    uidsk, slab = run(8)
    assert uids1 == uidsk
    for u in uids1:
        np.testing.assert_array_equal(slab[u].tokens, base[u].tokens)
    stopped = slab[uids1[1]]
    assert stopped.generated[-1] == eos
    assert stopped.generated.size <= 5 + 1   # cut at the eos emission


def test_per_lane_frontier_reuse_after_eviction(model):
    """With per-lane frontiers, a freed lane restarts at slot 0 and
    takes the next queued request IMMEDIATELY — while the other lane
    keeps decoding (the old shared frontier only reset on batch drain)."""
    cfg, params = model
    eng = engine.Engine(cfg, params, max_batch=2, max_len=32,
                        prefill_chunk=4, slab_k=1)
    prompts = _prompts(cfg, [6, 6, 4], seed=1)
    uids = [eng.submit(p, n) for p, n in zip(prompts, (2, 12, 4))]
    res = {}
    # run until the short request finishes and the queued one is admitted
    while len(eng.scheduler):
        for r in eng.step():
            res[r.uid] = r
    assert eng.stats["admitted"] == 3
    # the reused lane restarted its own frontier behind the running lane
    fr = eng.frontiers
    busy = [i for i in eng.active_lanes
            if eng.lanes[i].req.uid == uids[1]]
    fresh = [i for i in eng.active_lanes
             if eng.lanes[i].req.uid == uids[2]]
    assert busy and fresh
    assert fr[fresh[0]] < fr[busy[0]]
    res.update(eng.run())
    for uid, p, n in zip(uids, prompts, (2, 12, 4)):
        want, _ = serve_loop.generate(cfg, params, jnp.asarray(p)[None],
                                      max_new_tokens=n, max_len=32)
        np.testing.assert_array_equal(res[uid].tokens,
                                      np.asarray(want)[0])


def test_slot_eviction_and_reuse(model):
    cfg, params = model
    eng = engine.Engine(cfg, params, max_batch=2, max_len=32,
                        prefill_chunk=4, slab_k=4)
    # different budgets -> lanes free at different steps; 4 requests
    # over 2 lanes forces reuse of evicted slots
    prompts = _prompts(cfg, [6, 6, 4, 5])
    uids = [eng.submit(p, n) for p, n in zip(prompts, (3, 7, 4, 6))]
    res = eng.run()
    assert sorted(res) == sorted(uids)
    assert eng.stats["evicted"] == 4 and eng.stats["admitted"] == 4
    assert eng.active_lanes == [] and len(eng.scheduler) == 0
    for uid, p, n in zip(uids, prompts, (3, 7, 4, 6)):
        assert res[uid].generated.size == n
        want, _ = serve_loop.generate(cfg, params, jnp.asarray(p)[None],
                                      max_new_tokens=n, max_len=32)
        np.testing.assert_array_equal(res[uid].tokens,
                                      np.asarray(want)[0])


def test_truncation_at_cache_end_mid_slab(model):
    """A lane that runs out of cache slots mid-slab is truncated at
    exactly the same token as with per-token decode."""
    cfg, params = model
    prompts = _prompts(cfg, [6, 3], seed=5)

    def run(k):
        eng = engine.Engine(cfg, params, max_batch=2, max_len=10,
                            prefill_chunk=4, slab_k=k)
        uids = [eng.submit(p, 16) for p in prompts]
        return uids, eng.run(), eng.stats["truncated"]

    uids1, base, tr1 = run(1)
    uidsk, slab, trk = run(8)
    assert tr1 == trk == 2        # both lanes hit max_len before budget
    for u in uids1:
        assert slab[u].truncated and base[u].truncated
        np.testing.assert_array_equal(slab[u].tokens, base[u].tokens)


def test_admission_under_queue_pressure(model):
    cfg, params = model
    eng = engine.Engine(cfg, params, max_batch=2, max_len=24,
                        prefill_chunk=4, slab_k=2)
    prompts = _prompts(cfg, [4, 4, 4, 4, 4])
    uids = [eng.submit(p, 4) for p in prompts]
    assert len(eng.scheduler) == 5
    eng.step()
    # only max_batch lanes admitted; the rest wait in the FIFO queue
    assert eng.stats["admitted"] == 2 and len(eng.scheduler) == 3
    res = eng.run()
    assert sorted(res) == sorted(uids)
    assert eng.stats["admitted"] == 5
    for uid, p in zip(uids, prompts):
        want, _ = serve_loop.generate(cfg, params, jnp.asarray(p)[None],
                                      max_new_tokens=4, max_len=24)
        np.testing.assert_array_equal(res[uid].tokens,
                                      np.asarray(want)[0])


def test_local_global_pattern_parity():
    """Paired local/global stacks (gemma2-style) through the engine:
    chunked prefill + per-lane slab decode must match the oracle too."""
    cfg = tiny_cfg(layer_pattern="local_global", sliding_window=4,
                   attn_logit_softcap=50.0, final_logit_softcap=30.0,
                   scale_embeddings=True, tie_embeddings=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(1))
    prompts = _prompts(cfg, [8, 8], seed=3)
    want, _ = serve_loop.generate(cfg, params,
                                  jnp.asarray(np.stack(prompts)),
                                  max_new_tokens=5)
    got, _ = engine.generate(cfg, params, prompts, max_new_tokens=5,
                             prefill_chunk=4, slab_k=4)
    np.testing.assert_array_equal(np.stack(got), np.asarray(want))


def test_scheduler_rules():
    s = FIFOScheduler(max_batch=4, max_len=16)
    with pytest.raises(ValueError):      # prompt can never fit
        s.submit(Request(0, np.zeros(16, np.int32), 4))
    s.submit(Request(1, np.zeros(8, np.int32), 4))
    s.submit(Request(2, np.zeros(2, np.int32), 4))
    s.submit(Request(3, np.zeros(2, np.int32), 4))
    # per-lane frontiers: free lanes admit the FIFO prefix immediately
    got = s.admit(n_free=2)
    assert [r.uid for r in got] == [1, 2]
    assert len(s) == 1
    assert [r.uid for r in s.admit(n_free=2)] == [3]


def test_engine_rejects_non_kv_families(model):
    cfg, _ = model
    bad = dataclasses.replace(cfg, family="ssm")
    with pytest.raises(NotImplementedError):
        engine.Engine(bad, {}, max_batch=1, max_len=8)


@pytest.mark.parametrize("mixed", [False, True])
def test_reset_stats_and_observability_counters(model, mixed):
    """Scheduler observability (queue depth high-water, page-gate
    rejections, queued time) and the mixed-batching counters (fused
    steps, stall counter, TTFT/ITL percentiles) are tracked under BOTH
    scheduling modes and all cleared by reset_stats."""
    cfg, params = model
    eng = engine.Engine(cfg, params, max_batch=3, max_len=32,
                        prefill_chunk=4, slab_k=2, page_size=4,
                        n_pages=4, mixed=mixed)   # pool fits one at a time
    for p in _prompts(cfg, [8, 8, 8], seed=9):
        eng.submit(p, 5)
    assert eng.stats["queue_depth_peak"] == 3
    eng.step()                      # one admits; the page gate blocks two
    assert eng.stats["admitted"] == 1
    assert eng.scheduler.rejections >= 1
    eng.run()
    st = eng.stats
    assert st["admission_rejections"] >= 1
    assert st["queued_s_total"] >= st["queued_s_max"] >= 0.0
    assert st["ttft_p95_s"] >= st["ttft_p50_s"] > 0.0
    if mixed:
        # serialized admissions never overlap running decode: the
        # fused step fires per admission, decode is never stalled
        assert st["mixed_steps"] >= 3
        assert st["stalled_decode_steps"] == 0
    else:
        assert st["mixed_steps"] == 0
    eng.reset_stats()
    for key in ("queue_depth_peak", "admission_rejections",
                "queued_s_total", "queued_s_max", "mixed_steps",
                "mixed_s", "stalled_decode_steps", "prefill_chunks",
                "decode_tokens"):
        assert not eng.stats[key], key
    assert eng.scheduler.rejections == 0
    assert eng._ttft == [] and eng._itl == []
