"""Training chaos harness: SIGKILL a subprocess training run at a
seeded step, resume, and assert the final TrainState is
bitwise-identical to an uninterrupted run — including across a
prune-grow boundary. Plus checkpoint-corruption recovery paths driven
by the same TrainFaultPlan."""
import json
import os
import signal

import jax
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.checkpointing.checkpoint import Checkpointer
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.training import train_loop
from repro.training import faults as tf


def _run(cfg, steps, faults=None, **loop_kw):
    src = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=16,
                      seed=3)
    opt = adamw.AdamWConfig(peak_lr=2e-2, warmup_steps=5,
                            total_steps=60, weight_decay=0.0)
    loop = train_loop.TrainLoopConfig(total_steps=steps, log_every=5,
                                      **loop_kw)
    return train_loop.train(cfg, opt, src, loop, faults=faults,
                            log_fn=lambda m: None)


def _leaves(state):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(
        {"step": state.step, "params": state.params,
         "opt_state": state.opt_state, "masks": state.masks,
         "rng": state.rng})]


@pytest.mark.slow
def test_sigkill_resume_bitwise_across_prune_boundary(tmp_path):
    """The headline oracle. Child A is SIGKILLed at step 11 (newest
    checkpoint: step 8); child A2 resumes from 8 and replays — crossing
    the prune-grow mask refresh at step 10 — to completion; child B
    runs uninterrupted. A2's final TrainState must equal B's bitwise,
    leaf for leaf."""
    wd = str(tmp_path)
    ck = os.path.join(wd, "ck")
    spec_a = tf.default_chaos_spec(wd, ckpt_dir=ck, kill_at=11)
    ra = tf.run_child(spec_a, os.path.join(wd, "spec_a.json"))
    assert ra.returncode == -signal.SIGKILL, ra.stderr
    assert Checkpointer(ck).latest_intact_step() == 8

    spec_a2 = tf.default_chaos_spec(wd, ckpt_dir=ck)
    ra2 = tf.run_child(spec_a2, os.path.join(wd, "spec_a2.json"))
    assert ra2.returncode == 0, ra2.stderr
    with open(spec_a2["meta_out"]) as f:
        meta = json.load(f)
    assert meta["resumed_from"] == 8

    spec_b = tf.default_chaos_spec(
        wd, out=os.path.join(wd, "final_b.npz"),
        meta_out=os.path.join(wd, "meta_b.json"))
    rb = tf.run_child(spec_b, os.path.join(wd, "spec_b.json"))
    assert rb.returncode == 0, rb.stderr

    with np.load(spec_a2["out"]) as za, np.load(spec_b["out"]) as zb:
        assert set(za.files) == set(zb.files)
        for k in za.files:
            np.testing.assert_array_equal(za[k], zb[k], err_msg=k)


def test_corrupt_latest_falls_back_and_resume_matches_clean(tmp_path):
    """Bit-flip the newest checkpoint on disk after a run: auto-resume
    must detect the crc mismatch, fall back to the previous intact
    checkpoint, and the resumed run must still end bitwise-identical to
    a clean run (stateless data pipeline replays the gap)."""
    cfg = tiny_cfg()
    d = str(tmp_path / "ck")
    _run(cfg, 12, ckpt_dir=d, ckpt_every=4)        # saves 4, 8, 12
    f = os.path.join(d, "step_00000012", "arrays.npz")
    with open(f, "r+b") as fh:
        fh.seek(0, os.SEEK_END)
        off = fh.tell() // 2
        fh.seek(off)
        b = fh.read(1)
        fh.seek(off)
        fh.write(bytes([b[0] ^ 1]))
    assert Checkpointer(d).latest_intact_step() == 8

    state_a, hist = _run(cfg, 20, ckpt_dir=d, ckpt_every=4)
    metrics = [h for h in hist if "event" not in h]
    assert metrics[-1]["step"] == 19
    assert metrics[-1]["ckpt_fallbacks"] == 1
    state_c, _ = _run(cfg, 20)
    for a, c in zip(_leaves(state_a), _leaves(state_c)):
        np.testing.assert_array_equal(a, c)


def test_fault_plan_corrupts_nth_save(tmp_path):
    """corrupt_checkpoint(nth) flips a byte AFTER the save lands (post
    checksum, post rename): newer corrupt checkpoints are invisible to
    latest_intact_step, and keep-k GC never deleted the newest intact
    one."""
    cfg = tiny_cfg()
    d = str(tmp_path / "ck")
    plan = tf.TrainFaultPlan().corrupt_checkpoint(2).corrupt_checkpoint(3)
    _run(cfg, 16, faults=plan, ckpt_dir=d, ckpt_every=4, keep=3)
    # saves at 4, 8, 12, 16; nth 2 and 3 (steps 12, 16) corrupted
    assert sum(s.startswith("ckpt_bitflip") for s in plan.fired) == 2
    ck = Checkpointer(d)
    assert ck.latest_step() == 16
    assert not ck.verify(16) and not ck.verify(12)
    assert ck.latest_intact_step() == 8
