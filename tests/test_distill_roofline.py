"""Distillation loss + roofline parser unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distill import cross_entropy, distill_loss, kl_to_teacher
from repro.roofline import analysis


def test_ce_matches_manual(rng):
    logits = jax.random.normal(rng, (2, 5, 11))
    labels = jax.random.randint(rng, (2, 5), 0, 11)
    got = float(cross_entropy(logits, labels))
    p = jax.nn.log_softmax(logits, -1)
    want = float(-jnp.take_along_axis(
        p, labels[..., None], axis=-1).mean())
    assert got == pytest.approx(want, rel=1e-5)


def test_ce_ignore_index(rng):
    logits = jax.random.normal(rng, (1, 4, 7))
    labels = jnp.asarray([[1, 2, -100, -100]])
    got = float(cross_entropy(logits, labels))
    want = float(cross_entropy(logits[:, :2], labels[:, :2]))
    assert got == pytest.approx(want, rel=1e-5)


def test_kl_zero_for_identical(rng):
    logits = jax.random.normal(rng, (2, 3, 13))
    assert float(kl_to_teacher(logits, logits)) == pytest.approx(0.0,
                                                                 abs=1e-6)


def test_distill_combines(rng):
    s = jax.random.normal(rng, (1, 4, 9))
    t = jax.random.normal(jax.random.PRNGKey(9), (1, 4, 9))
    labels = jnp.zeros((1, 4), jnp.int32)
    base = float(distill_loss(s, labels))
    with_kd = float(distill_loss(s, labels, t, alpha=1.0, beta=2.0))
    assert with_kd > base


HLO = """
  %ag = bf16[16,4096,512]{2,1,0} all-gather(x), replica_groups={}
  %ar.1 = f32[1024]{0} all-reduce(y), to_apply=%add
  %rs = f32[64,32]{1,0} reduce-scatter(z), dimensions={0}
  %a2a = bf16[8,128]{1,0} all-to-all(w), dimensions={0}
  %cp = u32[4]{0} collective-permute(v), source_target_pairs={{0,1}}
  %ignored = f32[2] add(a, b)
  %agd = bf16[99]{0} all-gather-done(q)
"""


def test_collective_bytes_parser():
    out = analysis.collective_bytes(HLO)
    b = out["bytes"]
    assert b["all-gather"] == 16 * 4096 * 512 * 2
    assert b["all-reduce"] == 1024 * 4
    assert b["reduce-scatter"] == 64 * 32 * 4
    assert b["all-to-all"] == 8 * 128 * 2
    assert b["collective-permute"] == 4 * 4
    assert out["count"]["all-gather"] == 1   # -done line skipped


def test_roofline_terms():
    r = analysis.roofline_terms(197e12, 819e9, 50e9, chips=256,
                                model_flops=197e12 * 256)
    assert r["compute_s"] == pytest.approx(1.0)
    assert r["memory_s"] == pytest.approx(1.0)
    assert r["collective_s"] == pytest.approx(1.0)
    assert r["useful_flops_ratio"] == pytest.approx(1.0)
