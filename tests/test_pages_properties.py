"""Property tests for the refcounted page allocator (serving/pages.py):
random alloc / retain / release / cache_add / cache_drop interleavings
against a shadow model. Invariants, after EVERY operation:

  * ``free + referenced + cached_idle == n_pages`` (no page is ever in
    two states, none is lost);
  * allocation never exceeds ``n_pages`` and over-allocation raises
    instead of handing out phantom pages;
  * ``peak_in_use`` / ``peak_referenced`` are monotone running maxima
    of occupancy / lane-pinned pages;
  * invalid transitions (double free, retain/cache_add of a free page,
    cache_drop of a referenced page) raise and leave state unchanged.

Runs under real ``hypothesis`` when installed, else the deterministic
fallback sampler (tests/_hypo.py).
"""
import numpy as np
import pytest

from _hypo import given, settings, strategies as st
from repro.serving.pages import PagePool


def _model_counts(rc, cached):
    ref = sum(1 for r in rc if r > 0)
    ci = sum(1 for p, r in enumerate(rc) if r == 0 and cached[p])
    return ref, ci


@settings(max_examples=30)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_pages=st.integers(min_value=1, max_value=12),
       n_ops=st.integers(min_value=5, max_value=60))
def test_random_walk_preserves_page_accounting(seed, n_pages, n_ops):
    rng = np.random.default_rng(seed)
    pool = PagePool(n_pages, page_size=4)
    # shadow model
    rc = [0] * n_pages
    cached = [False] * n_pages
    peak_occ = peak_ref = 0

    for _ in range(n_ops):
        op = rng.choice(["alloc", "retain", "release", "cache_add",
                         "cache_drop", "overalloc"])
        owned = [p for p in range(n_pages) if rc[p] > 0]
        idle_cached = [p for p in range(n_pages)
                       if rc[p] == 0 and cached[p]]
        if op == "alloc":
            n = int(rng.integers(0, pool.free_pages + 1))
            got = pool.alloc(n)
            assert len(got) == len(set(got)) == n
            for p in got:
                assert rc[p] == 0 and not cached[p]
                rc[p] = 1
        elif op == "overalloc":
            want = pool.free_pages + 1
            with pytest.raises(RuntimeError, match="exhausted"):
                pool.alloc(want)
        elif op == "retain":
            pick = owned + idle_cached
            if not pick:
                continue
            p = int(rng.choice(pick))
            pool.retain([p])
            rc[p] += 1
        elif op == "release":
            if not owned:
                # double free must raise and change nothing
                free_p = int(rng.integers(0, n_pages))
                with pytest.raises(RuntimeError, match="double free"):
                    pool.release([free_p])
                continue
            p = int(rng.choice(owned))
            pool.release([p])
            rc[p] -= 1
        elif op == "cache_add":
            if not owned:
                continue
            p = int(rng.choice(owned))
            pool.cache_add([p])
            cached[p] = True
        elif op == "cache_drop":
            if idle_cached and rng.integers(2):
                p = int(rng.choice(idle_cached))
                pool.cache_drop([p])
                cached[p] = False
            elif owned and cached[(p := int(rng.choice(owned)))]:
                with pytest.raises(RuntimeError,
                                   match="still referenced"):
                    pool.cache_drop([p])
                continue
            else:
                continue

        # ---- invariants against the shadow model, every step
        ref, ci = _model_counts(rc, cached)
        occ = ref + ci
        peak_occ = max(peak_occ, occ)
        peak_ref = max(peak_ref, ref)
        assert pool.referenced == ref
        assert pool.cached_idle == ci
        assert pool.free_pages == n_pages - occ
        assert pool.free_pages + pool.referenced + pool.cached_idle \
            == n_pages
        assert pool.in_use == occ <= n_pages
        assert pool.peak_in_use == peak_occ      # monotone-correct
        assert pool.peak_referenced == peak_ref


@settings(max_examples=15)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_alloc_release_roundtrip_restores_full_pool(seed):
    rng = np.random.default_rng(seed)
    pool = PagePool(8, 4)
    held: list[int] = []
    for _ in range(20):
        if pool.free_pages and rng.integers(2):
            held.extend(pool.alloc(int(rng.integers(
                1, pool.free_pages + 1))))
        elif held:
            k = int(rng.integers(1, len(held) + 1))
            drop, held = held[:k], held[k:]
            pool.release(drop)
    if held:
        pool.release(held)
    assert pool.free_pages == pool.n_pages
    assert pool.referenced == 0 and pool.cached_idle == 0
    # every page is handed out exactly once when fully drained
    assert sorted(pool.alloc(8)) == list(range(8))
