"""Elastic restart: a checkpoint written from an 8-device (2x4) mesh
restores onto a 4-device (2x2) mesh (e.g. after losing half a pod) and
training continues with identical loss — checkpoints are logical, not
per-device (DESIGN.md §4)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os, sys, json, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
sys.path.insert(0, "tests")
from jax.sharding import NamedSharding, PartitionSpec as P
from conftest import tiny_cfg
from repro.checkpointing.checkpoint import Checkpointer
from repro.distributed import sharding as shd
from repro.distributed.context import DistContext
from repro.models import registry
from repro.optim import adamw
from repro.training import step as ts

cfg = tiny_cfg(num_heads=4, num_kv_heads=2, d_model=64, d_ff=128,
               head_dim=16)
opt = adamw.AdamWConfig(total_steps=20, warmup_steps=0)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                      cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                      cfg.vocab_size)}

def shardings(mesh):
    p_shd = shd.param_sharding_tree(registry.param_specs(cfg), mesh)
    rep = NamedSharding(mesh, P())
    m_shd = shd.mask_sharding_tree(ts.abstract_state(cfg).masks,
                                   registry.axes_tree(cfg),
                                   registry.sparse_paths(cfg), mesh)
    return ts.TrainState(step=rep, params=p_shd,
                         opt_state={"m": p_shd, "v": p_shd},
                         masks=m_shd, rng=rep)

def run_step(mesh, state):
    dist = DistContext(mesh=mesh)
    s_shd = shardings(mesh)
    b_shd = {k: shd.batch_sharding(mesh, v.ndim, v.shape[0])
             for k, v in batch.items()}
    with mesh:
        f = jax.jit(ts.make_train_step(cfg, opt, dist=dist),
                    in_shardings=(s_shd, b_shd),
                    out_shardings=(s_shd, None))
        return f(state, batch)

d = tempfile.mkdtemp()
# step 0 on the BIG mesh (2x4 = "two pods"), checkpoint
big = jax.make_mesh((2, 4), ("data", "model"))
state = ts.init_state(cfg, jax.random.PRNGKey(0))
state, m0 = run_step(big, state)
ck = Checkpointer(d)
ck.save(1, state, blocking=True)

# "lose a pod": restore onto a 2x2 mesh built from 4 devices
small = jax.sharding.Mesh(
    np.asarray(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
tmpl = ts.init_state(cfg, jax.random.PRNGKey(0))
restored = ck.restore_state(tmpl, shardings=None)
restored = jax.tree_util.tree_map(jnp.asarray, restored)
_, m_small = run_step(small, restored)

# reference: continue on the big mesh
_, m_big = run_step(big, state)
print(json.dumps({"small": float(m_small["loss"]),
                  "big": float(m_big["loss"])}))
"""


@pytest.mark.slow
def test_elastic_restart_across_meshes():
    env = dict(os.environ, PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    v = json.loads(out.stdout.strip().splitlines()[-1])
    assert v["small"] == pytest.approx(v["big"], rel=1e-4), v
