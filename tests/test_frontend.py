"""Asyncio front door (serving/frontend.py): streamed tokens and final
results must be bitwise-identical to the synchronous engine, submit-time
rejections must surface through ``await submit_async``, and the engine
thread must drain cleanly on close. Plain ``asyncio.run`` drivers — no
pytest-asyncio dependency."""
import asyncio

import jax
import numpy as np
import pytest

from conftest import tiny_cfg

from repro.models import registry
from repro.serving import serve_loop
from repro.serving.engine import Engine
from repro.serving.frontend import AsyncEngine
from repro.serving.scheduler import BATCH, INTERACTIVE, SLAScheduler


@pytest.fixture(scope="module")
def model():
    cfg = tiny_cfg()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


def _make_engine(cfg, params, **kw):
    base = dict(max_batch=2, max_len=48, slab_k=4, page_size=4)
    base.update(kw)
    return Engine(cfg, params, **base)


def test_stream_matches_sync_engine_bitwise(model):
    """Tokens streamed through the async front end == the synchronous
    engine's results == per-request they equal the final GenResult."""
    cfg, params = model
    prompts = _prompts(cfg, (7, 5, 9, 4))

    sync = _make_engine(cfg, params)
    uids = [sync.submit(p, 12) for p in prompts]
    base = {u: r.generated.tolist() for u, r in sync.run().items()}

    async def drive():
        eng = _make_engine(cfg, params)
        async with AsyncEngine(eng) as front:
            streams = [await front.submit_async(p, 12) for p in prompts]
            got = {}
            for s in streams:
                toks = []
                async for batch in s:
                    toks.extend(batch)
                res = await s.result()
                # the stream IS the result: no token lost or duplicated
                assert toks == res.generated.tolist()
                got[s.uid] = toks
            return got, eng

    got, eng = asyncio.run(drive())
    assert [got[u] for u in sorted(got)] == [base[u] for u in uids]
    # aclose finalized stats on the engine thread
    assert eng.stats["generated_tokens"] == sum(
        len(t) for t in base.values())
    assert "tok_per_s" in eng.stats


def test_stream_matches_oracle_solo(model):
    """One request through the front door == serve_loop.generate."""
    cfg, params = model
    [prompt] = _prompts(cfg, (6,), seed=3)
    want = serve_loop.generate(cfg, params, prompt[None, :],
                               max_new_tokens=10)[0][0, len(prompt):]

    async def drive():
        eng = _make_engine(cfg, params, max_batch=1)
        async with AsyncEngine(eng) as front:
            s = await front.submit_async(prompt, 10)
            return (await s.result()).generated

    got = asyncio.run(drive())
    np.testing.assert_array_equal(got, want)


def test_infeasible_submit_raises_through_async(model):
    cfg, params = model

    async def drive():
        # pool of 8 pages x 4 slots: a 40-slot extent can never fit,
        # while the slot gate (max_len 48) would have let it through
        eng = _make_engine(cfg, params, n_pages=8)
        async with AsyncEngine(eng) as front:
            with pytest.raises(ValueError, match="max_len"):
                await front.submit_async(np.ones(64, np.int32), 4)
            with pytest.raises(ValueError, match="oversized request"):
                await front.submit_async(np.ones(20, np.int32), 21)
            # the front end survives rejections: a feasible request
            # still runs to completion
            s = await front.submit_async(np.ones(4, np.int32), 4)
            res = await s.result()
            assert len(res.generated) == 4

    asyncio.run(drive())


def test_priority_and_preempt_through_front_end(model):
    """SLA classes and preemption compose with the async API: a batch
    job saturating the pool is preempted for an interactive arrival,
    and both streams complete with the engine's usual results."""
    cfg, params = model
    rng = np.random.default_rng(2)
    p_batch = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)
    p_inter = rng.integers(1, cfg.vocab_size, 6).astype(np.int32)

    async def drive():
        eng = Engine(cfg, params, max_batch=2, max_len=32, slab_k=2,
                     page_size=4, n_pages=8, preempt=True,
                     scheduler=SLAScheduler(2, 32, aging_s=None))
        async with AsyncEngine(eng) as front:
            sb = await front.submit_async(p_batch, 20, priority=BATCH)
            # let the batch lane start decoding before the interactive
            # arrives (page pressure is what forces the preemption)
            await asyncio.sleep(0.05)
            si = await front.submit_async(p_inter, 4,
                                          priority=INTERACTIVE,
                                          deadline_s=1.0)
            rb, ri = await sb.result(), await si.result()
            return rb, ri, eng

    rb, ri, eng = asyncio.run(drive())
    assert len(rb.generated) == 20 and len(ri.generated) == 4
    # under this sizing the interactive head cannot fit next to the
    # batch lane's 7-page extent without a preemption
    assert eng.stats["preemptions"] >= 1
    assert eng.stats["restores"] >= 1


def test_submit_after_close_raises(model):
    cfg, params = model

    async def drive():
        eng = _make_engine(cfg, params, max_batch=1)
        front = AsyncEngine(eng)
        with pytest.raises(RuntimeError, match="not running"):
            await front.submit_async(np.ones(4, np.int32), 4)
        front.start()
        s = await front.submit_async(np.ones(4, np.int32), 4)
        await s.result()
        await front.aclose()
        with pytest.raises(RuntimeError, match="not running"):
            await front.submit_async(np.ones(4, np.int32), 4)

    asyncio.run(drive())
