"""Blocked prune-and-grow invariants (paper §3.2 / Fig. 2)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _hypo import given, settings, strategies as st

from repro.core import topk
from repro.core.prune_grow import (BlastSpec, generate_mask,
                                   refresh_mask_and_weight)


def _spec(**kw):
    base = dict(b_in=8, b_out=8, s_max=0.75, total_steps=100,
                step_size=10, grow_frac=0.3)
    base.update(kw)
    return BlastSpec(**base)


@given(seed=st.integers(0, 2**31 - 1),
       kb=st.integers(4, 12), nb=st.integers(2, 8),
       s_max=st.floats(0.2, 0.95))
@settings(max_examples=25, deadline=None)
def test_mask_sparsity_tracks_schedule(seed, kb, nb, s_max):
    spec = _spec(s_max=s_max)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(k1, (kb * 8, nb * 8))
    g = jax.random.normal(k2, (kb * 8, nb * 8))
    m = generate_mask(spec, w, g, spec.total_steps)   # at full schedule
    kept_per_col = np.asarray(m).sum(axis=0)
    want = int(np.ceil((1 - s_max) * kb))
    assert (kept_per_col == max(want, 1)).all()       # balanced exact


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_grown_blocks_zeroed_and_disjoint(seed):
    spec = _spec()
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(k1, (64, 64))
    g = jax.random.normal(k2, (64, 64))
    old = generate_mask(spec, w, g, 50)
    # different gradient at next refresh -> some regrowth
    g2 = jax.random.normal(jax.random.PRNGKey(seed + 1), (64, 64)) * 10
    new, w_new, grown = refresh_mask_and_weight(spec, w, g2, old, 60)
    grown_np = np.asarray(grown)
    # grown is a subset of new and disjoint from old
    assert not np.any(grown_np & np.asarray(old))
    assert np.all(~grown_np | np.asarray(new))
    # regrown weights are zero-initialised (paper: 'initially set to 0')
    wm = np.asarray(w_new)
    em = np.asarray(topk.expand_mask(grown, 8, 8))
    if em.any():
        assert np.abs(wm[em]).max() == 0.0
    # pruned weights are exactly zero
    kept = np.asarray(topk.expand_mask(new, 8, 8))
    assert np.abs(wm[~kept]).max() == 0.0


def test_global_vs_balanced_budget():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    g = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    for sel in ("balanced", "global"):
        spec = _spec(selection=sel)
        m = np.asarray(generate_mask(spec, w, g, spec.total_steps))
        want = int(np.ceil((1 - spec.s_max) * 8)) * 8
        assert m.sum() == want


def test_dynamic_step_jit():
    """The whole refresh is jittable with a TRACED step (no recompiles
    across the schedule — the TPU adaptation's key property)."""
    spec = _spec()
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    g = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    f = jax.jit(lambda step: generate_mask(spec, w, g, step))
    s10 = np.asarray(f(jnp.int32(10))).sum()
    s90 = np.asarray(f(jnp.int32(90))).sum()
    assert s90 < s10  # sparser later in the schedule
