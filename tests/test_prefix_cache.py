"""Radix-tree prefix cache: refcounted copy-on-write page sharing
(serving/prefix_cache.py + refcounted serving/pages.py + engine
``prefix_cache=True``):

  * greedy decode with sharing ON is BITWISE-identical to sharing OFF
    (and the ``serve_loop`` oracle) on fully-shared, partially-shared
    and disjoint prompts, including mid-slab eviction / readmission and
    LRU cache eviction under pool pressure;
  * matched prefixes skip prefill compute (hit-rate / skipped-token
    accounting) and the admission gate sees the EFFECTIVE page cost —
    two requests that could never fit the pool separately are admitted
    together once their common prefix is cached;
  * a partially-filled boundary page is copy-on-write duplicated before
    a lane may write it: two lanes diverging INSIDE the same boundary
    page never corrupt each other or the cached original;
  * the refcounted allocator enforces the page state machine —
    double-free raises instead of handing one physical page to two
    lanes (regression for the historical free-list bug).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import tiny_cfg
from repro.models import registry
from repro.serving import engine, serve_loop
from repro.serving.pages import PagePool
from repro.serving.prefix_cache import PrefixCache


@pytest.fixture(scope="module")
def model():
    cfg = tiny_cfg()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=(int(p),))
            .astype(np.int32) for p in lens]


# ------------------------------------------------------- pool state machine
def test_pool_refcount_lifecycle():
    pool = PagePool(6, 4)
    a = pool.alloc(2)
    assert pool.referenced == 2 and pool.free_pages == 4
    pool.retain(a)                       # second lane shares both pages
    pool.release(a)                      # first lane lets go
    assert pool.referenced == 2          # still pinned by the second
    pool.cache_add([a[0]])               # tree takes page 0
    pool.release(a)                      # second lane lets go
    assert pool.free_pages == 5 and pool.cached_idle == 1
    assert pool.referenced == 0 and pool.in_use == 1
    pool.retain([a[0]])                  # prefix hit re-pins cached page
    assert pool.cached_idle == 0 and pool.referenced == 1
    pool.release([a[0]])
    pool.cache_drop([a[0]])              # eviction frees it
    assert pool.free_pages == 6
    assert pool.peak_referenced == 2 and pool.peak_in_use == 2


def test_pool_double_free_raises():
    """Regression: releasing a page twice used to put it on the free
    list twice — the allocator would later hand ONE physical page to
    TWO lanes. Now every invalid transition raises."""
    pool = PagePool(4, 2)
    a = pool.alloc(2)
    pool.release([a[0]])
    with pytest.raises(RuntimeError, match="double free"):
        pool.release([a[0]])
    with pytest.raises(RuntimeError, match="double free"):
        pool.release([3])                # never allocated
    with pytest.raises(RuntimeError, match="retain of free"):
        pool.retain([a[0]])
    with pytest.raises(RuntimeError, match="cache_add of free"):
        pool.cache_add([a[0]])
    pool.cache_add([a[1]])
    with pytest.raises(RuntimeError, match="still referenced"):
        pool.cache_drop([a[1]])
    pool.release([a[1]])                 # parks cached-idle, not free
    with pytest.raises(RuntimeError, match="double free"):
        pool.release([a[1]])
    with pytest.raises(RuntimeError, match="uncached"):
        pool.cache_drop([a[0]])
    pool.cache_drop([a[1]])
    assert pool.free_pages == 4


# ------------------------------------------------------- radix tree (host)
def test_radix_match_insert_split_and_cap():
    pool = PagePool(16, 4)
    pc = PrefixCache(pool)
    toks = np.arange(10, dtype=np.int32)          # 2 full pages + tail 2
    pages = pool.alloc(3)
    assert pc.insert(toks, pages) == 3            # all donated
    pool.release(pages)                           # park cached-idle
    assert pool.cached_idle == 3 and len(pc) == 3

    # full replay (longer prompt): 2 full pages + 2 tail rows shared
    m = pc.match(np.arange(12, dtype=np.int32))
    assert m.pages == pages[:2] and m.matched_tokens == 10
    assert m.tail_page == pages[2] and m.tail_matched == 2
    # identical prompt: the cap leaves one token to prefill
    m = pc.match(toks)
    assert m.matched_tokens == 9 and m.tail_matched == 1
    # diverging inside the SECOND page splits nothing, matches one page
    div = np.array([0, 1, 2, 3, 99, 98, 97, 96, 5], np.int32)
    m = pc.match(div)
    assert m.pages == pages[:1] and m.matched_tokens == 4
    # insert the divergent sequence: page 0 deduplicated (edge split at
    # the page boundary), pages 1.. donated
    dpages = [pages[0]] + pool.alloc(2)
    pool.retain([pages[0]])
    assert pc.insert(div, dpages) == 2
    pool.release(dpages)
    m2 = pc.match(np.concatenate([div, [7]]).astype(np.int32))
    assert m2.pages == dpages[:2] and m2.tail_matched == 1


def test_radix_lru_eviction_respects_refcounts():
    pool = PagePool(8, 4)
    pc = PrefixCache(pool)
    a = pool.alloc(2)
    pc.insert(np.arange(8, dtype=np.int32), a)          # older
    pool.release(a)
    b = pool.alloc(2)
    pc.insert(np.arange(100, 108, dtype=np.int32), b)   # newer
    pool.release(b)
    # pin the NEWER entry like a reading lane would
    pool.retain(b)
    assert pc.reclaimable() == 2
    assert pc.evict(3) == 2          # only the idle (older) entry goes
    assert pool.free_pages == 8 - 2
    assert pc.match(np.arange(9, dtype=np.int32)).matched_tokens == 0
    m = pc.match(np.arange(100, 109, dtype=np.int32))
    assert m.pages == b              # survived: lanes still read it
    pool.release(b)
    assert pc.evict(8) == 2          # now reclaimable
    assert pool.free_pages == 8


# ------------------------------------------------------------- engine parity
@pytest.mark.parametrize("slab_k", [1, 4])
def test_sharing_bitwise_parity_shared_partial_disjoint(model, slab_k):
    """Fully-shared, partially-shared and disjoint prompts over 2 lanes
    (mid-slab eviction + readmission): sharing on/off and the oracle
    agree bitwise, and the shared workload actually HITS."""
    cfg, params = model
    rng = np.random.default_rng(11)
    sys_p = rng.integers(0, cfg.vocab_size, size=(9,)).astype(np.int32)
    mk = lambda n, s: np.concatenate(
        [sys_p[:n], rng.integers(0, cfg.vocab_size, size=(s,))
         .astype(np.int32)])
    prompts = [np.concatenate([sys_p, [5]]).astype(np.int32),  # shared
               np.concatenate([sys_p, [5]]).astype(np.int32),  # identical
               mk(9, 4),                                       # shared
               mk(5, 6),                                       # partial
               rng.integers(0, cfg.vocab_size, size=(7,))
               .astype(np.int32),                              # disjoint
               mk(9, 2)]                                       # shared
    budgets = (4, 6, 3, 5, 4, 7)
    kw = dict(max_len=32, prefill_chunk=4, slab_k=slab_k, max_batch=2,
              page_size=4, n_pages=24)

    def run(pc):
        eng = engine.Engine(cfg, params, paged=True, prefix_cache=pc,
                            **kw)
        uids = [eng.submit(p, n) for p, n in zip(prompts, budgets)]
        return uids, eng.run(), eng.stats

    uids0, off, _ = run(False)
    uids1, on, st = run(True)
    assert uids0 == uids1
    for u in uids0:
        np.testing.assert_array_equal(on[u].tokens, off[u].tokens)
        assert on[u].truncated == off[u].truncated
    for u, p, n in zip(uids0, prompts, budgets):
        want, _ = serve_loop.generate(cfg, params, jnp.asarray(p)[None],
                                      max_new_tokens=n, max_len=32)
        np.testing.assert_array_equal(on[u].tokens, np.asarray(want)[0])
    assert st["prefix_hits"] > 0
    assert st["prefill_tokens_skipped"] > 0
    assert (st["prefill_tokens"] + st["prefill_tokens_skipped"]
            == st["prompt_tokens"])


def test_cow_two_lanes_diverge_inside_boundary_page(model):
    """A 9-token prompt with budget 1 caches 2 full pages + a 1-row
    boundary tail (page_size=4). Two follow-ups extend that prefix and
    diverge at token 9 — INSIDE the tail page. Each lane must get its
    own CoW copy: bitwise parity with no-sharing, and the cached
    original must still serve a third identical request afterwards."""
    cfg, params = model
    rng = np.random.default_rng(13)
    base = rng.integers(0, cfg.vocab_size, size=(9,)).astype(np.int32)
    kw = dict(max_len=32, prefill_chunk=4, slab_k=2, max_batch=2,
              page_size=4, n_pages=24)
    eng = engine.Engine(cfg, params, prefix_cache=True, **kw)
    uid_a = eng.submit(base, 1)
    eng.run()                                # inserts 2 pages + tail
    assert eng.pool.cached_pages == 3
    p_b = np.concatenate([base, [1, 7, 3]]).astype(np.int32)
    p_c = np.concatenate([base, [2, 7, 3]]).astype(np.int32)
    uid_b, uid_c = eng.submit(p_b, 4), eng.submit(p_c, 4)
    res = eng.run()
    assert eng.stats["cow_copies"] == 2      # one private copy each
    off, _ = engine.generate(cfg, params, [p_b, p_c], max_new_tokens=4,
                             prefix_cache=False, **kw)
    np.testing.assert_array_equal(res[uid_b].tokens, off[0])
    np.testing.assert_array_equal(res[uid_c].tokens, off[1])
    # the shared original survived both divergent writers
    uid_d = eng.submit(np.concatenate([base, [9]]).astype(np.int32), 3)
    res_d = eng.run()
    want, _ = engine.generate(cfg, params,
                              [np.concatenate([base, [9]])],
                              max_new_tokens=3, prefix_cache=False, **kw)
    np.testing.assert_array_equal(res_d[uid_d].tokens, want[0])


def test_repeat_prompt_skips_prefill_compute(model):
    """Serving the same prompt twice: the second admission prefills
    exactly ONE token (the match cap keeps the last token live so the
    engine gets its first logits)."""
    cfg, params = model
    p = _prompts(cfg, [11], seed=4)[0]
    eng = engine.Engine(cfg, params, max_len=32, prefill_chunk=4,
                        slab_k=2, max_batch=1, page_size=4, n_pages=16,
                        prefix_cache=True)
    eng.submit(p, 4)
    eng.run()
    before = eng.stats["prefill_tokens"]
    assert before == 11
    eng.submit(p, 4)
    res = eng.run()
    assert eng.stats["prefill_tokens"] == before + 1
    assert eng.stats["prefill_tokens_skipped"] >= 10
    want, _ = serve_loop.generate(cfg, params, jnp.asarray(p)[None],
                                  max_new_tokens=4, max_len=32)
    np.testing.assert_array_equal(list(res.values())[0].tokens,
                                  np.asarray(want)[0])


def test_eviction_under_pool_pressure_stays_bitwise_correct(model):
    """A pool too small to cache everything: cold entries are LRU
    evicted mid-traffic, readmissions re-prefill from scratch, and
    every token still matches the no-sharing engine bitwise. After the
    drain, every page is free or cached-idle (no leaks)."""
    cfg, params = model
    prompts = _prompts(cfg, [7, 9, 6, 8, 7, 9], seed=3)
    kw = dict(max_len=24, prefill_chunk=4, slab_k=2, max_batch=2,
              page_size=4, n_pages=10)
    on, st = engine.generate(cfg, params, prompts, max_new_tokens=4,
                             prefix_cache=True, **kw)
    off, _ = engine.generate(cfg, params, prompts, max_new_tokens=4,
                             prefix_cache=False, **kw)
    for a, b in zip(on, off):
        np.testing.assert_array_equal(a, b)
    assert st["cache_evicted_pages"] > 0


def test_admission_gate_sees_effective_cost_after_sharing(model):
    """Two 17-token-prefix requests each pinning 6 pages could never sit
    in a 9-page pool together uncached — but with the prefix cached
    they share its 4 full pages (+ the CoW boundary original) and BOTH
    admit in one step."""
    cfg, params = model
    rng = np.random.default_rng(7)
    base = rng.integers(0, cfg.vocab_size, size=(17,)).astype(np.int32)
    p1 = np.concatenate([base, [3]]).astype(np.int32)
    p2 = np.concatenate([base, [8]]).astype(np.int32)
    kw = dict(max_len=24, prefill_chunk=4, slab_k=2, max_batch=2,
              page_size=4, n_pages=9)

    def both(pc):
        eng = engine.Engine(cfg, params, prefix_cache=pc, **kw)
        if pc:                                   # prime the cache
            eng.submit(base, 1)
            eng.run()
            eng.reset_stats()
        eng.submit(p1, 4)
        eng.submit(p2, 4)
        eng.step()
        admitted = eng.stats["admitted"]
        res = eng.run()
        return admitted, res, eng

    cold_admitted, _, _ = both(False)
    warm_admitted, res, eng = both(True)
    assert cold_admitted == 1                    # page-gated serially
    assert warm_admitted == 2                    # shared prefix fits
    assert eng.stats["prefix_hits"] == 2
    off, _ = engine.generate(cfg, params, [p1, p2], max_new_tokens=4,
                             prefix_cache=False, **kw)
    for got, want in zip(res.values(), off):
        np.testing.assert_array_equal(got.tokens, want)


def test_scheduler_observability_counters(model):
    """Queue depth high-water, page-gate rejections and queued-time
    counters are tracked per run and cleared by reset_stats."""
    cfg, params = model
    eng = engine.Engine(cfg, params, max_len=32, prefill_chunk=4,
                        slab_k=2, max_batch=3, page_size=4, n_pages=4)
    for p in _prompts(cfg, [8, 8, 8], seed=9):
        eng.submit(p, 5)
    assert eng.stats["queue_depth_peak"] == 3
    eng.step()                      # one admits; the gate blocks two
    assert eng.stats["admitted"] == 1
    assert eng.scheduler.rejections >= 1
    eng.run()
    assert eng.stats["admission_rejections"] >= 1
    assert eng.stats["queued_s_total"] >= eng.stats["queued_s_max"] >= 0.0
    eng.reset_stats()
    assert eng.stats["queue_depth_peak"] == 0
    assert eng.stats["admission_rejections"] == 0
    assert eng.scheduler.rejections == 0
    assert eng.stats["queued_s_total"] == 0.0


def test_sharing_reduces_referenced_peak_and_prefill(model):
    """The concurrency benefit the benchmark reports: a common system
    prompt over parallel lanes pins its pages ONCE, so the referenced
    peak (pages live lanes pin at once — the rightsized-pool
    requirement) drops strictly below no-sharing, as does prefill."""
    cfg, params = model
    rng = np.random.default_rng(21)
    sys_p = rng.integers(0, cfg.vocab_size, size=(24,)).astype(np.int32)
    prompts = [np.concatenate([sys_p, rng.integers(
        0, cfg.vocab_size, size=(3,)).astype(np.int32)])
        for _ in range(6)]
    kw = dict(max_len=48, prefill_chunk=4, slab_k=2, max_batch=3,
              page_size=4, n_pages=40, max_new_tokens=4)

    def run(pc):
        eng = engine.Engine(cfg, params, prefix_cache=pc,
                            **{k: v for k, v in kw.items()
                               if k != "max_new_tokens"})
        if pc:                                   # prime with the prefix
            eng.submit(sys_p, 1)
            eng.run()
            eng.reset_stats()
        uids = [eng.submit(p, kw["max_new_tokens"]) for p in prompts]
        res = eng.run()
        return [res[u].tokens for u in uids], eng.stats

    off, st_off = run(False)
    on, st_on = run(True)
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a, b)
    assert st_on["prefill_tokens"] < st_off["prefill_tokens"]
    assert (st_on["peak_kv_bytes_referenced"]
            < st_off["peak_kv_bytes_referenced"])


def test_whole_pool_prompt_readmits_after_caching(model):
    """Livelock regression: a request whose extent fills the WHOLE pool
    completes, caches every page, and is resubmitted. The CoW tail
    match would need extent + 1 pages (original + private copy alive at
    once) — permanently inadmissible — so admission must fall back to
    full-page sharing and the rerun must complete with identical
    tokens, not spin in the scheduler forever."""
    cfg, params = model
    p = _prompts(cfg, [20], seed=17)[0]
    eng = engine.Engine(cfg, params, max_batch=1, max_len=32,
                        prefill_chunk=4, slab_k=2, page_size=4,
                        n_pages=8, prefix_cache=True)
    uid1 = eng.submit(p, 13)             # extent = 32 slots = all 8 pages
    first = eng.run()[uid1]
    uid2 = eng.submit(p, 13)
    done = {}
    for _ in range(64):                  # bounded: a livelock fails here
        for r in eng.step():
            done[r.uid] = r
        if uid2 in done:
            break
    assert uid2 in done, "whole-pool readmission never completed"
    np.testing.assert_array_equal(done[uid2].tokens, first.tokens)
    assert eng.stats["prefix_hits"] >= 1  # full pages still shared


def test_prefix_cache_requires_paged(model):
    cfg, params = model
    with pytest.raises(ValueError, match="requires paged"):
        engine.Engine(cfg, params, max_batch=1, max_len=16,
                      paged=False, prefix_cache=True)
