"""Gradient compression (int8-EF) + microbatch grad-accumulation tests
(beyond-paper distributed optimizations, DESIGN.md §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, strategies as st

from conftest import tiny_cfg
from repro.optim import adamw, compress
from repro.training import step as ts


@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.01, 100.0))
@settings(max_examples=25, deadline=None)
def test_quantize_bounded_error(seed, scale):
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    err0 = jnp.zeros_like(g)
    q, s, err = compress.quantize_int8(g, err0)
    deq = compress.dequantize(q, s)
    bound = float(jnp.abs(g).max()) / 127.0
    assert float(jnp.abs(deq - g).max()) <= bound * 0.5 + 1e-9
    # residual is exactly the quantization error
    np.testing.assert_allclose(np.asarray(err), np.asarray(g - deq),
                               atol=1e-6)


def test_error_feedback_unbiased():
    g = jax.random.normal(jax.random.PRNGKey(0), (32,)) * 0.1
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(100):
        q, s, err = compress.quantize_int8(g, err)
        acc = acc + compress.dequantize(q, s)
    np.testing.assert_allclose(np.asarray(acc / 100), np.asarray(g),
                               atol=2e-4)


def test_traffic_report_sparse():
    grads = {"layers": {"mlp": {"w_gate": jnp.ones((64, 64))}},
             "embed": jnp.ones((64, 64))}
    masks = {"layers/mlp/w_gate": jnp.zeros((4, 4), bool)
             .at[0].set(True)}                      # 25% kept
    r = compress.traffic_report(grads, masks)
    assert r["int8_bytes"] == 2 * 64 * 64
    assert r["int8_sparse_bytes"] == 64 * 64 + 64 * 64 // 4
    assert r["reduction_vs_f32"] > 4.0


def test_microbatch_equivalent():
    cfg = tiny_cfg()
    opt = adamw.AdamWConfig(total_steps=10, warmup_steps=0)
    state = ts.init_state(cfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                     cfg.vocab_size),
    }
    s1, m1 = jax.jit(ts.make_train_step(cfg, opt))(state, batch)
    s4, m4 = jax.jit(ts.make_train_step(cfg, opt, microbatches=4))(
        state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]),
                                              rel=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5)
