"""Attention masking edge cases (models/attention.py):

  * ``chunk_attention`` ``lane_mask`` shielding — running lanes' cache
    rows (dense) / pool pages (paged) survive a group prefill untouched;
  * sliding-window attention combined with ragged offsets — the window
    mask is AND-ed with the causal mask, so the ``_PAD_POS`` sentinel
    for left-pad slots must survive both, with and without page
    boundaries inside the window.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import tiny_cfg
from repro.models import attention as attn
from repro.models import registry
from repro.serving import engine, serve_loop


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    layer = jax.tree_util.tree_map(lambda p: p[0], params["layers"])
    return cfg, layer["attn"]


def _rand(rng, shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def test_chunk_attention_lane_mask_shields_cache_rows(setup):
    cfg, p = setup
    rng = np.random.default_rng(0)
    b, c, smax, kv, hd = 3, 4, 16, 2, cfg.head_dim
    x = _rand(rng, (b, c, cfg.d_model))
    ck = _rand(rng, (b, smax, kv, hd))
    cv = _rand(rng, (b, smax, kv, hd))
    offsets = jnp.asarray([0, 1, 2], jnp.int32)
    mask = jnp.asarray([True, False, True])
    _, nk, nv = attn.chunk_attention(cfg, p, x, ck, cv, 4, offsets,
                                     lane_mask=mask)
    # masked lane 1: every cache row bitwise-preserved
    np.testing.assert_array_equal(np.asarray(nk[1]), np.asarray(ck[1]))
    np.testing.assert_array_equal(np.asarray(nv[1]), np.asarray(cv[1]))
    # unmasked lanes: the chunk's rows changed, the rest preserved
    assert not np.array_equal(np.asarray(nk[0, 4:8]),
                              np.asarray(ck[0, 4:8]))
    np.testing.assert_array_equal(np.asarray(nk[0, :4]),
                                  np.asarray(ck[0, :4]))
    np.testing.assert_array_equal(np.asarray(nk[0, 8:]),
                                  np.asarray(ck[0, 8:]))


def test_paged_chunk_lane_mask_shields_pool_pages(setup):
    """Paged twin: a shielded lane's POOL pages survive bitwise — and
    no other page is touched either (the write is a drop, not a
    read-modify-write of someone else's page)."""
    cfg, p = setup
    rng = np.random.default_rng(1)
    b, c, ps, n_pages, kv, hd = 2, 4, 4, 6, 2, cfg.head_dim
    x = _rand(rng, (b, c, cfg.d_model))
    pk = _rand(rng, (n_pages, ps, kv, hd))
    pv = _rand(rng, (n_pages, ps, kv, hd))
    bt = jnp.asarray([[2, 4], [1, 3]], jnp.int32)
    offsets = jnp.asarray([0, 1], jnp.int32)
    mask = jnp.asarray([True, False])
    _, nk, _ = attn.paged_chunk_attention(
        cfg, p, x, pk, pv, bt, 2, offsets, read_pages=2, lane_mask=mask)
    # lane 1 owns pages 1 and 3: untouched
    np.testing.assert_array_equal(np.asarray(nk[1]), np.asarray(pk[1]))
    np.testing.assert_array_equal(np.asarray(nk[3]), np.asarray(pk[3]))
    # unowned pages 0 and 5: untouched too
    np.testing.assert_array_equal(np.asarray(nk[0]), np.asarray(pk[0]))
    np.testing.assert_array_equal(np.asarray(nk[5]), np.asarray(pk[5]))
    # lane 0 wrote slots [2, 6): page 2 rows 2-3 and page 4 rows 0-1
    assert not np.array_equal(np.asarray(nk[2, 2:]),
                              np.asarray(pk[2, 2:]))
    assert not np.array_equal(np.asarray(nk[4, :2]),
                              np.asarray(pk[4, :2]))


def test_sliding_window_with_ragged_offsets_matches_solo():
    """Ragged batch + sliding window through the full engine: every
    request must reproduce its solo (offset-free) generation exactly —
    the window mask must act on LOGICAL positions, with left-pad slots
    excluded by the AND-ed causal/_PAD_POS mask."""
    cfg = tiny_cfg(sliding_window=3)
    params = registry.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,))
               .astype(np.int32) for n in (5, 8, 3)]
    for paged in (False, True):
        got, _ = engine.generate(
            cfg, params, prompts, max_new_tokens=6, max_len=20,
            prefill_chunk=4, slab_k=4, paged=paged,
            **({"page_size": 4} if paged else {}))
        for p, g in zip(prompts, got):
            want, _ = serve_loop.generate(cfg, params,
                                          jnp.asarray(p)[None],
                                          max_new_tokens=6, max_len=20)
            np.testing.assert_array_equal(g, np.asarray(want)[0])


def test_window_mask_across_page_boundary(setup):
    """Direct check that a window smaller than a page AND one spanning a
    page boundary read identical context through the paged gather as
    through the dense cache (page_size=4, window ∈ {2, 5})."""
    cfg, p = setup
    rng = np.random.default_rng(4)
    b, smax, ps, kv, hd = 2, 16, 4, 2, cfg.head_dim
    x = _rand(rng, (b, 1, cfg.d_model))
    ck = _rand(rng, (b, smax, kv, hd))
    cv = _rand(rng, (b, smax, kv, hd))
    offsets = jnp.asarray([0, 2], jnp.int32)
    pos = jnp.asarray([6, 7], jnp.int32)
    # paged pool holding the same data: lane b's page j = rows of ck
    bt = jnp.asarray([[0, 1, 2, 3], [4, 5, 6, 7]], jnp.int32)
    pool_k = jnp.concatenate([ck[0].reshape(4, ps, kv, hd),
                              ck[1].reshape(4, ps, kv, hd)])
    pool_v = jnp.concatenate([cv[0].reshape(4, ps, kv, hd),
                              cv[1].reshape(4, ps, kv, hd)])
    for window in (2, 5):
        want, _, _ = attn.decode_attention(cfg, p, x, ck, cv, pos,
                                           window=window, offsets=offsets)
        got, _, _ = attn.paged_decode_attention(
            cfg, p, x, pool_k, pool_v, bt, pos, read_pages=2,
            window=window, offsets=offsets)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)
