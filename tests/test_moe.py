"""MoE layer invariants: routing, capacity, combine weights, shared
experts, per-expert BLaST masks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import moe, registry


def _cfg():
    return get_config("qwen3-moe-235b-a22b", smoke=True)


def test_router_topk_normalized(rng):
    cfg = _cfg()
    x = jax.random.normal(rng, (10, cfg.d_model))
    router = jax.random.normal(rng, (cfg.d_model, cfg.num_experts))
    vals, idx, aux = moe.route(cfg, x, router)
    assert vals.shape == (10, cfg.top_k)
    np.testing.assert_allclose(np.asarray(vals.sum(-1)), 1.0, atol=1e-5)
    assert int(idx.max()) < cfg.num_experts
    assert float(aux) > 0


def test_capacity_static():
    cfg = _cfg()
    c = moe.capacity(cfg, 1024)
    assert c == int(np.ceil(cfg.top_k * 1024 * cfg.capacity_factor
                            / cfg.num_experts))


def test_expert_offset_partition(rng):
    """Sum of per-shard local_expert_forward over offsets == full E."""
    cfg = _cfg()
    t, d, e = 32, cfg.d_model, cfg.num_experts
    f = cfg.moe_d_ff
    x = jax.random.normal(rng, (t, d)) * 0.3
    ks = jax.random.split(rng, 4)
    wg = jax.random.normal(ks[0], (e, d, f)) * 0.05
    wu = jax.random.normal(ks[1], (e, d, f)) * 0.05
    wd = jax.random.normal(ks[2], (e, f, d)) * 0.05
    router = jax.random.normal(ks[3], (d, e))
    vals, idx, _ = moe.route(cfg, x, router)
    full = moe.local_expert_forward(cfg, x, vals, idx, wg, wu, wd)
    half = e // 2
    p1 = moe.local_expert_forward(cfg, x, vals, idx, wg[:half],
                                  wu[:half], wd[:half], expert_offset=0)
    p2 = moe.local_expert_forward(cfg, x, vals, idx, wg[half:],
                                  wu[half:], wd[half:],
                                  expert_offset=half)
    np.testing.assert_allclose(np.asarray(p1 + p2), np.asarray(full),
                               atol=1e-4, rtol=1e-4)


def test_capacity_drops_tokens():
    """With capacity 1 and many tokens on one expert, extras drop."""
    cfg = dataclasses.replace(_cfg(), capacity_factor=0.01, top_k=1)
    t, d = 64, cfg.d_model
    x = jnp.ones((t, d)) * 0.1
    e = cfg.num_experts
    wg = jnp.ones((e, d, cfg.moe_d_ff)) * 0.01
    wu = jnp.ones((e, d, cfg.moe_d_ff)) * 0.01
    wd = jnp.ones((e, cfg.moe_d_ff, d)) * 0.01
    vals = jnp.ones((t, 1))
    idx = jnp.zeros((t, 1), jnp.int32)       # all tokens -> expert 0
    y = moe.local_expert_forward(cfg, x, vals, idx, wg, wu, wd)
    nz_rows = np.asarray(jnp.any(y != 0, axis=-1)).sum()
    assert nz_rows == moe.capacity(cfg, t)


def test_moe_masks_applied(rng):
    """All-pruned expert masks zero the routed contribution."""
    cfg = _cfg()
    params = registry.init_params(cfg, rng)
    masks = registry.init_masks(cfg, params)
    x = jax.random.randint(rng, (2, 8), 0, cfg.vocab_size)
    logits_dense, _ = registry.forward(cfg, params, x, masks=masks)
    zero_masks = {k: jnp.zeros_like(v) for k, v in masks.items()}
    logits_zero, _ = registry.forward(cfg, params, x, masks=zero_masks)
    # zero masks must change the output (routing contribution killed)
    assert float(jnp.abs(logits_dense - logits_zero).max()) > 1e-6
