"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every
(architecture x input-shape) cell — weak-type-correct, shardable, no
device allocation (MULTI-POD DRY-RUN §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.distributed import sharding as shd
from repro.models import registry


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(cfg, shape):
    gb, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((gb, s), jnp.int32),
             "labels": _sds((gb, s), jnp.int32)}
    if cfg.family == "audio":
        # 50/50 encoder frames / decoder tokens (DESIGN.md §6)
        se = s // 2
        batch = {"tokens": _sds((gb, se), jnp.int32),
                 "labels": _sds((gb, se), jnp.int32),
                 "frames": _sds((gb, se, cfg.d_model), jnp.bfloat16)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = _sds((gb, cfg.num_patches, cfg.d_model),
                                     jnp.bfloat16)
    return batch


def batch_shardings(batch, mesh):
    return {k: shd.batch_sharding(mesh, v.ndim, v.shape[0])
            for k, v in batch.items()}


def decode_specs(cfg, shape):
    """(tokens, cache, pos) abstract values for serve_step."""
    gb, s = shape.global_batch, shape.seq_len
    kw = {}
    if cfg.family == "audio":
        kw["enc_len"] = s // 2
        s = s // 2
    cache = registry.abstract_cache(cfg, gb, s, **kw)
    tokens = _sds((gb, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    return tokens, cache, pos


def cache_shardings(cache, mesh):
    return jax.tree_util.tree_map(
        lambda x: shd.cache_sharding(mesh, x.shape), cache)


def input_specs(arch: str, shape_name: str):
    """Public entry: (cfg, shape, dict of abstract inputs)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        tokens, cache, pos = decode_specs(cfg, shape)
        return cfg, shape, {"tokens": tokens, "cache": cache, "pos": pos}
    return cfg, shape, train_batch_specs(cfg, shape)
