"""Serving launcher: load (or init) a model, prune+pack per BLaST, and
serve greedy generation through the continuous-batching engine
(``serving/engine.py``) — ragged prompt lengths, FIFO admission, lane
reuse. ``--oracle`` falls back to the token-by-token
``serve_loop.generate`` parity path.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b \
        --smoke --prompt-len 16 --new-tokens 32 --batch 4 [--packed] \
        [--max-batch 2] [--ragged] [--prefill-chunk 8]

``--frontdoor`` serves a live multi-tenant trace through the asyncio
production API instead (``serving/frontend.py``): a batch tier queued
up front, interactive requests arriving mid-decode with an SLA
deadline; ``--sla`` orders admission by priority class (with the
anti-starvation aging bound) and ``--preempt`` lets a page-blocked
interactive head preempt batch lanes — their KV pages round-trip
through host RAM (``serving/offload.py``) and decoding resumes at the
saved frontier, never re-prefilling. Prints the per-class TTFT split
and the preemption/offload counters:

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b \
        --smoke --frontdoor --sla --preempt --batch 4 --n-inter 6
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--sparsity", type=float, default=0.8,
                    help="one-shot magnitude sparsity when no ckpt")
    ap.add_argument("--max-batch", type=int, default=0,
                    help="engine lanes (default: --batch)")
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--slab-k", type=int, default=8,
                    help="decode steps per jitted slab (host syncs once "
                         "per slab; 1 = per-token baseline)")
    ap.add_argument("--ragged", action="store_true",
                    help="vary prompt lengths across the batch")
    ap.add_argument("--oracle", action="store_true",
                    help="token-by-token serve_loop.generate instead of "
                         "the continuous-batching engine")
    ap.add_argument("--contiguous", action="store_true",
                    help="dense (B, max_len) KV slab instead of the "
                         "paged page-pool cache (parity baseline)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="cache slots per KV pool page (paged mode)")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="KV pool pages (default: contiguous-equivalent "
                         "max_batch * ceil(max_len / page_size))")
    ap.add_argument("--mixed", action="store_true",
                    help="stall-free mixed batching: fuse chunked "
                         "prefill into the decode step under a token "
                         "budget (decode never stalls for admission)")
    ap.add_argument("--prefill-token-budget", type=int, default=0,
                    help="tokens one mixed step may spend (decode "
                         "first, remainder to prefill chunks; 0 = "
                         "engine default max_batch + prefill_chunk)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prefix cache: share prompt-prefix "
                         "KV pages across requests (refcounted, "
                         "copy-on-write boundary pages, LRU eviction; "
                         "paged mode only)")
    ap.add_argument("--frontdoor", action="store_true",
                    help="serve a live interactive+batch trace through "
                         "the asyncio front door (serving/frontend.py) "
                         "and print the per-class TTFT split")
    ap.add_argument("--sla", action="store_true",
                    help="SLA-class admission (SLAScheduler): "
                         "interactive requests jump the batch tier, "
                         "aged batch requests never starve")
    ap.add_argument("--preempt", action="store_true",
                    help="preempt lower-priority lanes for a blocked "
                         "urgent head: KV pages offload to host RAM "
                         "and restore on readmission (no re-prefill)")
    ap.add_argument("--aging-s", type=float, default=30.0,
                    help="anti-starvation aging period (--sla)")
    ap.add_argument("--n-inter", type=int, default=6,
                    help="interactive arrivals in the frontdoor trace")
    ap.add_argument("--inter-tokens", type=int, default=8)
    ap.add_argument("--inter-gap-s", type=float, default=0.5,
                    help="gap between interactive arrivals")
    ap.add_argument("--deadline-s", type=float, default=0.5,
                    help="interactive SLA deadline (EDF within class)")
    ap.add_argument("--trace-out", default=None,
                    help="record request spans (obs/trace.py) and "
                         "write a Chrome/Perfetto trace JSON here — "
                         "open in https://ui.perfetto.dev")
    ap.add_argument("--artifact", default=None, metavar="DIR",
                    help="serve from a SEALED artifact "
                         "(serving/artifact.py): every layer is "
                         "verified — checksums, config fingerprint, "
                         "packed structure, golden canaries — before a "
                         "single token is served; a corrupt artifact "
                         "exits non-zero with the typed error")
    ap.add_argument("--validate-only", action="store_true",
                    help="with --artifact: verify and exit (exit code "
                         "2 + typed error on any corruption)")
    ap.add_argument("--seal", default=None, metavar="DIR",
                    help="pack (requires --packed) and seal the "
                         "serving weights into DIR as a validated "
                         "artifact — config fingerprint, per-array "
                         "crc32s, golden canary generations — then "
                         "exit")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.core import sparse_mlp as sm
    from repro.models import registry
    from repro.serving import engine, export, serve_loop
    from repro.training import step as ts

    cfg = get_config(args.arch, smoke=args.smoke)

    if args.validate_only and not args.artifact:
        raise SystemExit("--validate-only requires --artifact")
    if args.artifact:
        from repro.serving import artifact as art
        try:
            params, manifest = art.load(args.artifact, cfg,
                                        run_canaries=True)
        except art.ArtifactError as e:
            print(f"artifact INVALID ({type(e).__name__}): {e}")
            raise SystemExit(2)
        print(f"artifact OK: fingerprint "
              f"{manifest['fingerprint'][:12]}…, "
              f"{len(manifest['checksums'])} arrays, "
              f"{len(manifest.get('canaries', []))} canaries replayed")
        if args.validate_only:
            return
        _serve(cfg, params, args)
        return

    state = ts.init_state(cfg, jax.random.PRNGKey(0))
    if args.ckpt_dir:
        from repro.checkpointing.checkpoint import Checkpointer
        state = Checkpointer(args.ckpt_dir).restore_state(state)
    elif cfg.blast.enabled:
        # no checkpoint: one-shot magnitude prune at --sparsity
        spec = dataclasses.replace(cfg.blast, s_init=args.sparsity,
                                   s_max=args.sparsity)
        masks = {}
        from repro.core.prune_grow import initial_mask
        import dataclasses as dc
        for path in registry.sparse_paths(cfg):
            w = state.params[path.split("/")[0]]
            w = sm.get_path(state.params, path)
            bi, bo = sm.block_dims_for(spec, path)
            pspec = dc.replace(spec, b_in=bi, b_out=bo)
            fn = lambda wi: initial_mask(pspec, wi)
            for _ in range(w.ndim - 2):
                fn = jax.vmap(fn)
            masks[path] = fn(w)
        state = dataclasses.replace(state, masks=masks)

    pad_report: dict = {}
    params = (export.pack_params(cfg, state.params, state.masks,
                                 pad_report=pad_report)
              if args.packed else
              export.prune_params(cfg, state.params, state.masks))
    print("serving memory:", export.memory_report(cfg, params))

    if args.seal:
        from repro.serving import artifact as art
        if not args.packed:
            raise SystemExit("--seal requires --packed (artifacts hold "
                             "packed serving params)")
        manifest = art.seal(cfg, params, args.seal,
                            pad=pad_report or None)
        print(f"sealed {args.seal}: fingerprint "
              f"{manifest['fingerprint'][:12]}…, "
              f"{len(manifest['checksums'])} arrays, "
              f"{len(manifest['canaries'])} canaries")
        return

    _serve(cfg, params, args)


def _serve(cfg, params, args):
    from repro.models import registry
    from repro.serving import engine, serve_loop

    rng = np.random.default_rng(0)
    tracer = None
    if args.trace_out:
        from repro.obs.trace import Tracer
        tracer = Tracer()
    if args.frontdoor:
        if not registry.supports_prefill_chunk(cfg):
            raise SystemExit(f"--frontdoor needs an engine-servable "
                             f"family; {cfg.family!r} is not")
        _frontdoor(cfg, params, args, rng, tracer=tracer)
        _write_trace(args, tracer)
        return
    if args.oracle or not registry.supports_prefill_chunk(cfg):
        prompts = jnp.asarray(rng.integers(
            0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
            jnp.int32)
        toks, stats = serve_loop.generate(cfg, params, prompts,
                                          max_new_tokens=args.new_tokens)
        print(f"generated {toks.shape} — {stats['tok_per_s']:.1f} tok/s")
        print(toks[:, args.prompt_len:][:2])
        return
    lens = (rng.integers(max(1, args.prompt_len // 2),
                         args.prompt_len + 1, size=args.batch)
            if args.ragged else [args.prompt_len] * args.batch)
    prompts = [rng.integers(0, cfg.vocab_size, size=(int(p),))
               .astype(np.int32) for p in lens]
    toks, stats = engine.generate(
        cfg, params, prompts, max_new_tokens=args.new_tokens,
        max_batch=args.max_batch or args.batch,
        prefill_chunk=args.prefill_chunk, slab_k=args.slab_k,
        paged=not args.contiguous, page_size=args.page_size,
        n_pages=args.n_pages or None, prefix_cache=args.prefix_cache,
        mixed=args.mixed,
        prefill_token_budget=args.prefill_token_budget or None,
        tracer=tracer)
    print(f"generated {len(toks)} seqs — {stats['tok_per_s']:.1f} tok/s "
          f"({stats['decode_slabs']} slabs of {args.slab_k}, "
          f"{stats['prefill_chunks']} prefill chunks, "
          f"peak_kv_kib={stats['peak_kv_bytes'] / 1024:.1f}, "
          f"ttft_p95_ms={stats['ttft_p95_s'] * 1e3:.1f})"
          + (f" prefix_hit_rate={stats['prefix_hit_rate']:.2f} "
             f"skipped={stats['prefill_tokens_skipped']}"
             if args.prefix_cache else "")
          + (f" mixed_steps={stats['mixed_steps']} "
             f"stalled={stats['stalled_decode_steps']}"
             if args.mixed else ""))
    for p, t in list(zip(prompts, toks))[:2]:
        print(t[p.size:])
    _write_trace(args, tracer)


def _write_trace(args, tracer):
    if tracer is None:
        return
    from repro.obs.export import write_chrome_trace
    write_chrome_trace(args.trace_out, tracer.records)
    print(f"wrote {len(tracer.records)} spans to {args.trace_out} "
          f"(open in https://ui.perfetto.dev)")


def _frontdoor(cfg, params, args, rng, tracer=None):
    """The asyncio front door over a live multi-tenant trace: batch
    jobs saturate the lanes, interactive requests trickle in and (with
    --sla / --preempt) jump the queue or preempt a batch lane's KV to
    host. Streams are consumed concurrently; per-class TTFT is measured
    from each request's own submission."""
    from repro.serving.engine import Engine
    from repro.serving.frontend import AsyncEngine
    from repro.serving.scheduler import (BATCH, INTERACTIVE,
                                         FIFOScheduler, SLAScheduler)

    max_batch = args.max_batch or 2
    max_len = max(args.prompt_len + args.new_tokens + 8, 32)

    def build():
        sched = (SLAScheduler(max_batch, max_len, aging_s=args.aging_s)
                 if args.sla else FIFOScheduler(max_batch, max_len))
        return Engine(cfg, params, max_batch=max_batch, max_len=max_len,
                      prefill_chunk=args.prefill_chunk,
                      slab_k=args.slab_k, page_size=args.page_size,
                      n_pages=args.n_pages or None, scheduler=sched,
                      mixed=args.mixed, preempt=args.preempt,
                      tracer=tracer)

    # jit-warm both request shapes outside the served trace
    warm = build()
    warm.submit(np.ones(args.prompt_len, np.int32), 4, priority=BATCH)
    warm.submit(np.ones(max(args.prompt_len // 2, 1), np.int32), 4,
                priority=INTERACTIVE)
    warm.run()

    eng = build()
    lat = {"batch": [], "interactive": []}

    async def one(front, prompt, tokens, klass, *, delay=0.0, **kw):
        """One client: wait for its arrival time, submit, stream.
        TTFT is measured from BEFORE the submit — ack latency (the
        engine thread drains its inbox between steps) and queue wait
        both count, as a served client would experience them."""
        await asyncio.sleep(delay)
        t0 = time.monotonic()
        stream = await front.submit_async(prompt, tokens, **kw)
        first = None
        async for _ in stream:
            if first is None:
                first = time.monotonic() - t0
        await stream.result()
        lat[klass].append((first, time.monotonic() - t0))

    async def drive():
        async with AsyncEngine(eng) as front:
            tasks = []
            for _ in range(args.batch):
                p = rng.integers(0, cfg.vocab_size, args.prompt_len)
                tasks.append(one(front, p.astype(np.int32),
                                 args.new_tokens, "batch",
                                 priority=BATCH))
            for k in range(args.n_inter):
                p = rng.integers(0, cfg.vocab_size,
                                 max(args.prompt_len // 2, 1))
                tasks.append(one(front, p.astype(np.int32),
                                 args.inter_tokens, "interactive",
                                 delay=(k + 1) * args.inter_gap_s,
                                 priority=INTERACTIVE,
                                 deadline_s=args.deadline_s))
            await asyncio.gather(*tasks)

    asyncio.run(drive())
    for klass in ("interactive", "batch"):
        ttft = np.array([t for t, _ in lat[klass]])
        e2e = np.array([e for _, e in lat[klass]])
        print(f"{klass:>12}: n={len(ttft)} "
              f"ttft p50={np.percentile(ttft, 50) * 1e3:7.1f}ms "
              f"p95={np.percentile(ttft, 95) * 1e3:7.1f}ms   "
              f"e2e p95={np.percentile(e2e, 95) * 1e3:7.1f}ms")
    st = eng.stats
    print(f"{'engine':>12}: {st['e2e_tok_per_s']:.1f} tok/s e2e, "
          f"preemptions={st['preemptions']} restores={st['restores']} "
          f"offloaded_pages={st['offloaded_pages']} "
          f"offload_bytes_peak={st['offload_bytes_peak']:,} "
          f"stalled_decode_steps={st['stalled_decode_steps']}")


if __name__ == "__main__":
    main()
