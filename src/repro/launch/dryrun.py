import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before any jax import (task spec).

"""Multi-pod dry-run: lower + compile train_step / serve_step for every
(architecture x input shape) on the 16x16 single-pod mesh and the
2x16x16 multi-pod mesh; record memory_analysis, cost_analysis and the
roofline terms (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch internvl2-2b \
        --shape train_4k [--multipod] [--out results/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, cells, get_config, skip_shapes
from repro.distributed import sharding as shd
from repro.distributed.context import DistContext
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.optim import adamw
from repro.roofline import analysis
from repro.training import step as train_step_mod


def _state_shardings(cfg, mesh):
    pspecs = registry.param_specs(cfg)
    p_shd = shd.param_sharding_tree(pspecs, mesh)
    masks_abs = train_step_mod.abstract_state(cfg).masks
    m_shd = shd.mask_sharding_tree(masks_abs, registry.axes_tree(cfg),
                                   registry.sparse_paths(cfg), mesh) \
        if cfg.blast.enabled else {}
    rep = NamedSharding(mesh, P())
    return train_step_mod.TrainState(
        step=rep, params=p_shd,
        opt_state={"m": p_shd, "v": p_shd}, masks=m_shd, rng=rep)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               packed: bool = False):
    """Returns (lowered, compiled, meta)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(mesh.devices.size)
    cfg, shape, inputs = specs_mod.input_specs(arch, shape_name)
    # §Perf experiment knobs (baseline = all unset)
    import dataclasses as _dc
    overrides = {}
    if os.environ.get("DRYRUN_REMAT"):
        overrides["remat_policy"] = os.environ["DRYRUN_REMAT"]
    if os.environ.get("DRYRUN_CHUNK"):
        overrides["chunk_size"] = int(os.environ["DRYRUN_CHUNK"])
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    dist = DistContext(mesh=mesh,
                       sp=not os.environ.get("DRYRUN_NO_SP"))
    rep = NamedSharding(mesh, P())
    t0 = time.time()

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig(total_steps=cfg.blast.total_steps)
        mb = int(os.environ.get("DRYRUN_MICROBATCH", "1"))
        if os.environ.get("DRYRUN_DEFERRED"):
            from repro.training import deferred
            mb = int(os.environ["DRYRUN_DEFERRED"])
            ts = deferred.make_train_step_deferred(
                cfg, opt_cfg, mesh, microbatches=mb,
                compress_grads=not os.environ.get("DRYRUN_NOCOMPRESS"))
        else:
            ts = train_step_mod.make_train_step(cfg, opt_cfg, dist=dist,
                                                microbatches=mb)
        state_abs = train_step_mod.abstract_state(cfg)
        if os.environ.get("DRYRUN_DEFERRED"):
            state_abs = train_step_mod.TrainState(
                step=state_abs.step, params=state_abs.params,
                opt_state={**state_abs.opt_state,
                           "ef": state_abs.params
                           if not os.environ.get("DRYRUN_NOCOMPRESS")
                           else {}},
                masks=state_abs.masks, rng=state_abs.rng)
        state_shd = _state_shardings(cfg, mesh)
        if os.environ.get("DRYRUN_DEFERRED") \
                and not os.environ.get("DRYRUN_NOCOMPRESS"):
            state_shd = train_step_mod.TrainState(
                step=state_shd.step, params=state_shd.params,
                opt_state={**state_shd.opt_state,
                           "ef": state_shd.params},
                masks=state_shd.masks, rng=state_shd.rng)
        batch_shd = specs_mod.batch_shardings(inputs, mesh)
        with mesh:
            lowered = jax.jit(
                ts, in_shardings=(state_shd, batch_shd),
                out_shardings=(state_shd, None),
                donate_argnums=(0,)).lower(state_abs, inputs)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * cfg.n_active_params() * tokens
    elif shape.kind == "prefill":
        def prefill(params, batch):
            kw = {}
            if cfg.family == "audio":
                kw["frames"] = batch["frames"]
            if cfg.family == "vlm":
                kw["patch_embeds"] = batch["patch_embeds"]
            logits, _ = registry.forward(cfg, params, batch["tokens"],
                                         masks=None, dist=dist, **kw)
            return logits[:, -1]
        params_abs = _serve_params(cfg)
        p_shd = shd.param_sharding_tree(registry.param_specs(cfg), mesh)
        batch = dict(inputs)
        batch.pop("labels", None)
        batch_shd = specs_mod.batch_shardings(batch, mesh)
        with mesh:
            lowered = jax.jit(
                prefill, in_shardings=(p_shd, batch_shd)).lower(
                params_abs, batch)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * cfg.n_active_params() * tokens
    else:  # decode
        def serve_step(params, cache, tokens, pos):
            logits, new_cache = registry.decode_step(
                cfg, params, cache, tokens, pos, masks=None, dist=dist)
            return jnp.argmax(logits[:, -1], -1), new_cache
        if packed or os.environ.get("DRYRUN_PACKED"):
            from repro.serving import export
            sparsity = float(os.environ.get("DRYRUN_SPARSITY", "0.8"))
            params_abs, p_shd = export.abstract_packed_params(
                cfg, sparsity, mesh)
        else:
            params_abs = _serve_params(cfg)
            p_shd = shd.param_sharding_tree(registry.param_specs(cfg),
                                            mesh)
        cache_shd = specs_mod.cache_shardings(inputs["cache"], mesh)
        tok_shd = shd.batch_sharding(mesh, 2, inputs['tokens'].shape[0])
        with mesh:
            lowered = jax.jit(
                serve_step,
                in_shardings=(p_shd, cache_shd, tok_shd, rep),
                donate_argnums=(1,)).lower(
                params_abs, inputs["cache"], inputs["tokens"],
                inputs["pos"])
        model_flops = 2 * cfg.n_active_params() * shape.global_batch
    lower_s = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "kind": shape.kind,
        "lower_s": round(lower_s, 1), "compile_s": round(compile_s, 1),
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "blast_block": (cfg.blast.b_in, cfg.blast.b_out),
        "model_flops": model_flops,
    }
    return lowered, compiled, meta


def _serve_params(cfg):
    """bf16 serving weights (pruned dense layout) — abstract."""
    abs_p = registry.abstract_params(cfg)
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
        abs_p)


def run_cell(arch, shape_name, multi_pod, out_dir, verbose=True):
    lowered, compiled, meta = lower_cell(arch, shape_name, multi_pod)
    report = analysis.analyze_compiled(compiled, meta["chips"],
                                       meta["model_flops"])
    result = {**meta, **report}
    if verbose:
        ma = compiled.memory_analysis()
        print(f"[{arch} x {shape_name} x {meta['mesh']}] "
              f"compile={meta['compile_s']}s")
        print("  memory_analysis:", ma)
        r = report["roofline"]
        print(f"  roofline: compute={r['compute_s']:.4f}s "
              f"memory={r['memory_s']:.4f}s "
              f"collective={r['collective_s']:.4f}s "
              f"dominant={r['dominant']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{meta['mesh'].replace('x', '-')}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(result, f, indent=1, default=str)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--list-cells", action="store_true",
                    help="print 'arch shape mesh' rows and exit (used by "
                         "the per-cell-subprocess sweep driver)")
    args = ap.parse_args()

    if args.list_cells:
        for arch, shape in cells():
            print(arch, shape, "single")
            print(arch, shape, "multi")
        return

    todo = []
    if args.all:
        for arch, shape in cells():
            todo.append((arch, shape, False))
            todo.append((arch, shape, True))
    else:
        meshes = [args.multipod] if not args.both_meshes else [False, True]
        for mp in meshes:
            todo.append((args.arch, args.shape, mp))

    failures = []
    for arch, shape, mp in todo:
        tag = f"{arch}_{shape}_{'2-16-16' if mp else '16-16'}"
        if args.skip_existing and os.path.exists(
                os.path.join(args.out, tag + ".json")):
            continue
        try:
            run_cell(arch, shape, mp, args.out)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures.append(tag)
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
            traceback.print_exc()
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run OK")


if __name__ == "__main__":
    main()
