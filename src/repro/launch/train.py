"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
        --smoke --steps 100 --batch 8 --seq 128 [--ckpt-dir ckpts/]

``--smoke`` selects the reduced config (CPU-runnable). On a real TPU
fleet the same entry point runs the full config on the production mesh
(--mesh single|multi selects it; jax.distributed.initialize is called
when JAX_COORDINATOR is set).
"""
from __future__ import annotations

import argparse
import dataclasses
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--s-max", type=float, default=None)
    ap.add_argument("--step-size", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--no-guard", action="store_true",
                    help="disable the anomaly guard (device-side skip "
                         "+ host-side spike/rewind policy)")
    ap.add_argument("--data", default=None, help="memmap token file")
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--devices", type=int, default=0,
                    help="force host platform device count")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    if os.environ.get("JAX_COORDINATOR"):
        import jax
        jax.distributed.initialize()   # multi-host fleet entry

    import jax
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import make_source
    from repro.distributed.context import DistContext
    from repro.launch.mesh import make_production_mesh
    from repro.optim import adamw
    from repro.training import train_loop

    cfg = get_config(args.arch, smoke=args.smoke)
    overrides = {}
    if args.s_max is not None:
        overrides["s_max"] = args.s_max
    if args.step_size is not None:
        overrides["step_size"] = args.step_size
    if overrides or cfg.blast.enabled:
        cfg = dataclasses.replace(cfg, blast=dataclasses.replace(
            cfg.blast, total_steps=args.steps, **overrides))

    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    dist = DistContext(mesh=mesh) if mesh else None

    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    source = make_source(cfg, shape, path=args.data)
    opt = adamw.AdamWConfig(peak_lr=args.lr, total_steps=args.steps,
                            warmup_steps=max(args.steps // 20, 5))
    loop = train_loop.TrainLoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=max(args.steps // 5, 10),
        guard=None if args.no_guard else train_loop.GuardConfig())
    state, history = train_loop.train(cfg, opt, source, loop, dist=dist)
    print(f"done: final loss {history[-1]['loss']:.4f}, "
          f"sparsity {history[-1]['sparsity']:.3f}")


if __name__ == "__main__":
    main()
