"""Production mesh builders (task spec, MULTI-POD DRY-RUN §1).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a (data, model) mesh — used by tests
    with xla_force_host_platform_device_count set small."""
    n = len(jax.devices())
    shape = (max(n // 2, 1), 2 if n >= 2 else 1)
    return jax.make_mesh(shape, ("data", "model"))
