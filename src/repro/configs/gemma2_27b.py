"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864,
vocab=256000; local+global alternating attention, logit softcaps, GeGLU.
[arXiv:2408.00118]

Layers are scanned in (local, global) PAIRS (23 pairs) to keep the
scan body homogeneous (DESIGN.md §5)."""
from repro.configs.base import ModelConfig, reduced, with_blast

CONFIG = with_blast(ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36_864,
    vocab_size=256_000,
    mlp_kind="glu",
    mlp_act="gelu",              # GeGLU
    rope_theta=10_000.0,
    norm_kind="rmsnorm",
    attn_scale=0.0625,           # query_pre_attn_scalar=256
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sliding_window=4096,
    layer_pattern="local_global",
    tie_embeddings=True,
    scale_embeddings=True,
))

SMOKE = reduced(CONFIG)
SKIP_SHAPES = {"long_500k": "alternating GLOBAL layers still need the full "
                            "512k KV cache -> effectively full attention "
                            "(DESIGN.md §6)"}
