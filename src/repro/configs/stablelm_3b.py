"""stablelm-3b [dense] — 32L d_model=2560 32H (MHA kv=32) d_ff=6912,
vocab=50304. [hf:stabilityai/stablelm-*]

d_ff/TP = 432 forces b_out=16 at TP=16 (DESIGN.md §6); the padded-d_ff
variant re-enabling 128-wide blocks is a §Perf lever."""
from repro.configs.base import ModelConfig, reduced, with_blast

CONFIG = with_blast(ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50_304,
    mlp_kind="glu",
    mlp_act="silu",
    rope_theta=10_000.0,
    norm_kind="layernorm",
))

SMOKE = reduced(CONFIG)
SKIP_SHAPES = {"long_500k": "pure full-attention dense decoder (DESIGN.md §6)"}
