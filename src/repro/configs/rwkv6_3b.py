"""rwkv6-3b (Finch) [ssm] — 32L d_model=2560 (attention-free, 40 heads of
64) d_ff=8960 (channel-mix), vocab=65536; data-dependent decay.
[arXiv:2404.05892]

BLaST sparsifies the channel-mix matrices (the RWKV MLP analogue); the
time-mix projections stay dense (attention analogue — DESIGN.md §5).
Runs ``long_500k``: O(1) recurrent state per layer."""
from repro.configs.base import ModelConfig, reduced, with_blast

CONFIG = with_blast(ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,          # time-mix heads (head size 64)
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65_536,
    pad_heads_to=48,
    mlp_kind="mlp2",       # channel-mix: square-relu 2-matrix MLP
    mlp_act="relu",
    norm_kind="layernorm",
))

SMOKE = reduced(CONFIG)
SKIP_SHAPES: dict[str, str] = {}   # sub-quadratic: all four shapes run
