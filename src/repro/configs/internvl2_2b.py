"""internvl2-2b [vlm] — InternLM2-1.8B backbone: 24L d_model=2048 16H
(GQA kv=8) d_ff=8192, vocab=92553; InternViT frontend is a STUB
(input_specs() provides 256 precomputed patch embeddings prepended to the
text sequence, counted inside the stated seq_len). [arXiv:2404.16821]"""
from repro.configs.base import ModelConfig, reduced, with_blast

CONFIG = with_blast(ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92_560,   # 92553 padded to /16 for vocab-parallel logits
    mlp_kind="glu",
    mlp_act="silu",
    rope_theta=1_000_000.0,
    norm_kind="rmsnorm",
    num_patches=256,
))

SMOKE = reduced(CONFIG)
SKIP_SHAPES = {"long_500k": "full-attention VLM decoder (DESIGN.md §6)"}
