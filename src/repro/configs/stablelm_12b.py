"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824,
vocab=100352. [hf:stabilityai/stablelm-2-12b]"""
from repro.configs.base import ModelConfig, reduced, with_blast

CONFIG = with_blast(ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13_824,
    vocab_size=100_352,
    mlp_kind="glu",
    mlp_act="silu",
    rope_theta=10_000.0,
    norm_kind="layernorm",
    qk_norm=True,
))

SMOKE = reduced(CONFIG)
SKIP_SHAPES = {"long_500k": "pure full-attention dense decoder (DESIGN.md §6)"}
