"""qwen2-7b [dense] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944,
vocab=152064; QKV bias. [arXiv:2407.10671]"""
from repro.configs.base import ModelConfig, reduced, with_blast

CONFIG = with_blast(ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    vocab_size=152_064,
    mlp_kind="glu",
    mlp_act="silu",
    qkv_bias=True,
    pad_heads_to=32,
    rope_theta=1_000_000.0,
    norm_kind="rmsnorm",
))

SMOKE = reduced(CONFIG)
SKIP_SHAPES = {"long_500k": "pure full-attention dense decoder (DESIGN.md §6)"}
