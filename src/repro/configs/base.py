"""Config dataclasses: model architecture, input shapes, parallelism.

One ``ModelConfig`` describes any of the 10 assigned architectures (plus
the paper's own GPT-2/Llama configs). Block shapes for BLaST are derived
per-arch so blocks tile the *per-TP-shard* weight (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

from repro.core.prune_grow import BlastSpec


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- MLP flavour
    mlp_kind: Literal["glu", "mlp2"] = "glu"
    mlp_act: str = "silu"
    # --- attention details
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_logit_softcap: float = 0.0      # gemma2: 50.0
    final_logit_softcap: float = 0.0     # gemma2: 30.0
    attn_scale: float = 0.0              # 0 -> 1/sqrt(head_dim)
    # pad q (and MHA kv) heads with zero-init heads so the head dim is
    # TP-shardable (exact: padded wo rows are zero). DESIGN.md §5.
    pad_heads_to: int = 0
    sliding_window: int = 0              # 0 = full attention
    layer_pattern: Literal["uniform", "local_global"] = "uniform"
    norm_kind: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    tie_embeddings: bool = False
    scale_embeddings: bool = False       # gemma2: x *= sqrt(d_model)
    # --- MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    # --- SSM / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    attn_every: int = 0                  # zamba2: shared block period
    conv_kernel: int = 4
    # --- encoder-decoder (whisper)
    num_encoder_layers: int = 0
    # --- VLM
    num_patches: int = 0
    # --- BLaST
    blast: BlastSpec = dataclasses.field(
        default_factory=lambda: BlastSpec(enabled=False))
    # --- numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # --- misc
    remat: bool = True
    remat_policy: str = "nothing_saveable"
    chunk_size: int = 64                 # linear-attention chunk length
    max_position: int = 1 << 20

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def n_params(self) -> int:
        """Total parameter count (approximate, matches param tree)."""
        from repro.models import registry
        return registry.count_params(self)

    def n_active_params(self) -> int:
        """Params touched per token (MoE: only routed top_k experts)."""
        from repro.models import registry
        return registry.count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How the model maps onto the device mesh."""
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    pod_axis: str | None = None          # extra DP axis on multi-pod mesh
    tp: int = 16                         # size of the model axis
    # activation sharding of the sequence dim (SP) — hillclimb lever
    shard_seq: bool = False
    remat_policy: str = "dots_with_no_batch_dims_saveable"

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return (self.pod_axis,) + self.data_axes if self.pod_axis \
            else self.data_axes


def derive_block_shape(d_in: int, d_out: int, tp: int,
                       shard_out: bool = True) -> tuple[int, int]:
    """Largest (b_in, b_out) in {128,64,32,16,8} tiling the per-shard
    weight (DESIGN.md §6). ``shard_out``: the out dim is TP-sharded
    (W1/W2); otherwise the in dim is (W3). We use ONE block shape per
    model, so take the constraint over the sharded d_ff and the
    replicated d_model."""
    def largest(dim: int) -> int:
        for b in (128, 64, 32, 16, 8):
            if dim % b == 0:
                return b
        raise ValueError(f"dim {dim} not tileable")
    local_out = d_out // tp if shard_out else d_out
    return largest(d_in), largest(local_out)


def with_blast(cfg: ModelConfig, tp: int = 16, **overrides) -> ModelConfig:
    """Attach a BlastSpec with per-arch derived block shape.

    For MoE archs the experts are EP-sharded (not intra-expert), so the
    expert d_ff is NOT divided by tp when deriving the block shape."""
    ff = cfg.moe_d_ff if cfg.is_moe else cfg.d_ff
    shard_out = not cfg.is_moe
    b_in, b_out = derive_block_shape(cfg.d_model, ff, tp,
                                     shard_out=shard_out)
    spec = dataclasses.replace(
        BlastSpec(enabled=True, b_in=b_in, b_out=b_out), **overrides)
    return dataclasses.replace(cfg, blast=spec)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant: same family/topology, tiny dims."""
    kv = max(1, min(cfg.num_kv_heads, 2))
    heads = max(kv, 4)
    small = dict(
        num_layers=min(cfg.num_layers, 4) if cfg.attn_every == 0
        else max(cfg.attn_every, 4),
        d_model=64, num_heads=heads, num_kv_heads=kv, head_dim=16,
        d_ff=128, vocab_size=256,
        num_experts=min(cfg.num_experts, 8),
        top_k=min(cfg.top_k, 2),
        moe_d_ff=64 if cfg.is_moe else 0,
        num_shared_experts=min(cfg.num_shared_experts, 1),
        ssm_state=min(cfg.ssm_state, 16),
        ssm_heads=min(cfg.ssm_heads, 4) if cfg.ssm_heads else 0,
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        num_patches=min(cfg.num_patches, 8),
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window
        else 0,
        chunk_size=16,
        remat=False,
        compute_dtype="float32",
        name=cfg.name + "-smoke",
    )
    if cfg.blast.enabled:
        small["blast"] = dataclasses.replace(
            cfg.blast, b_in=16, b_out=16, total_steps=20, step_size=5,
            dense_last=1)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
