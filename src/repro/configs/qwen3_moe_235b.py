"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4)
moe_d_ff=1536, vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-*]

Every layer is MoE; per-expert BLaST block masks (paper §2.2 treats MoE
as a functional equivariant of the MLP). Experts are EP-sharded, so the
block shape is derived against the *unsharded* expert d_ff -> (128,128).
"""
from repro.configs.base import ModelConfig, reduced, with_blast

CONFIG = with_blast(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151_936,
    mlp_kind="glu",
    mlp_act="silu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    norm_kind="rmsnorm",
    num_experts=128,
    top_k=8,
    moe_d_ff=1536,
))

SMOKE = reduced(CONFIG)
SKIP_SHAPES = {"long_500k": "pure full-attention MoE decoder; 512k KV "
                            "cache is the quadratic regime (DESIGN.md §6)"}
