"""zamba2-1.2b [hybrid] — 38 Mamba2 layers d_model=2048 + ONE shared
transformer block (32H attn + d_ff=8192 MLP) applied every 6 layers,
ssm_state=64, vocab=32000. [arXiv:2411.15242]

BLaST sparsifies the shared block's MLP; Mamba2 in/out projections are
state-mixer (attention-analogue) weights and stay dense (DESIGN.md §5).
Per-invocation LoRA on the shared block is omitted (noted deviation).
Runs ``long_500k``: O(1) SSM state; the shared attn block keeps a KV
cache (6x fewer cached layers than a dense transformer)."""
from repro.configs.base import ModelConfig, reduced, with_blast

CONFIG = with_blast(ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32_000,
    mlp_kind="glu",
    mlp_act="gelu",
    norm_kind="rmsnorm",
    ssm_state=64,
    ssm_heads=64,             # d_inner 4096 / head 64
    ssm_expand=2,
    attn_every=6,
))

SMOKE = reduced(CONFIG)
SKIP_SHAPES: dict[str, str] = {}   # hybrid: all four shapes run
