"""Config registry: ``--arch <id>`` resolution for every assigned
architecture (+ the paper's own models)."""
from __future__ import annotations

import importlib

from repro.configs.base import (SHAPES, ModelConfig, ParallelConfig,
                                ShapeConfig, reduced, with_blast)

_ARCH_MODULES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "stablelm-3b": "stablelm_3b",
    "gemma2-27b": "gemma2_27b",
    "stablelm-12b": "stablelm_12b",
    "qwen2-7b": "qwen2_7b",
    "rwkv6-3b": "rwkv6_3b",
    "whisper-large-v3": "whisper_large_v3",
    "zamba2-1.2b": "zamba2_1b",
    "internvl2-2b": "internvl2_2b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = _module(arch)
    return mod.SMOKE if smoke else mod.CONFIG


def skip_shapes(arch: str) -> dict[str, str]:
    return dict(getattr(_module(arch), "SKIP_SHAPES", {}))


def cells(include_skipped: bool = False):
    """All runnable (arch, shape) dry-run cells."""
    out = []
    for arch in ARCH_IDS:
        skips = skip_shapes(arch)
        for shape in SHAPES:
            if shape in skips and not include_skipped:
                continue
            out.append((arch, shape))
    return out


__all__ = ["ARCH_IDS", "SHAPES", "ModelConfig", "ParallelConfig",
           "ShapeConfig", "cells", "get_config", "reduced", "skip_shapes",
           "with_blast"]
