"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (MHA kv=16) d_ff=1408,
vocab=102400, 64 routed experts top-6 + 2 shared (fine-grained).
[arXiv:2401.06066]

Shared experts are replicated (small) and BLaST-sparsified like routed
ones. The real model's dense layer 0 is simplified to MoE-everywhere
(noted deviation)."""
from repro.configs.base import ModelConfig, reduced, with_blast

CONFIG = with_blast(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102_400,
    mlp_kind="glu",
    mlp_act="silu",
    rope_theta=10_000.0,
    norm_kind="rmsnorm",
    num_experts=64,
    top_k=6,
    moe_d_ff=1408,
    num_shared_experts=2,
))

SMOKE = reduced(CONFIG)
SKIP_SHAPES = {"long_500k": "pure full-attention MoE decoder (DESIGN.md §6)"}
