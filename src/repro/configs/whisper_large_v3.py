"""whisper-large-v3 [audio] — enc-dec, 32 encoder + 32 decoder layers,
d_model=1280 20H (MHA) d_ff=5120, vocab=51866; conv frontend is a STUB
(input_specs() provides precomputed frame embeddings). [arXiv:2212.04356]

Shape mapping (DESIGN.md §6): train/prefill split seq 50/50 between
encoder frames and decoder tokens; decode = 1 new decoder token vs 16k
encoder memory + 16k decoder self-cache."""
from repro.configs.base import ModelConfig, reduced, with_blast

CONFIG = with_blast(ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,             # decoder layers
    num_encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51_872,   # 51866 padded to /16 for vocab-parallel logits
    pad_heads_to=32,
    rope_theta=0.0,            # learned absolute positions, no rope
    mlp_kind="mlp2",
    mlp_act="gelu",
    norm_kind="layernorm",
))

SMOKE = reduced(CONFIG)
SKIP_SHAPES = {"long_500k": "enc-dec; decoder context << 512k by "
                            "construction (DESIGN.md §6)"}
