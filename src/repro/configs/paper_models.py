"""The paper's own evaluation models (§4): GPT-2 family + Llama-3.2 1B.

Used by the benchmark harness to reproduce Tables 2/4/5/6 and Figures
8-11 at reduced scale on CPU; the full configs are exact."""
from repro.configs.base import ModelConfig, reduced, with_blast


def _gpt2(name, layers, d_model, heads):
    return ModelConfig(
        name=name, family="dense", num_layers=layers, d_model=d_model,
        num_heads=heads, num_kv_heads=heads, head_dim=d_model // heads,
        d_ff=4 * d_model, vocab_size=50_257, mlp_kind="mlp2",
        mlp_act="gelu", norm_kind="layernorm", tie_embeddings=True)


GPT2_SMALL = with_blast(_gpt2("gpt2-small", 12, 768, 12))
GPT2_MEDIUM = with_blast(_gpt2("gpt2-medium", 24, 1024, 16))
GPT2_LARGE = with_blast(_gpt2("gpt2-large", 36, 1280, 20))
GPT2_XL = with_blast(_gpt2("gpt2-xl", 48, 1600, 25))

LLAMA32_1B = with_blast(ModelConfig(
    name="llama3.2-1b", family="dense", num_layers=16, d_model=2048,
    num_heads=32, num_kv_heads=8, head_dim=64, d_ff=8192,
    vocab_size=128_256, mlp_kind="glu", mlp_act="silu",
    rope_theta=500_000.0, norm_kind="rmsnorm", tie_embeddings=True))

# Llama 3.1 405B MLP dims — used by the Fig. 5 MLP-speedup benchmark.
LLAMA_FAMILY_MLP = {
    "llama3.2-1b": (2048, 8192),
    "llama3.2-3b": (3072, 8192),
    "llama3.1-8b": (4096, 14336),
    "llama3.1-70b": (8192, 28672),
    "llama3.1-405b": (16384, 53248),
}

GPT2_SMALL_SMOKE = reduced(GPT2_SMALL)
LLAMA32_1B_SMOKE = reduced(LLAMA32_1B)
