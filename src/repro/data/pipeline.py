"""Deterministic, shardable token data pipeline.

Two sources:
  * ``SyntheticLM`` — a fixed-seed Zipfian Markov stream (no external
    datasets in the container; gives a LEARNABLE distribution so loss
    curves in tests/benchmarks are meaningful, standing in for
    OpenWebText in the paper's Tables 2/4/5/6);
  * ``MemmapTokens`` — production path: a flat uint16/uint32 token file,
    random-access windows, deterministic shuffling by (seed, step).

Both are stateless-resumable: batch(step) is a pure function of
(seed, step), so checkpoint/restart replays exactly (fault tolerance —
the iterator state IS the step counter). Per-host sharding slices the
global batch by data-parallel rank.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    order: int = 3          # Markov order of the synthetic language

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # Zipfian unigram + deterministic successor tables: each context
        # hash maps to a small candidate set -> learnable structure.
        self._probs = 1.0 / (np.arange(1, v + 1) ** 1.1)
        self._probs /= self._probs.sum()
        # Zipf-biased successor candidates: the marginal stays Zipfian
        # (fast unigram learning signal) on top of the Markov structure
        self._succ = rng.choice(v, size=(8192, 4),
                                p=self._probs).astype(np.int64)

    def batch(self, step: int, rank: int = 0, world: int = 1) -> dict:
        b = self.global_batch // world
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4096 + rank)
        toks = np.empty((b, self.seq_len + 1), np.int64)
        toks[:, 0] = rng.choice(self.vocab_size, size=b, p=self._probs)
        h = toks[:, 0].copy()
        for t in range(1, self.seq_len + 1):
            cand = self._succ[h % 8192]                    # (b, 4)
            pick = rng.integers(0, 4, size=b)
            nxt = cand[np.arange(b), pick]
            # 10% noise resample from unigram for entropy
            noise = rng.random(b) < 0.1
            nxt[noise] = rng.choice(self.vocab_size, size=int(noise.sum()),
                                    p=self._probs)
            toks[:, t] = nxt
            h = h * 31 + nxt
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


@dataclasses.dataclass
class MemmapTokens:
    path: str
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    dtype: str = "uint16"

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._n = len(self._data) - self.seq_len - 1
        assert self._n > 0, "token file shorter than one sequence"

    def batch(self, step: int, rank: int = 0, world: int = 1) -> dict:
        b = self.global_batch // world
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4096 + rank)
        starts = rng.integers(0, self._n, size=b)
        toks = np.stack([np.asarray(
            self._data[s:s + self.seq_len + 1], dtype=np.int64)
            for s in starts])
        toks = np.clip(toks, 0, self.vocab_size - 1)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def make_source(cfg, shape, path: str | None = None, seed: int = 0):
    if path:
        return MemmapTokens(path, cfg.vocab_size, shape.seq_len,
                            shape.global_batch, seed)
    return SyntheticLM(cfg.vocab_size, shape.seq_len, shape.global_batch,
                       seed)
