"""Typed metrics registry with a backward-compatible dict view.

The serving engine and the train loop used to keep hand-edited stats
dicts whose ``reset_stats`` re-listed every key by hand — a recurring
drift bug (a new counter added in one place but not the other survives
reset with a stale value, or KeyErrors on first read). Here every
metric is REGISTERED once with a kind, and reset / snapshot / Prometheus
exposition all derive from the registry — there is nothing to keep in
sync.

``registry.view()`` returns a ``MutableMapping`` facade over the scalar
metrics so existing call sites keep working unchanged:

  stats["decode_tokens"] += k        # counter inc
  stats["queue_depth_peak"] = max(stats["queue_depth_peak"], d)
  dict(stats), stats.update(other), "x" in stats, len(stats)

Unknown keys assigned through the view auto-register (int -> counter,
float -> gauge), so derived stats computed at finalize time are swept
into the same reset/snapshot path as everything else.
"""
from __future__ import annotations

from collections.abc import MutableMapping


class Counter:
    """Monotonic-by-convention scalar. ``set`` is allowed (finalize
    passes overwrite derived values); the kind is exposition metadata
    and reset semantics, not an enforcement."""
    kind = "counter"
    __slots__ = ("name", "help", "value", "_zero")

    def __init__(self, name: str, help: str = "", value=0):
        self.name = name
        self.help = help
        self.value = value
        self._zero = value

    def inc(self, delta=1):
        self.value += delta

    def set(self, value):
        self.value = value

    def reset(self):
        self.value = self._zero

    def get(self):
        return self.value


class Gauge(Counter):
    """Point-in-time scalar (peaks, rates, derived stats)."""
    kind = "gauge"
    __slots__ = ()


class Histogram:
    """Sample-keeping distribution (latency lists). The raw samples
    stay host-side Python floats — percentile folding happens at
    finalize, never on the hot path."""
    kind = "histogram"
    __slots__ = ("name", "help", "samples")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.samples: list[float] = []

    def observe(self, v: float):
        self.samples.append(v)

    def reset(self):
        # in place: the engine exposes the list itself (``eng._ttft``)
        # and callers may hold a reference across a reset
        self.samples.clear()

    def percentile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        s = sorted(self.samples)
        if len(s) == 1:
            return float(s[0])
        # linear interpolation, matching numpy's default
        pos = (q / 100.0) * (len(s) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(s) - 1)
        return float(s[lo] + (s[hi] - s[lo]) * (pos - lo))

    def get(self):
        return {"count": len(self.samples),
                "sum": float(sum(self.samples)),
                "p50": self.percentile(50),
                "p95": self.percentile(95)}


class MetricsRegistry:
    """Get-or-create registry; reset/snapshot/exposition walk it."""

    def __init__(self, namespace: str = "blast"):
        self.namespace = namespace
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    # ------------------------------------------------- registration
    def _get_or_create(self, cls, name: str, help: str):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help)
            self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get_or_create(Histogram, name, help)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str):
        return self._metrics[name]

    def names(self) -> list[str]:
        return list(self._metrics)

    # ------------------------------------------------------ lifecycle
    def reset(self) -> None:
        """Reset EVERY registered metric — derived, auto-registered,
        and declared alike. The anti-drift property: there is no list
        of names to forget to update."""
        for m in self._metrics.values():
            m.reset()

    def snapshot(self) -> dict:
        """JSON-able {name: value}; histograms fold to summary dicts."""
        return {name: m.get() for name, m in self._metrics.items()}

    def view(self) -> "StatsView":
        return StatsView(self)

    # ----------------------------------------------------- exposition
    def prometheus_text(self) -> str:
        """Prometheus text exposition format (0.0.4). Histograms render
        as summaries (quantile labels + _sum/_count)."""
        out = []
        ns = self.namespace
        for name, m in sorted(self._metrics.items()):
            full = f"{ns}_{name}" if ns else name
            if m.help:
                out.append(f"# HELP {full} {m.help}")
            if isinstance(m, Histogram):
                out.append(f"# TYPE {full} summary")
                out.append(f'{full}{{quantile="0.5"}} '
                           f"{m.percentile(50)}")
                out.append(f'{full}{{quantile="0.95"}} '
                           f"{m.percentile(95)}")
                out.append(f"{full}_sum {float(sum(m.samples))}")
                out.append(f"{full}_count {len(m.samples)}")
            else:
                out.append(f"# TYPE {full} {m.kind}")
                out.append(f"{full} {m.value}")
        return "\n".join(out) + "\n"


def parse_prometheus_text(text: str) -> dict:
    """Strict-enough parser for the exposition format above (used by
    the CI obs-smoke job to prove the output is well formed): returns
    {metric_name: value} for plain samples and
    {metric_name: {labels_str: value}} for labeled ones. Raises
    ``ValueError`` on a malformed line."""
    out: dict = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                raise ValueError(f"line {lineno}: bad comment {line!r}")
            continue
        try:
            name_part, value_part = line.rsplit(None, 1)
            value = float(value_part)
        except ValueError:
            raise ValueError(f"line {lineno}: bad sample {line!r}")
        if "{" in name_part:
            name, rest = name_part.split("{", 1)
            if not rest.endswith("}"):
                raise ValueError(f"line {lineno}: bad labels {line!r}")
            out.setdefault(name, {})[rest[:-1]] = value
        else:
            out[name_part] = value
    return out


class StatsView(MutableMapping):
    """Dict facade over a registry's SCALAR metrics (histograms are
    reached through the registry itself). Assigning an unknown key
    auto-registers it — int values as counters, floats as gauges — so
    ad-hoc derived stats participate in reset/snapshot/exposition."""

    __slots__ = ("_reg",)

    def __init__(self, registry: MetricsRegistry):
        self._reg = registry

    def _scalars(self):
        return {k: m for k, m in self._reg._metrics.items()
                if not isinstance(m, Histogram)}

    def __getitem__(self, key):
        m = self._reg._metrics[key]
        if isinstance(m, Histogram):
            raise KeyError(f"{key} is a histogram; use the registry")
        return m.value

    def __setitem__(self, key, value):
        m = self._reg._metrics.get(key)
        if m is None:
            cls = Gauge if isinstance(value, float) else Counter
            m = self._reg._get_or_create(cls, key, "")
        m.set(value)

    def __delitem__(self, key):
        del self._reg._metrics[key]

    def __iter__(self):
        return iter(self._scalars())

    def __len__(self):
        return len(self._scalars())

    def __contains__(self, key):
        return key in self._scalars()

    def __repr__(self):
        return repr({k: m.value for k, m in self._scalars().items()})
