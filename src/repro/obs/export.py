"""Chrome trace-event JSON export of span timelines.

Open the output in https://ui.perfetto.dev or chrome://tracing. Spans
render as complete ("X") events with microsecond timestamps; point
events (``t1 == t0``) render as instants ("i"). Rows (tids) group by
the request uid when a span carries one, so each request reads as its
own timeline lane; engine-wide spans (slabs, mixed steps, train steps)
land on row 0.
"""
from __future__ import annotations

import json
from typing import Iterable


def _tid(attrs: dict) -> int:
    uid = attrs.get("uid")
    if uid is None:
        return 0
    try:
        return int(uid) + 1          # row 0 is the engine-wide lane
    except (TypeError, ValueError):
        return 1 + (hash(uid) % 997)


def _args(attrs: dict) -> dict:
    # JSON-safe shallow copy: numpy scalars / exotic values stringify
    out = {}
    for k, v in attrs.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        elif isinstance(v, (list, tuple)):
            out[k] = [x if isinstance(x, (str, int, float, bool))
                      else str(x) for x in v]
        else:
            out[k] = str(v)
    return out


def chrome_trace_events(spans: Iterable, pid: int = 0) -> list[dict]:
    """Spans (obs.trace.Span or their ``to_dict`` form) -> trace-event
    dicts. Timestamps convert from monotonic seconds to microseconds."""
    out = []
    for s in spans:
        if isinstance(s, dict):
            name, t0, t1, attrs = (s["name"], s["t0"], s["t1"],
                                   s.get("attrs") or {})
        else:
            name, t0, t1, attrs = s.name, s.t0, s.t1, s.attrs
        ev = {"name": name, "pid": pid, "tid": _tid(attrs),
              "ts": t0 * 1e6, "args": _args(attrs)}
        if t1 > t0:
            ev["ph"] = "X"
            ev["dur"] = (t1 - t0) * 1e6
        else:
            ev["ph"] = "i"
            ev["s"] = "t"           # thread-scoped instant
        out.append(ev)
    return out


def to_chrome_trace(spans: Iterable, pid: int = 0) -> dict:
    return {"traceEvents": chrome_trace_events(spans, pid=pid),
            "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, spans: Iterable, pid: int = 0) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(spans, pid=pid), f)
    return path
