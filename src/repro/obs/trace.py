"""Request-span tracing with a bounded flight recorder.

The prime directive of this repo's serving/training hot paths is ONE
host sync per slab / step — so the tracer never creates sync points of
its own. Instrumented code hands ``span_at`` the ``t0``/``now``
monotonic timestamps it ALREADY captured around its jitted calls, and
the tracer's whole job is to remember them:

  tr.span_at("decode.slab", t0, now, lanes=4, k=8)     # completed span
  tr.event("request.finish", uid=3, tokens=17)         # point event
  with tr.span("ckpt.save", step=40):                  # host-only phase
      ...

Completed spans/events land in a ``deque(maxlen=capacity)`` — the
flight recorder. Appends are GIL-atomic, so the engine thread, the
asyncio front end, and a watchdog thread share one tracer without a
lock (the same idiom as serving/frontend.py's token deques). When a
crash path fires (watchdog, supervisor, training rewind),
``postmortem()`` freezes the ring into a JSON dump: the last N things
that happened, with the victim request's uid threaded through its
spans, instead of nothing.

``NULL_TRACER`` is the disabled default. Its methods are no-ops that
never touch ``Span`` — tests/test_obs.py proves no span object is
allocated on the hot path when tracing is off. Instrumented sites that
would build attribute collections eagerly guard on ``tracer.enabled``.
"""
from __future__ import annotations

import json
import os
import time
from collections import deque


class Span:
    """One completed span (or point event: ``t1 == t0``). Monotonic
    timestamps, arbitrary small attrs (uids, counts, error names)."""
    __slots__ = ("name", "t0", "t1", "attrs")

    def __init__(self, name: str, t0: float, t1: float, attrs: dict):
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.attrs = attrs

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        return {"name": self.name, "t0": self.t0, "t1": self.t1,
                "attrs": self.attrs}

    def __repr__(self):
        return (f"Span({self.name!r}, t0={self.t0:.6f}, "
                f"dur={self.dur:.6f}, {self.attrs})")


class _SpanCtx:
    """Context manager for host-only phases (checkpoint writes,
    supervisor recovery) where the span IS allowed to read the clock —
    these run between device calls, never inside the hot loop."""
    __slots__ = ("_tr", "_name", "_attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tr = tracer
        self._name = name
        self._attrs = attrs

    def __enter__(self):
        self._t0 = self._tr.clock()
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self._attrs["error"] = exc_type.__name__
        self._tr.span_at(self._name, self._t0, self._tr.clock(),
                         **self._attrs)
        return False


class Tracer:
    """Span recorder + flight recorder + postmortem dumper.

    ``capacity`` bounds the ring buffer (host memory is the only cost:
    ~one small object per slab/step/event, not per token).
    ``postmortem_dir`` (optional) is where ``postmortem()`` writes its
    JSON dumps; without it the payloads still accumulate on
    ``self.postmortems`` for programmatic access."""

    enabled = True

    def __init__(self, capacity: int = 4096,
                 postmortem_dir: str | None = None,
                 clock=time.monotonic):
        self.capacity = capacity
        self.clock = clock
        self.postmortem_dir = postmortem_dir
        self.records: deque[Span] = deque(maxlen=capacity)
        self.postmortems: list[dict] = []
        self._pm_seq = 0

    # -------------------------------------------------------- recording
    def span_at(self, name: str, t0: float, t1: float, **attrs) -> None:
        """Record a span from timestamps the caller ALREADY took at its
        existing host-sync points — the zero-extra-sync attach."""
        self.records.append(Span(name, t0, t1, attrs))

    def event(self, name: str, t: float | None = None, **attrs) -> None:
        """Point event (admission, finish, preempt, quarantine...).
        ``t`` defaults to now — events fire from host control flow,
        never between a device dispatch and its sync."""
        if t is None:
            t = self.clock()
        self.records.append(Span(name, t, t, attrs))

    def span(self, name: str, **attrs) -> _SpanCtx:
        return _SpanCtx(self, name, attrs)

    # ---------------------------------------------------- flight recorder
    def snapshot(self) -> list[dict]:
        """The ring as JSON-able dicts, oldest first. Snapshotting the
        deque (GIL-atomic copy) before iterating keeps this safe against
        concurrent appends from the engine thread."""
        return [s.to_dict() for s in list(self.records)]

    def spans_for(self, uid) -> list[dict]:
        """A request's timeline: every retained span/event whose attrs
        carry the uid (directly or in a ``uids`` list)."""
        out = []
        for s in list(self.records):
            a = s.attrs
            if a.get("uid") == uid or uid in (a.get("uids") or ()):
                out.append(s.to_dict())
        return out

    def postmortem(self, reason: str, **meta) -> dict:
        """Freeze the flight recorder into a crash dump. Writes
        ``postmortem_<seq>_<reason>.json`` under ``postmortem_dir``
        when one is set; always appends the payload to
        ``self.postmortems``. Never raises — a failing dump must not
        mask the crash being reported."""
        payload = {
            "reason": reason,
            "wall_time_unix": time.time(),
            "monotonic": self.clock(),
            "meta": meta,
            "spans": self.snapshot(),
        }
        self.postmortems.append(payload)
        if self.postmortem_dir is not None:
            try:
                os.makedirs(self.postmortem_dir, exist_ok=True)
                fname = f"postmortem_{self._pm_seq:04d}_{reason}.json"
                with open(os.path.join(self.postmortem_dir, fname),
                          "w") as f:
                    json.dump(payload, f, indent=2, default=str)
            except OSError:
                pass
        self._pm_seq += 1
        return payload

    # ------------------------------------------------------------ export
    def chrome_trace(self) -> dict:
        from repro.obs.export import to_chrome_trace
        return to_chrome_trace(list(self.records))


class _NullCtx:
    """Shared reusable no-op context manager."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_CTX = _NullCtx()


class _NullTracer:
    """Tracing disabled: every method is a no-op that never constructs
    a ``Span`` (or anything else). Hot-path sites additionally guard
    attr building on ``tracer.enabled`` so the disabled engine runs
    byte-for-byte the same work as before tracing existed."""

    enabled = False
    records = ()          # empty, iterable, immutable
    postmortems = ()

    def span_at(self, name, t0, t1, **attrs) -> None:
        pass

    def event(self, name, t=None, **attrs) -> None:
        pass

    def span(self, name, **attrs) -> _NullCtx:
        return _NULL_CTX

    def snapshot(self) -> list:
        return []

    def spans_for(self, uid) -> list:
        return []

    def postmortem(self, reason, **meta) -> None:
        return None

    def chrome_trace(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}


NULL_TRACER = _NullTracer()
