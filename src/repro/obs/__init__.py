"""Unified observability: typed metrics registry, request-span tracing
with a crash flight recorder, and Chrome-trace/Perfetto export.

Three pieces, used together or alone:

  * ``metrics.MetricsRegistry`` — typed counters / gauges / histograms
    behind a backward-compatible dict view (``registry.view()`` walks
    and mutates like the old hand-edited ``Engine.stats`` dict), with
    Prometheus text exposition and a JSON-able snapshot. Reset derives
    from the registry itself, so a newly added metric can never be
    missed by ``reset_stats`` again.
  * ``trace.Tracer`` — per-request / per-step spans recorded at
    EXISTING host-sync timestamps (``span_at``): tracing adds zero
    extra device syncs and zero graph changes, and the greedy tokens /
    TrainState bits are identical tracing on or off
    (tests/test_obs.py). The last N spans live in a bounded ring
    buffer — the flight recorder — and ``postmortem()`` dumps them to
    JSON when a watchdog / supervisor / rewind fires. ``NULL_TRACER``
    is the disabled default: a shared no-op that never allocates a
    span object on the hot path.
  * ``export`` — Chrome trace-event JSON (open in Perfetto / chrome
    about:tracing) from any span iterable.
"""
from repro.obs.metrics import MetricsRegistry, StatsView  # noqa: F401
from repro.obs.trace import NULL_TRACER, Span, Tracer     # noqa: F401
from repro.obs.export import (chrome_trace_events,        # noqa: F401
                              to_chrome_trace, write_chrome_trace)
