"""Chunked linear attention with per-channel (RWKV6) or per-head scalar
(Mamba2/SSD) decay — the sub-quadratic token mixer for the SSM/hybrid
architectures.

Recurrence (per head, state S in R^{dk x dv}):

    S_t = Diag(w_t) S_{t-1} + k_t^T v_t
    y_t = q_t S_{t-1} + (q_t ⊙ u) k_t^T v_t      (include_diag='bonus', RWKV)
    y_t = q_t S_t                                 (include_diag='full', SSD)

Chunked evaluation (chunk C): intra-chunk attention with decay weights +
inter-chunk state carry, O(S·C·d) instead of O(S²·d) — and an exact O(1)
recurrent step for decode.

Numerics: the factorized intra-chunk form q·exp(Λ_t−mid) × k·exp(mid−Λ_s)
is exact only while the centered exponents stay in f32 range. We enforce
a per-step log-decay FLOOR of ``-RANGE/chunk`` (RANGE=70), so the total
within-chunk decay is ≤ e^-70 and every centered exponent is ≤ 35 — no
clamping of individual factors (two-sided clamping silently corrupts
pairs where both sides bind; found by the exactness tests). The same
floor is applied in the recurrent decode step, so train and decode
numerics agree bit-for-bit in structure. A step decay below e^(-70/C)
retains < 1e-30 over one chunk — the floor is vacuous in practice
(DESIGN.md §8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

RANGE = 70.0


def decay_floor(chunk: int) -> float:
    return -RANGE / max(chunk, 1)


def _chunk(x, c):
    b, s = x.shape[:2]
    return x.reshape(b, s // c, c, *x.shape[2:])


def chunked_linear_attention(q, k, v, log_w, *, u=None, chunk=64,
                             initial_state=None, include_diag="full"):
    """q,k: (B,S,H,dk); v: (B,S,H,dv); log_w: (B,S,H,dk) (<=0; per-head
    scalar decays broadcast to dk); u: (H,dk) bonus or None.

    Returns (y (B,S,H,dv), final_state (B,H,dk,dv))."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    log_w = jnp.maximum(log_w.astype(jnp.float32), decay_floor(c))
    qc, kc, vc, wc = (_chunk(t, c) for t in (q, k, v, log_w))
    lam = jnp.cumsum(wc, axis=2)                          # (B,N,C,H,dk)

    if initial_state is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)

    tri = jnp.tril(jnp.ones((c, c), bool),
                   0 if include_diag == "full" else -1)

    def step(state, inp):
        q_c, k_c, v_c, lam_c, w_c = inp                   # (B,C,H,*)
        q_c = q_c.astype(jnp.float32)
        k_c = k_c.astype(jnp.float32)
        v_c = v_c.astype(jnp.float32)
        # decay of q_t relative to chunk start:
        #   'bonus' (RWKV): y_t reads S_{t-1}  -> Λ_{t-1} = Λ_t − w_t
        #   'full'  (SSD):  y_t reads S_t      -> Λ_t
        lam_q = lam_c - (w_c if include_diag == "bonus" else 0.0)
        # inter-chunk: y += (q ⊙ exp(Λ_q)) @ S0      (Λ_q ≤ 0: safe)
        q_in = q_c * jnp.exp(lam_q)
        y = jnp.einsum("bchk,bhkv->bchv", q_in, state)
        # intra-chunk, mid-centered: |Λ − mid| ≤ RANGE/2 by the decay
        # floor, so exp never overflows and no per-factor clamp exists.
        mid = lam_c[:, c // 2, None]
        qf = q_c * jnp.exp(lam_q - mid)
        kf = k_c * jnp.exp(mid - lam_c)
        a = jnp.einsum("bthk,bshk->bhts", qf, kf)
        a = jnp.where(tri[None, None], a, 0.0)
        y = y + jnp.einsum("bhts,bshv->bthv", a, v_c)
        if u is not None:  # RWKV bonus: current token via u, not decay
            diag = jnp.einsum("bthk,hk,bthk->bth", q_c,
                              u.astype(jnp.float32), k_c)
            y = y + diag[..., None] * v_c
        # state carry: S1 = Diag(exp(Λ_C)) S0 + Σ_s Diag(exp(Λ_C−Λ_s)) kᵀv
        lam_end = lam_c[:, -1]
        k_out = k_c * jnp.exp(lam_end[:, None] - lam_c)
        s1 = (jnp.exp(lam_end)[..., None] * state
              + jnp.einsum("bshk,bshv->bhkv", k_out, v_c))
        return s1, y

    xs = tuple(t.swapaxes(0, 1) for t in (qc, kc, vc, lam, wc))
    final, ys = jax.lax.scan(step, s0, xs)
    y = ys.swapaxes(0, 1).reshape(b, s, h, dv)
    return y.astype(q.dtype), final


def chunked_ssd(q, k, v, log_a, *, chunk=64, initial_state=None):
    """Grouped SSD (Mamba2, n_groups=1): q,k are SHARED across heads and
    the decay is a per-head SCALAR — so nothing of shape (B,S,H,d_state)
    is ever materialised (the broadcast in the generic path was the #1
    byte contributor of the zamba2 roofline — EXPERIMENTS.md §Perf).

    q,k: (B,S,ds); v: (B,S,H,hd); log_a: (B,S,H) (<=0).
    Returns (y (B,S,H,hd), state (B,H,ds,hd)).

    Per-head exponents are applied as full (C,C) decay matrices with
    exponent Λ_t−Λ_s ≤ 0 — no factorization, no overflow, no clamping."""
    b, s, ds = q.shape
    h, hd = v.shape[2], v.shape[3]
    c = min(chunk, s)
    assert s % c == 0
    # NO decay floor here: the decay matrices are computed directly with
    # exponents Λ_t−Λ_s ≤ 0, so nothing can overflow (unlike the
    # factorized per-channel path above).
    qc, kc, vc, ac = (_chunk(t, c) for t in (q, k, v, log_a.astype(jnp.float32)))
    lam = jnp.cumsum(ac, axis=2)                      # (B,N,C,H)
    if initial_state is None:
        s0 = jnp.zeros((b, h, ds, hd), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)
    tri = jnp.tril(jnp.ones((c, c), bool))

    def step(state, inp):
        q_c, k_c, v_c, lam_c = inp                    # (B,C,*)
        q_c = q_c.astype(jnp.float32)
        k_c = k_c.astype(jnp.float32)
        v_c = v_c.astype(jnp.float32)
        # inter-chunk: y[b,t,h,v] = exp(Λ_t^h) Σ_d q_td S0[h,d,v]
        y = jnp.einsum("btd,bhdv->bthv", q_c, state) \
            * jnp.exp(lam_c)[..., None]
        # intra-chunk: A0 shared across heads, per-head decay matrix
        a0 = jnp.einsum("btd,bsd->bts", q_c, k_c)      # (B,C,C)
        dec = jnp.exp(lam_c[:, :, None] - lam_c[:, None, :, :])
        a = a0[:, :, :, None] * jnp.where(tri[None, :, :, None], dec, 0.)
        y = y + jnp.einsum("btsh,bshv->bthv", a, v_c)
        # state: S1 = exp(Λ_C) S0 + Σ_s k_s ⊗ (v_s exp(Λ_C−Λ_s))
        lam_end = lam_c[:, -1]                        # (B,H)
        vdec = v_c * jnp.exp(lam_end[:, None] - lam_c)[..., None]
        s1 = (jnp.exp(lam_end)[..., None, None] * state
              + jnp.einsum("bsd,bshv->bhdv", k_c, vdec))
        return s1, y

    xs = tuple(t.swapaxes(0, 1) for t in (qc, kc, vc, lam))
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1).reshape(b, s, h, hd).astype(v.dtype), final


def ssd_recurrent_step(q, k, v, log_a, state, *, chunk=64):
    """Grouped-SSD decode step. q,k: (B,ds); v: (B,H,hd);
    log_a: (B,H); state: (B,H,ds,hd)."""
    a = jnp.exp(log_a.astype(jnp.float32))
    kv = k.astype(jnp.float32)[:, None, :, None] \
        * v.astype(jnp.float32)[:, :, None, :]         # (B,H,ds,hd)
    new_state = a[..., None, None] * state + kv
    y = jnp.einsum("bd,bhdv->bhv", q.astype(jnp.float32), new_state)
    return y.astype(v.dtype), new_state


def recurrent_step(q, k, v, log_w, state, *, u=None, chunk=64,
                   include_diag="full"):
    """Exact single-token recurrence for decode (same decay floor as the
    chunked path, keyed by the training ``chunk``).

    q,k: (B,H,dk); v: (B,H,dv); log_w: (B,H,dk); state: (B,H,dk,dv).
    Returns (y (B,H,dv), new_state)."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    w = jnp.exp(jnp.maximum(log_w.astype(jnp.float32),
                            decay_floor(min(chunk, 1 << 30))))
    kv = kf[..., :, None] * vf[..., None, :]              # (B,H,dk,dv)
    if include_diag == "bonus":
        y = jnp.einsum("bhk,bhkv->bhv", qf, state)
        y = y + jnp.einsum("bhk,hk,bhkv->bhv", qf,
                           u.astype(jnp.float32), kv)
        new_state = w[..., None] * state + kv
    else:
        new_state = w[..., None] * state + kv
        y = jnp.einsum("bhk,bhkv->bhv", qf, new_state)
    return y.astype(q.dtype), new_state
