"""Parameter declaration system: one source of truth per model for
(shape, dtype, init, logical sharding axes).

From a ``ParamSpec`` tree we derive, without duplication:
  * real initialization (``init_params``),
  * allocation-free abstract params for the dry-run (``abstract_params``),
  * ``PartitionSpec`` trees via the logical-axis rules in
    ``distributed/sharding.py``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

Initializer = Callable[[jax.Array, tuple, jnp.dtype], jax.Array]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names per dim
    init: str = "normal"                  # normal|zeros|ones|embed
    scale: float = 1.0                    # fan-in scaling multiplier
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(rng: jax.Array, spec: ParamSpec) -> jax.Array:
    dt = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "embed":
        return (jax.random.normal(rng, spec.shape, jnp.float32)
                * 0.02 * spec.scale).astype(dt)
    # fan-in scaled normal (last-but-one dim is fan-in for matrices)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(rng, spec.shape, jnp.float32) * std
            ).astype(dt)


def init_params(specs: dict, rng: jax.Array) -> dict:
    """Materialize a (nested) ParamSpec tree into arrays."""
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    rngs = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(r, s) for r, s in zip(rngs, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(specs: dict) -> dict:
    """ShapeDtypeStruct tree — no allocation (dry-run path)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def axes_tree(specs: dict) -> dict:
    """Logical-axes tree parallel to the params tree."""
    return jax.tree_util.tree_map(
        lambda s: s.axes, specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))
