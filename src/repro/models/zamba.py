"""zamba2 [hybrid]: a stack of Mamba2 (SSD) layers with ONE weight-shared
transformer block (attention + GLU MLP) applied every ``attn_every``
layers (arXiv:2411.15242; per-invocation LoRA omitted — DESIGN.md §8).

Mamba2 layer: in_proj -> [z | x | B | C | dt]; causal depthwise conv on
(x,B,C); scalar-per-head decay a_t = exp(-softplus(dt + bias)·exp(A_log));
SSD evaluated with the shared chunked linear scan ('full' diagonal mode);
gated RMSNorm; out_proj. BLaST applies to the shared block's MLP only
(the Mamba mixers are attention-analogue weights).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import sparse_mlp as sm
from repro.models import attention as attn_mod
from repro.models.layers import norm, rmsnorm
from repro.models.linear_scan import (chunked_linear_attention,
                                      chunked_ssd, recurrent_step,
                                      ssd_recurrent_step)
from repro.models.params import ParamSpec


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = cfg.ssm_heads
    headdim = d_inner // nheads
    return d_inner, nheads, headdim, cfg.ssm_state


def mamba_param_specs(cfg) -> dict:
    d = cfg.d_model
    d_inner, nheads, headdim, state = _dims(cfg)
    conv_dim = d_inner + 2 * state
    proj_out = 2 * d_inner + 2 * state + nheads
    return {
        "ln_scale": ParamSpec((d,), ("embed",), init="zeros"),
        "in_proj": ParamSpec((d, proj_out), ("embed", "ssm_proj")),
        "conv_w": ParamSpec((cfg.conv_kernel, conv_dim),
                            (None, "ssm_conv"), init="normal", scale=1.0),
        "conv_b": ParamSpec((conv_dim,), ("ssm_conv",), init="zeros"),
        "a_log": ParamSpec((nheads,), ("ssm_heads",), init="zeros"),
        "dt_bias": ParamSpec((nheads,), ("ssm_heads",), init="zeros"),
        "d_skip": ParamSpec((nheads,), ("ssm_heads",), init="ones"),
        "norm_scale": ParamSpec((d_inner,), ("ssm_inner",), init="zeros"),
        "out_proj": ParamSpec((d_inner, d), ("ssm_inner", "embed"),
                              scale=1.0 / math.sqrt(2 * cfg.num_layers)),
    }


def shared_block_specs(cfg) -> dict:
    from repro.models.transformer import _norm_specs, mlp_param_specs
    specs = {}
    specs.update(_norm_specs(cfg, "ln_attn"))
    specs["attn"] = attn_mod.attn_param_specs(cfg)
    specs.update(_norm_specs(cfg, "ln_mlp"))
    specs["mlp"] = mlp_param_specs(cfg)
    return specs


def param_specs(cfg) -> dict:
    from repro.models.transformer import _norm_specs, _stack_specs
    specs = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                           ("vocab", "embed"), init="embed"),
        "layers": _stack_specs(mamba_param_specs(cfg), cfg.num_layers),
        "shared": shared_block_specs(cfg),
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab_size),
                             ("embed", "vocab"), init="embed"),
    }
    specs.update(_norm_specs(cfg, "ln_f"))
    return specs


def sparse_paths(cfg) -> list[str]:
    return ["shared/mlp/w_gate", "shared/mlp/w_up", "shared/mlp/w_down"]


def dense_layer_flags(cfg):
    return None   # the single shared MLP is sparsified as a whole


def n_shared_applications(cfg) -> int:
    return len([i for i in range(cfg.num_layers)
                if i % cfg.attn_every == 0])


def _split_proj(cfg, zxbcdt):
    d_inner, nheads, headdim, state = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * state]
    dt = zxbcdt[..., -nheads:]
    return z, xbc, dt


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B,S,C); w: (K,C). Returns (y, tail)
    where tail = last K-1 inputs (decode state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, :k - 1])
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(k))
    y = jax.nn.silu(y + b.astype(x.dtype))
    return y, xp[:, -(k - 1):]


def mamba_mixer(cfg, p, x, *, ssm_state=None, conv_state=None,
                decode=False):
    """x: (B,S,D) -> (y, (new_ssm_state, new_conv_state))."""
    d_inner, nheads, headdim, state = _dims(cfg)
    b, s, _ = x.shape
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs = xbc[..., :d_inner].reshape(b, s, nheads, headdim)
    bmat = xbc[..., d_inner:d_inner + state]           # (B,S,state)
    cmat = xbc[..., d_inner + state:]                  # (B,S,state)
    dt_s = jax.nn.softplus(dt.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))       # (H,)
    log_a = dt_s * a                                   # (B,S,H) scalar
    # Grouped SSD (n_groups=1): B/C shared across heads, per-head scalar
    # decay — never materialises (B,S,H,d_state) broadcasts
    # (EXPERIMENTS.md §Perf, zamba2 iteration)
    v = xs * dt_s[..., None].astype(xs.dtype)
    if attn_mod.DRYRUN_BASELINE:            # pre-optimization variant
        log_w = jnp.broadcast_to((dt_s * a)[..., None],
                                 (b, s, nheads, state))
        q = jnp.broadcast_to(cmat[:, :, None], (b, s, nheads, state))
        k = jnp.broadcast_to(bmat[:, :, None], (b, s, nheads, state))
        if decode:
            y, new_ssm = recurrent_step(q[:, 0], k[:, 0], v[:, 0],
                                        log_w[:, 0], ssm_state,
                                        chunk=cfg.chunk_size,
                                        include_diag="full")
            y = y[:, None]
        else:
            y, new_ssm = chunked_linear_attention(
                q, k, v, log_w, chunk=cfg.chunk_size,
                initial_state=ssm_state, include_diag="full")
    elif decode:
        y, new_ssm = ssd_recurrent_step(cmat[:, 0], bmat[:, 0], v[:, 0],
                                        log_a[:, 0], ssm_state)
        y = y[:, None]
    else:
        y, new_ssm = chunked_ssd(cmat, bmat, v, log_a,
                                 chunk=cfg.chunk_size,
                                 initial_state=ssm_state)
    y = y + xs * p["d_skip"].astype(x.dtype)[:, None]
    y = y.reshape(b, s, d_inner)
    # gated RMSNorm (mamba2)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    return y @ p["out_proj"].astype(x.dtype), (new_ssm, new_conv)


def _shared_block(cfg, p, x, positions, masks, cache=None, pos=None):
    """The weight-shared attention+MLP block. With ``cache`` (decode):
    cache = (ck, cv) for THIS application. Returns (x, new_cache)."""
    h = norm(cfg.norm_kind, x, p["ln_attn_scale"], p.get("ln_attn_bias"))
    if cache is None:
        a, _ = attn_mod.multihead_attention(cfg, p["attn"], h, positions,
                                            causal=True)
        new_cache = None
    else:
        a, nk, nv = attn_mod.decode_attention(cfg, p["attn"], h,
                                              cache[0], cache[1], pos)
        new_cache = (nk, nv)
    x = x + a
    h = norm(cfg.norm_kind, x, p["ln_mlp_scale"], p.get("ln_mlp_bias"))
    from repro.models.transformer import _layer_masks
    m = sm.glu_mlp(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                   p["mlp"]["w_down"], act=cfg.mlp_act,
                   masks=masks, spec=cfg.blast)
    return x + m, new_cache


def _shared_masks(masks):
    if not masks:
        return None
    prefix = "shared/mlp/"
    out = {k[len(prefix):]: v for k, v in masks.items()
           if k.startswith(prefix)}
    return out or None


def forward(cfg, params, tokens, *, masks=None, dist=None, **_):
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if dist is not None:
        x = dist.constrain_seq(x)
    smasks = _shared_masks(masks)

    def body(carry, xs_):
        x, i = carry
        p_l = xs_

        def with_attn(x):
            y, _ = _shared_block(cfg, params["shared"], x, positions,
                                 smasks)
            return y

        x = jax.lax.cond(i % cfg.attn_every == 0, with_attn,
                         lambda x: x, x)
        h = norm(cfg.norm_kind, x, p_l["ln_scale"], None)
        y, _ = mamba_mixer(cfg, p_l, h)
        x = x + y
        if dist is not None:
            x = dist.constrain_seq(x)
        return (x, i + 1), None

    if cfg.remat:
        from repro.models.layers import remat_policy
        body = jax.checkpoint(body, policy=remat_policy(cfg))
    (x, _), _ = jax.lax.scan(body, (x, 0), params["layers"])
    from repro.models.transformer import logits_from_hidden
    return logits_from_hidden(cfg, params, x, dist), 0.0


# ------------------------------------------------------------------ decode
def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    d_inner, nheads, headdim, state = _dims(cfg)
    napp = n_shared_applications(cfg)
    _, kv = attn_mod.eff_heads(cfg)
    return {
        "ssm": jnp.zeros((cfg.num_layers, batch, nheads, state, headdim),
                         jnp.float32),
        "conv": jnp.zeros((cfg.num_layers, batch, cfg.conv_kernel - 1,
                           d_inner + 2 * state), dtype),
        "k": jnp.zeros((napp, batch, max_len, kv, cfg.head_dim), dtype),
        "v": jnp.zeros((napp, batch, max_len, kv, cfg.head_dim), dtype),
    }


def abstract_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


def decode_step(cfg, params, cache, tokens, pos, *, masks=None, dist=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    smasks = _shared_masks(masks)
    napp = n_shared_applications(cfg)

    # shared-attn applications run OUTSIDE the mamba scan (python loop
    # over the napp cache slots, interleaved by layer index)
    new_k, new_v = [], []
    app_layers = [i for i in range(cfg.num_layers)
                  if i % cfg.attn_every == 0]

    def mamba_span(x, lo, hi, cache):
        """Scan mamba layers [lo, hi) functionally."""
        sl = lambda t: t[lo:hi]

        def body(carry, xs_):
            x, = carry
            p_l, st, cv = xs_
            h = norm(cfg.norm_kind, x, p_l["ln_scale"], None)
            y, (nst, ncv) = mamba_mixer(cfg, p_l, h, ssm_state=st,
                                        conv_state=cv, decode=True)
            return (x + y,), (nst, ncv)

        xs_ = (jax.tree_util.tree_map(sl, params["layers"]),
               sl(cache["ssm"]), sl(cache["conv"]))
        (x,), (nst, ncv) = jax.lax.scan(body, (x,), xs_)
        return x, nst, ncv

    ssm_parts, conv_parts = [], []
    spans = app_layers + [cfg.num_layers]
    for j, lo in enumerate(app_layers):
        x, nc = _shared_block(cfg, params["shared"], x, None, smasks,
                              cache=(cache["k"][j], cache["v"][j]),
                              pos=pos)
        new_k.append(nc[0])
        new_v.append(nc[1])
        hi = spans[j + 1]
        x, nst, ncv = mamba_span(x, lo, hi, cache)
        ssm_parts.append(nst)
        conv_parts.append(ncv)

    new_cache = {
        "ssm": jnp.concatenate(ssm_parts, 0),
        "conv": jnp.concatenate(conv_parts, 0),
        "k": jnp.stack(new_k),
        "v": jnp.stack(new_v),
    }
    del napp
    from repro.models.transformer import logits_from_hidden
    return logits_from_hidden(cfg, params, x), new_cache
