"""Attention: GQA/MHA with rope, sliding window, logit softcap, qk-norm,
query-chunked computation (bounds the score transient to
(chunk, S) — the memory behaviour a production TPU stack needs at 32k),
decode with sequence-sharded KV caches, and optional cross-attention
(whisper).

Head padding: archs whose head count does not divide TP=16 declare
``pad_heads_to``; extra heads are zero-initialised (wo rows zero ⇒ the
padding is numerically exact) — DESIGN.md §5.
"""
from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, rmsnorm, softcap
from repro.models.params import ParamSpec

NEG_INF = -1e30

# Read ONCE at import: the pre-optimization dry-run variant. A per-call
# env read inside traced code was silently baked into whatever jit cache
# existed when the function was first traced — flipping the env var
# mid-process did nothing (or worse, half of it).
DRYRUN_BASELINE = bool(os.environ.get("DRYRUN_BASELINE"))


def eff_heads(cfg) -> tuple[int, int]:
    """(q_heads, kv_heads) after TP padding."""
    h = cfg.num_heads
    kv = cfg.num_kv_heads
    if cfg.pad_heads_to:
        h = max(h, cfg.pad_heads_to)
        if cfg.num_kv_heads == cfg.num_heads:     # MHA: pad kv too
            kv = h
    return h, kv


def attn_param_specs(cfg, cross: bool = False) -> dict:
    """ParamSpec dict for one attention block (stacked by caller)."""
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = eff_heads(cfg)
    specs = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed"),
                        scale=1.0 / math.sqrt(2 * cfg.num_layers)),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), init="zeros")
        specs["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"),
                                init="zeros")
        specs["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"),
                                init="zeros")
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), ("head_dim",), init="zeros")
        specs["k_norm"] = ParamSpec((hd,), ("head_dim",), init="zeros")
    if cross:
        # cross-attention re-uses wq/wo; K/V project from encoder states
        specs = {k: v for k, v in specs.items()}
    return specs


def _project_qkv(cfg, p, x, kv_src=None):
    """-> q (B,S,H,hd), k,v (B,Skv,KV,hd)."""
    kv_src = x if kv_src is None else kv_src
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    return q, k, v


def _scores_to_out(cfg, q, k, v, q_pos, k_pos, causal, window):
    """Grouped attention core. q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd);
    q_pos: (B,Sq); k_pos: (B,Sk) (for masking). Returns (B,Sq,H,hd).

    Mixed precision WITHOUT materialising f32 copies of K/V: the dots
    accumulate in f32 via preferred_element_type (a wholesale
    cache->f32 convert was the #1 byte contributor of the decode
    roofline — EXPERIMENTS.md §Perf iteration 1)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = cfg.attn_scale or 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kv, g, hd)
    if DRYRUN_BASELINE:                     # pre-optimization variant
        logits = jnp.einsum("bqhgk,bshk->bhgqs", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
    else:
        logits = jnp.einsum("bqhgk,bshk->bhgqs", qg, k,
                            preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, cfg.attn_logit_softcap)
    mask = jnp.ones((b, sq, k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, :, None] >= k_pos[:, None, :]
    if window:
        mask &= q_pos[:, :, None] - k_pos[:, None, :] < window
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    if DRYRUN_BASELINE:
        out = jnp.einsum("bhgqs,bshk->bqhgk", probs,
                         v.astype(jnp.float32))
    else:
        out = jnp.einsum("bhgqs,bshk->bqhgk", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def multihead_attention(cfg, p, x, positions, *, causal=True, window=0,
                        q_chunk=1024, kv_src=None, kv_positions=None):
    """Full (train/prefill/encoder) attention with query chunking.

    Returns (out (B,S,D), (k, v)) — k/v returned so prefill can seed the
    cache."""
    q, k, v = _project_qkv(cfg, p, x, kv_src)
    if cfg.rope_theta > 0 and kv_src is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions if kv_positions is None
                       else kv_positions, cfg.rope_theta)
    kpos = positions if kv_positions is None else kv_positions
    s = q.shape[1]
    if s <= q_chunk or s % q_chunk != 0:
        out = _scores_to_out(cfg, q, k, v, positions, kpos, causal, window)
    else:
        nch = s // q_chunk
        qs = q.reshape(q.shape[0], nch, q_chunk, *q.shape[2:])
        ps = positions.reshape(positions.shape[0], nch, q_chunk)
        def chunk(carry, inp):
            qc, pc = inp
            oc = _scores_to_out(cfg, qc, k, v, pc, kpos, causal, window)
            return carry, oc
        # scan over chunks: transient is (B, q_chunk, S) not (B, S, S)
        _, outs = jax.lax.scan(chunk, None,
                               (qs.swapaxes(0, 1), ps.swapaxes(0, 1)))
        out = outs.swapaxes(0, 1).reshape(q.shape)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, (k, v)


# Cache slots holding no real token (left-padding of ragged prompts) get
# this sentinel "logical position": larger than any query position, so the
# causal mask excludes them (and with it the AND-ed window mask).
_PAD_POS = 1 << 30


def _cache_positions(smax: int, offsets: jax.Array) -> jax.Array:
    """(B, Smax) logical position of each cache slot for right-aligned
    sequences: slot s holds logical token ``s - offset``; slots before
    ``offset`` are padding (sentinel ``_PAD_POS`` → always masked)."""
    slots = jnp.arange(smax, dtype=jnp.int32)[None, :]
    off = offsets.astype(jnp.int32)[:, None]
    return jnp.where(slots >= off, slots - off, jnp.int32(_PAD_POS))


def decode_attention(cfg, p, x, cache_k, cache_v, pos, *, window=0,
                     cross=False, offsets=None):
    """One-token decode. x: (B,1,D); cache_k/v: (B,Smax,KV,hd); ``pos``
    is the CACHE SLOT of the new token — a scalar int32 (synchronized
    batch: every lane writes the same slot) or a (B,) vector (per-lane
    frontiers: lane b writes its own slot ``pos[b]``, engine slab
    decode). Out-of-range per-lane slots (>= Smax) drop the write — the
    engine parks finished lanes there so they stop advancing.

    For self-attention the new K/V is written at ``pos`` (functional
    update); for cross-attention the cache is the (static) encoder memory.
    With ``offsets`` (B,) the batch is ragged: lane b's logical position
    is ``pos[b] - offsets[b]`` (rope + masking), while the cache slot
    stays ``pos``. ``offsets=None`` with scalar ``pos`` is
    bitwise-identical to the historical synchronized path.
    Returns (out, new_cache_k, new_cache_v)."""
    b = x.shape[0]
    per_lane = jnp.ndim(pos) > 0
    posv = (pos.astype(jnp.int32) if per_lane
            else jnp.full((b,), pos, jnp.int32))
    if offsets is None:
        posb = posv[:, None]
    else:
        posb = (posv - offsets.astype(jnp.int32))[:, None]
    if cross:
        # encoder memory is already projected K/V; only project Q
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        if cfg.qkv_bias:
            q = q + p["bq"].astype(x.dtype)
        if cfg.qk_norm:
            q = rmsnorm(q, p["q_norm"])
    else:
        q, k, v = _project_qkv(cfg, p, x)
        if cfg.rope_theta > 0:
            q = apply_rope(q, posb, cfg.rope_theta)
            k = apply_rope(k, posb, cfg.rope_theta)
        if per_lane:
            # per-lane write slots: scatter row b at (b, pos[b]);
            # lanes whose slot is out of bounds are dropped
            lanes = jnp.arange(b)
            cache_k = cache_k.at[lanes, posv].set(
                k[:, 0].astype(cache_k.dtype), mode="drop")
            cache_v = cache_v.at[lanes, posv].set(
                v[:, 0].astype(cache_v.dtype), mode="drop")
        else:
            cache_k = jax.lax.dynamic_update_slice(
                cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
            cache_v = jax.lax.dynamic_update_slice(
                cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    smax = cache_k.shape[1]
    if offsets is None:
        kpos = jnp.broadcast_to(jnp.arange(smax, dtype=jnp.int32),
                                (b, smax))
    else:
        kpos = _cache_positions(smax, offsets)
    # causal mask at qpos==pos also masks the garbage cache tail
    out = _scores_to_out(cfg, q, cache_k.astype(q.dtype),
                         cache_v.astype(q.dtype), posb, kpos,
                         causal=not cross, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, cache_k, cache_v


def chunk_attention(cfg, p, x, cache_k, cache_v, slot, offsets, *,
                    window=0, lane_mask=None):
    """Batched chunked-prefill attention: C prompt tokens at once.

    x: (B,C,D); cache_k/v: (B,Smax,KV,hd). The chunk's K/V is written at
    cache slots [slot, slot+C); lane b's token at slot s has logical
    position ``s - offsets[b]`` (right-aligned ragged batch — left-pad
    slots are masked everywhere via the ``_PAD_POS`` sentinel).
    ``lane_mask`` (B,) bool, when given, preserves the existing cache
    rows of lanes not being prefilled (continuous batching admits new
    sequences behind the decode frontier of running ones).
    Returns (out (B,C,D), new_cache_k, new_cache_v)."""
    b, c, _ = x.shape
    slots = jnp.int32(slot) + jnp.arange(c, dtype=jnp.int32)
    qpos = slots[None, :] - offsets.astype(jnp.int32)[:, None]   # (B,C)
    q, k, v = _project_qkv(cfg, p, x)
    if cfg.rope_theta > 0:
        # pad queries have negative logical positions; clamp for rope
        # (their K/V and outputs are masked / discarded anyway)
        rp = jnp.maximum(qpos, 0)
        q = apply_rope(q, rp, cfg.rope_theta)
        k = apply_rope(k, rp, cfg.rope_theta)
    k = k.astype(cache_k.dtype)
    v = v.astype(cache_v.dtype)
    if lane_mask is not None:
        keep = lane_mask[:, None, None, None]
        k = jnp.where(keep, k, jax.lax.dynamic_slice(
            cache_k, (0, slot, 0, 0), k.shape))
        v = jnp.where(keep, v, jax.lax.dynamic_slice(
            cache_v, (0, slot, 0, 0), v.shape))
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
    kpos = _cache_positions(cache_k.shape[1], offsets)
    out = _scores_to_out(cfg, q, cache_k.astype(q.dtype),
                         cache_v.astype(q.dtype), qpos, kpos,
                         causal=True, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, cache_k, cache_v


# ---------------------------------------------------------------- paged KV
# The contiguous cache above scores every query against the full
# (B, Smax, KV, hd) slab, so decode attention bytes scale with ``Smax``
# no matter how short a lane's live context is. The paged variant stores
# K/V in a SHARED page pool (n_pages, page_size, KV, hd); each lane maps
# logical cache slots to pool pages through a (max_pages,) block table
# and attention gathers ONLY the lane's first ``read_pages`` pages — the
# engine buckets ``read_pages`` to the next power of two of the live
# frontier, so per-token attention reads scale with
# ``ceil(frontier / page_size)`` instead of ``Smax`` (BLaST's
# move-only-the-blocks-that-matter thesis applied to the KV cache).
#
# Logical slot ``s`` of lane ``b`` lives at pool page
# ``block_tables[b, s // page_size]``, row ``s % page_size``; the slot
# numbering (and with it rope, offsets, causal/window masking via
# ``_cache_positions``) is IDENTICAL to the contiguous cache, so greedy
# decode through this path is bitwise-identical to the dense one — the
# gathered slots beyond a lane's frontier land on unallocated (or
# stale) pages and are killed by the same causal mask that hides the
# garbage cache tail in the dense path.


def gather_pages(pool: jax.Array, block_tables: jax.Array,
                 read_pages: int) -> jax.Array:
    """(n_pages, ps, KV, hd) pool + (B, max_pages) tables ->
    (B, read_pages*ps, KV, hd): each lane's first ``read_pages`` logical
    pages, in logical-slot order (the XLA fallback of the Pallas
    blocked-gather kernel — kernels/paged_attention.py)."""
    b = block_tables.shape[0]
    g = pool[block_tables[:, :read_pages]]    # (B, R, ps, KV, hd)
    return g.reshape(b, read_pages * pool.shape[1], *pool.shape[2:])


def paged_write(pool: jax.Array, block_tables: jax.Array,
                slots: jax.Array, values: jax.Array,
                lane_mask: jax.Array | None = None) -> jax.Array:
    """Scatter ``values`` at logical ``slots`` through the block tables.

    pool: (n_pages, ps, KV, hd); slots: (B,) or (B, C) int32; values:
    slots.shape + (KV, hd). Slots past the table end (>= max_pages*ps —
    the engine parks finished lanes there) and lanes masked out by
    ``lane_mask`` are DROPPED, never clamped: a clamp would alias the
    write onto pool page 0, which may belong to another lane.
    ``lane_mask`` is (B,) bool (whole lanes) or (B, C) bool (per-token:
    the mixed decode+prefill step pads every lane's query run to a
    common width — pad tokens must not scribble through the block
    table, whose rows beyond a lane's allocation point at page 0)."""
    n_pages, ps = pool.shape[0], pool.shape[1]
    max_pages = block_tables.shape[1]
    slots = slots.astype(jnp.int32)
    squeeze = slots.ndim == 1
    s2 = slots[:, None] if squeeze else slots            # (B, C)
    page = s2 // ps
    ok = page < max_pages
    if lane_mask is not None:
        ok &= (lane_mask[:, None] if lane_mask.ndim == 1 else lane_mask)
    phys = jnp.take_along_axis(block_tables,
                               jnp.minimum(page, max_pages - 1), axis=1)
    phys = jnp.where(ok, phys, jnp.int32(n_pages))       # OOB -> drop
    vals = values[:, None] if squeeze else values
    return pool.at[phys, s2 % ps].set(vals.astype(pool.dtype),
                                      mode="drop")


def paged_decode_attention(cfg, p, x, pool_k, pool_v, block_tables, pos,
                           *, read_pages: int, window=0, offsets=None,
                           backend: str = "xla"):
    """One-token decode over the paged pool. x: (B,1,D); pool_k/v:
    (n_pages, ps, KV, hd) SHARED across lanes; ``block_tables``
    (B, max_pages) int32; ``pos`` (B,) is each lane's logical cache
    slot (parked lanes carry ``max_pages*ps`` — the write drops).
    ``read_pages`` is STATIC: attention reads each lane's first
    ``read_pages`` pages (the engine guarantees they cover every live
    frontier and buckets the value to a power of two so the jit cache
    stays O(log max_pages)).

    ``backend``: 'xla' (gather + dense core — the oracle), 'pallas'
    (blocked-gather flash-decode kernel, kernels/paged_attention.py), or
    'pallas_interp' (same kernel, interpret mode).
    Returns (out, new_pool_k, new_pool_v)."""
    b = x.shape[0]
    ps = pool_k.shape[1]
    posv = pos.astype(jnp.int32)
    posb = (posv if offsets is None
            else posv - offsets.astype(jnp.int32))[:, None]
    q, k, v = _project_qkv(cfg, p, x)
    if cfg.rope_theta > 0:
        q = apply_rope(q, posb, cfg.rope_theta)
        k = apply_rope(k, posb, cfg.rope_theta)
    pool_k = paged_write(pool_k, block_tables, posv, k[:, 0])
    pool_v = paged_write(pool_v, block_tables, posv, v[:, 0])
    smax = read_pages * ps
    if offsets is None:
        kpos = jnp.broadcast_to(jnp.arange(smax, dtype=jnp.int32),
                                (b, smax))
    else:
        kpos = _cache_positions(smax, offsets)
    if backend in ("pallas", "pallas_interp"):
        from repro.kernels import paged_attention as pk
        out = pk.paged_decode_attn(
            cfg, q, pool_k, pool_v, block_tables[:, :read_pages],
            posb, kpos, window=window,
            interpret=(backend == "pallas_interp"))
    else:
        gk = gather_pages(pool_k, block_tables, read_pages)
        gv = gather_pages(pool_v, block_tables, read_pages)
        out = _scores_to_out(cfg, q, gk.astype(q.dtype),
                             gv.astype(q.dtype), posb, kpos,
                             causal=True, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, pool_k, pool_v


def paged_chunk_attention(cfg, p, x, pool_k, pool_v, block_tables, slot,
                          offsets, *, read_pages: int, window=0,
                          lane_mask=None, q_lens=None):
    """Batched chunked-prefill attention over the paged pool: C prompt
    tokens written at logical slots [slot, slot+C) through each lane's
    block table (the engine allocates the covering pages before the
    first chunk). ``lane_mask`` shields running lanes the natural paged
    way — their writes are dropped, their pages never touched (the
    dense path had to read-modify-write them back).

    ``slot`` may be a scalar (every lane writes the same slot range —
    group prefill) or a (B,) vector of PER-LANE start slots; with
    ``q_lens`` (B,) the query run is additionally RAGGED per lane: lane
    b's tokens [0, q_lens[b]) are real (written + attended from its own
    positions), the rest of the width-C row is padding whose writes are
    dropped and whose outputs the caller discards. This is the mixed
    decode+prefill core: decode lanes ride along at q_len == 1 (start =
    their frontier) while admitting lanes prefill a chunk, all in ONE
    call — per-query attention math is position-row independent, so
    each lane's rows come out bitwise-identical to the phased paths.
    Returns (out (B,C,D), new_pool_k, new_pool_v)."""
    b, c, _ = x.shape
    ps = pool_k.shape[1]
    slot = jnp.asarray(slot, jnp.int32)
    steps = jnp.arange(c, dtype=jnp.int32)
    if slot.ndim == 0:
        slots_b = jnp.broadcast_to((slot + steps)[None, :], (b, c))
    else:
        slots_b = slot[:, None] + steps[None, :]             # (B, C)
    qpos = slots_b - offsets.astype(jnp.int32)[:, None]      # (B, C)
    q, k, v = _project_qkv(cfg, p, x)
    if cfg.rope_theta > 0:
        rp = jnp.maximum(qpos, 0)
        q = apply_rope(q, rp, cfg.rope_theta)
        k = apply_rope(k, rp, cfg.rope_theta)
    wmask = None if lane_mask is None else lane_mask
    if q_lens is not None:
        valid = steps[None, :] < q_lens.astype(jnp.int32)[:, None]
        wmask = valid if wmask is None else (wmask[:, None] & valid
                                             if wmask.ndim == 1
                                             else wmask & valid)
    pool_k = paged_write(pool_k, block_tables, slots_b, k, wmask)
    pool_v = paged_write(pool_v, block_tables, slots_b, v, wmask)
    kpos = _cache_positions(read_pages * ps, offsets)
    gk = gather_pages(pool_k, block_tables, read_pages)
    gv = gather_pages(pool_v, block_tables, read_pages)
    out = _scores_to_out(cfg, q, gk.astype(q.dtype), gv.astype(q.dtype),
                         qpos, kpos, causal=True, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, pool_k, pool_v
