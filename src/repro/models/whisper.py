"""whisper-large-v3 [audio]: encoder-decoder transformer backbone.

The conv/mel frontend is a STUB per the task statement: ``input_specs()``
provides precomputed frame embeddings (B, S_enc, d_model). Learned
position embeddings (whisper style, sized to the assigned shapes);
decoder layers interleave causal self-attention and cross-attention into
the encoder memory. BLaST applies to both encoder and decoder MLPs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import sparse_mlp as sm
from repro.models import attention as attn_mod
from repro.models.layers import norm
from repro.models.params import ParamSpec
from repro.models.transformer import (_layer_masks, _norm_specs,
                                      _stack_specs, mlp_param_specs)

MAX_POS = 16_384   # backbone scaled to the assigned shapes (prefill 16k)


def enc_layer_specs(cfg) -> dict:
    specs = {}
    specs.update(_norm_specs(cfg, "ln_attn"))
    specs["attn"] = attn_mod.attn_param_specs(cfg)
    specs.update(_norm_specs(cfg, "ln_mlp"))
    specs["mlp"] = mlp_param_specs(cfg)
    return specs


def dec_layer_specs(cfg) -> dict:
    specs = enc_layer_specs(cfg)
    specs.update(_norm_specs(cfg, "ln_cross"))
    specs["cross"] = attn_mod.attn_param_specs(cfg, cross=True)
    return specs


def param_specs(cfg) -> dict:
    d = cfg.d_model
    specs = {
        "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "embed"),
                           init="embed"),
        "pos_enc": ParamSpec((MAX_POS, d), (None, "embed"), init="embed"),
        "pos_dec": ParamSpec((MAX_POS, d), (None, "embed"), init="embed"),
        "encoder": _stack_specs(enc_layer_specs(cfg),
                                cfg.num_encoder_layers),
        "decoder": _stack_specs(dec_layer_specs(cfg), cfg.num_layers),
        "lm_head": ParamSpec((d, cfg.vocab_size), ("embed", "vocab"),
                             init="embed"),
    }
    specs.update(_norm_specs(cfg, "ln_f"))
    specs.update(_norm_specs(cfg, "ln_enc_f"))
    return specs


def sparse_paths(cfg) -> list[str]:
    return ["encoder/mlp/w_in", "encoder/mlp/w_out",
            "decoder/mlp/w_in", "decoder/mlp/w_out"]


def dense_layer_flags(cfg):
    """Per-stack flags (encoder/decoder depths differ in smoke configs);
    the last L layers of EACH stack stay dense (paper §5.4.4)."""
    def flags(n):
        return jnp.arange(n) >= (n - cfg.blast.dense_last)
    return {"encoder": flags(cfg.num_encoder_layers),
            "decoder": flags(cfg.num_layers)}


def encode(cfg, params, frames, *, masks=None, dist=None):
    """frames: (B, S_enc, D) precomputed embeddings (stub frontend)."""
    b, s, _ = frames.shape
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + params["pos_enc"][:s].astype(x.dtype)
    if dist is not None:
        x = dist.constrain_seq(x)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    emasks = _layer_masks(masks, "encoder")

    def body(carry, xs_):
        x, = carry
        p_l, m_l = xs_
        h = norm(cfg.norm_kind, x, p_l["ln_attn_scale"],
                 p_l.get("ln_attn_bias"))
        a, _ = attn_mod.multihead_attention(cfg, p_l["attn"], h,
                                            positions, causal=False)
        x = x + a
        h = norm(cfg.norm_kind, x, p_l["ln_mlp_scale"],
                 p_l.get("ln_mlp_bias"))
        m = sm.mlp2(h, p_l["mlp"]["w_in"], p_l["mlp"]["w_out"],
                    p_l["mlp"].get("b_in"), p_l["mlp"].get("b_out"),
                    act=cfg.mlp_act, masks=m_l, spec=cfg.blast)
        x = x + m
        if dist is not None:
            x = dist.constrain_seq(x)
        return (x,), None

    if cfg.remat:
        from repro.models.layers import remat_policy
        body = jax.checkpoint(body, policy=remat_policy(cfg))
    (x,), _ = jax.lax.scan(body, (x,), (params["encoder"], emasks))
    return norm(cfg.norm_kind, x, params["ln_enc_f_scale"],
                params.get("ln_enc_f_bias"))


def _dec_block(cfg, p_l, m_l, x, positions, memory, mem_positions):
    h = norm(cfg.norm_kind, x, p_l["ln_attn_scale"],
             p_l.get("ln_attn_bias"))
    a, kv = attn_mod.multihead_attention(cfg, p_l["attn"], h, positions,
                                         causal=True)
    x = x + a
    h = norm(cfg.norm_kind, x, p_l["ln_cross_scale"],
             p_l.get("ln_cross_bias"))
    c, cross_kv = attn_mod.multihead_attention(
        cfg, p_l["cross"], h, positions, causal=False, kv_src=memory,
        kv_positions=mem_positions)
    x = x + c
    h = norm(cfg.norm_kind, x, p_l["ln_mlp_scale"],
             p_l.get("ln_mlp_bias"))
    m = sm.mlp2(h, p_l["mlp"]["w_in"], p_l["mlp"]["w_out"],
                p_l["mlp"].get("b_in"), p_l["mlp"].get("b_out"),
                act=cfg.mlp_act, masks=m_l, spec=cfg.blast)
    return x + m, kv, cross_kv


def forward(cfg, params, tokens, *, frames=None, masks=None, dist=None,
            **_):
    """Training forward: frames (B,S_enc,D) + tokens (B,S_dec)."""
    memory = encode(cfg, params, frames, masks=masks, dist=dist)
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    x = x + params["pos_dec"][:s].astype(x.dtype)
    if dist is not None:
        x = dist.constrain_seq(x)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    mem_positions = jnp.broadcast_to(
        jnp.arange(memory.shape[1], dtype=jnp.int32),
        (b, memory.shape[1]))
    dmasks = _layer_masks(masks, "decoder")

    def body(carry, xs_):
        x, = carry
        p_l, m_l = xs_
        x, _, _ = _dec_block(cfg, p_l, m_l, x, positions, memory,
                             mem_positions)
        if dist is not None:
            x = dist.constrain_seq(x)
        return (x,), None

    if cfg.remat:
        from repro.models.layers import remat_policy
        body = jax.checkpoint(body, policy=remat_policy(cfg))
    (x,), _ = jax.lax.scan(body, (x,), (params["decoder"], dmasks))
    from repro.models.transformer import logits_from_hidden
    return logits_from_hidden(cfg, params, x, dist), 0.0


def prefill_cross(cfg, params, frames, *, masks=None, dist=None,
                  dtype=jnp.bfloat16):
    """Run the encoder and project per-decoder-layer cross K/V — fills
    the 'ck'/'cv' slots of the decode cache."""
    memory = encode(cfg, params, frames, masks=masks, dist=dist)

    def proj(p_l):
        k = jnp.einsum("bsd,dhk->bshk", memory,
                       p_l["cross"]["wk"].astype(memory.dtype))
        v = jnp.einsum("bsd,dhk->bshk", memory,
                       p_l["cross"]["wv"].astype(memory.dtype))
        if cfg.qkv_bias:
            k = k + p_l["cross"]["bk"].astype(k.dtype)
            v = v + p_l["cross"]["bv"].astype(v.dtype)
        return k.astype(dtype), v.astype(dtype)

    ck, cv = jax.lax.map(proj, params["decoder"])
    return ck, cv


# ------------------------------------------------------------------ decode
def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
               enc_len: int | None = None):
    """Self-attn cache (decoder) + projected encoder memory K/V."""
    enc_len = enc_len or max_len
    _, kv = attn_mod.eff_heads(cfg)
    L = cfg.num_layers
    return {
        "k": jnp.zeros((L, batch, max_len, kv, cfg.head_dim), dtype),
        "v": jnp.zeros((L, batch, max_len, kv, cfg.head_dim), dtype),
        "ck": jnp.zeros((L, batch, enc_len, kv, cfg.head_dim), dtype),
        "cv": jnp.zeros((L, batch, enc_len, kv, cfg.head_dim), dtype),
    }


def abstract_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
                   enc_len: int | None = None):
    # eval_shape: NO allocation (decode_32k whisper cache is ~1 TB)
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, dtype, enc_len))


def decode_step(cfg, params, cache, tokens, pos, *, masks=None,
                dist=None):
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    x = x + jax.lax.dynamic_slice_in_dim(
        params["pos_dec"], pos, 1).astype(x.dtype)
    dmasks = _layer_masks(masks, "decoder")

    def body(carry, xs_):
        x, = carry
        p_l, m_l, ck, cv, cck, ccv = xs_
        h = norm(cfg.norm_kind, x, p_l["ln_attn_scale"],
                 p_l.get("ln_attn_bias"))
        a, nk, nv = attn_mod.decode_attention(cfg, p_l["attn"], h, ck, cv,
                                              pos)
        x = x + a
        h = norm(cfg.norm_kind, x, p_l["ln_cross_scale"],
                 p_l.get("ln_cross_bias"))
        c, _, _ = attn_mod.decode_attention(cfg, p_l["cross"], h, cck,
                                            ccv, pos, cross=True)
        x = x + c
        h = norm(cfg.norm_kind, x, p_l["ln_mlp_scale"],
                 p_l.get("ln_mlp_bias"))
        m = sm.mlp2(h, p_l["mlp"]["w_in"], p_l["mlp"]["w_out"],
                    p_l["mlp"].get("b_in"), p_l["mlp"].get("b_out"),
                    act=cfg.mlp_act, masks=m_l, spec=cfg.blast)
        return (x + m,), (nk, nv)

    xs_ = (params["decoder"], dmasks, cache["k"], cache["v"],
           cache["ck"], cache["cv"])
    (x,), (nk, nv) = jax.lax.scan(body, (x,), xs_)
    new_cache = dict(cache, k=nk, v=nv)
    from repro.models.transformer import logits_from_hidden
    return logits_from_hidden(cfg, params, x), new_cache
