"""Generic decoder-only Transformer LM covering the dense / MoE / VLM
families (stablelm-3b/12b, qwen2-7b, gemma2-27b, qwen3-moe, deepseek-moe,
internvl2-2b, and the paper's GPT-2 / Llama configs).

Layers are scanned (stacked params, single compiled body — compile time
independent of depth). gemma2's local/global alternating pattern scans
(local, global) PAIRS. BLaST masks ride along as stacked scan inputs.

Decode uses per-layer KV caches stacked on the layer axis; caches shard
their sequence dim over the ``model`` axis so a 1.6 TB gemma2 32k-batch
cache fits (DESIGN.md §5).
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import sparse_mlp as sm
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.layers import norm, softcap
from repro.models.params import ParamSpec


# -------------------------------------------------------------- param spec
def _norm_specs(cfg, name):
    d = {name + "_scale": ParamSpec((cfg.d_model,), ("embed",),
                                    init="zeros" if cfg.norm_kind ==
                                    "rmsnorm" else "ones")}
    if cfg.norm_kind == "layernorm":
        d[name + "_bias"] = ParamSpec((cfg.d_model,), ("embed",),
                                      init="zeros")
    return d


def mlp_param_specs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    down_scale = 1.0 / math.sqrt(2 * cfg.num_layers)
    if cfg.is_moe:
        return moe_mod.moe_param_specs(cfg)
    if cfg.mlp_kind == "glu":
        return {
            "w_gate": ParamSpec((d, f), ("embed", "ff")),
            "w_up": ParamSpec((d, f), ("embed", "ff")),
            "w_down": ParamSpec((f, d), ("ff", "embed"), scale=down_scale),
        }
    return {
        "w_in": ParamSpec((d, f), ("embed", "ff")),
        "b_in": ParamSpec((f,), ("ff",), init="zeros"),
        "w_out": ParamSpec((f, d), ("ff", "embed"), scale=down_scale),
        "b_out": ParamSpec((d,), ("embed",), init="zeros"),
    }


def layer_param_specs(cfg) -> dict:
    specs = {}
    specs.update(_norm_specs(cfg, "ln_attn"))
    specs.update({"attn": attn.attn_param_specs(cfg)})
    specs.update(_norm_specs(cfg, "ln_mlp"))
    specs.update({"mlp": mlp_param_specs(cfg)})
    return specs


def _stack_specs(specs: dict, n: int) -> dict:
    """Prepend a stacked 'layers' dim to every leaf."""
    def f(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, ("layers",) + s.axes,
                         init=s.init, scale=s.scale, dtype=s.dtype)
    return jax.tree_util.tree_map(
        f, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def n_stacks(cfg) -> tuple[int, int]:
    """(stack length, layers per scan step)."""
    if cfg.layer_pattern == "local_global":
        assert cfg.num_layers % 2 == 0
        return cfg.num_layers // 2, 2
    return cfg.num_layers, 1


def param_specs(cfg) -> dict:
    ns, per = n_stacks(cfg)
    specs: dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                           ("vocab", "embed"), init="embed"),
    }
    if cfg.layer_pattern == "local_global":
        specs["layers_local"] = _stack_specs(layer_param_specs(cfg), ns)
        specs["layers_global"] = _stack_specs(layer_param_specs(cfg), ns)
    else:
        specs["layers"] = _stack_specs(layer_param_specs(cfg), ns)
    specs.update(_norm_specs(cfg, "ln_f"))
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                     ("embed", "vocab"), init="embed")
    del per
    return specs


def sparse_paths(cfg) -> list[str]:
    """Mask-tree paths of BLaST-sparsified weights (stacked)."""
    stacks = (["layers_local", "layers_global"]
              if cfg.layer_pattern == "local_global" else ["layers"])
    if cfg.is_moe:
        leaves = ["mlp/w_gate", "mlp/w_up", "mlp/w_down"]
        if cfg.num_shared_experts:
            leaves += ["mlp/ws_gate", "mlp/ws_up", "mlp/ws_down"]
    elif cfg.mlp_kind == "glu":
        leaves = ["mlp/w_gate", "mlp/w_up", "mlp/w_down"]
    else:
        leaves = ["mlp/w_in", "mlp/w_out"]
    return [f"{s}/{leaf}" for s in stacks for leaf in leaves]


def dense_layer_flags(cfg) -> jax.Array:
    """(stack,) bool — True where the MLP stays dense (last L layers,
    paper §5.4.4). For paired stacks the flag covers the pair."""
    ns, per = n_stacks(cfg)
    n_dense = math.ceil(cfg.blast.dense_last / per)
    idx = jnp.arange(ns)
    return idx >= (ns - n_dense)


# ----------------------------------------------------------------- forward
def _layer_masks(masks: dict | None, stack: str) -> dict | None:
    if not masks:
        return None
    prefix = stack + "/mlp/"
    out = {k[len(prefix):]: v for k, v in masks.items()
           if k.startswith(prefix)}
    return out or None


def _moe_shardmap(cfg, p, x, masks, dist):
    """EP over the model axis: tokens replicated across 'model', local
    experts per shard, psum combine (DESIGN.md §4)."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.context import shard_map
    ma = dist.model_axis
    bp = dist.batch_pspec(3)
    rep = P()
    p_specs = {k: (P(ma, None, None) if k in ("w_gate", "w_up", "w_down")
                   else rep) for k in p}
    if masks:
        m_specs = {k: (P(ma, None, None)
                       if k in ("w_gate", "w_up", "w_down") else rep)
                   for k in masks}
    else:
        m_specs = None

    def body(x_l, p_l, m_l):
        y, aux = moe_mod.moe_forward(cfg, p_l, x_l, masks=m_l,
                                     axis_name=ma)
        if dist.batch_axes:
            aux = jax.lax.pmean(aux, dist.batch_axes)
        return y, aux

    y, aux = shard_map(body, mesh=dist.mesh,
                       in_specs=(bp, p_specs, m_specs),
                       out_specs=(bp, rep), check_vma=False)(x, p, masks)
    return y, aux


def mlp_forward(cfg, p, x, masks, dist=None):
    if cfg.is_moe:
        if dist is not None and dist.mesh is not None \
                and not dist.inside_shard_map:
            return _moe_shardmap(cfg, p, x, masks, dist)
        axis = dist.model_axis if (dist and dist.inside_shard_map) else None
        y, aux = moe_mod.moe_forward(cfg, p, x, masks=masks,
                                     axis_name=axis)
        return y, aux
    if cfg.mlp_kind == "glu":
        y = sm.glu_mlp(x, p["w_gate"], p["w_up"], p["w_down"],
                       act=cfg.mlp_act, masks=masks, spec=cfg.blast)
    else:
        y = sm.mlp2(x, p["w_in"], p["w_out"], p.get("b_in"),
                    p.get("b_out"), act=cfg.mlp_act, masks=masks,
                    spec=cfg.blast)
    return y, 0.0


def _block(cfg, p, x, positions, masks, *, window, dist=None):
    """One pre-norm transformer block (full attention)."""
    h = norm(cfg.norm_kind, x, p["ln_attn_scale"], p.get("ln_attn_bias"))
    a, _ = attn.multihead_attention(cfg, p["attn"], h, positions,
                                    causal=True, window=window)
    x = x + a
    h = norm(cfg.norm_kind, x, p["ln_mlp_scale"], p.get("ln_mlp_bias"))
    m, aux = mlp_forward(cfg, p["mlp"], h, masks, dist)
    return x + m, aux


def embed_inputs(cfg, params, tokens, patch_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    if cfg.scale_embeddings:
        x = x * math.sqrt(cfg.d_model)
    if patch_embeds is not None and cfg.num_patches:
        p = patch_embeds.astype(x.dtype)
        x = jnp.concatenate([p, x[:, cfg.num_patches:]], axis=1)
    return x


def logits_from_hidden(cfg, params, x, dist=None):
    xf = norm(cfg.norm_kind, x, params["ln_f_scale"],
              params.get("ln_f_bias"))
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", xf, head.astype(xf.dtype))
    logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    if dist is not None:
        logits = dist.constrain_logits(logits)
    return logits


def forward(cfg, params, tokens, *, masks=None, patch_embeds=None,
            dist=None):
    """Training/prefill forward -> (logits (B,S,V) f32, aux_loss)."""
    b, s = tokens.shape
    x = embed_inputs(cfg, params, tokens, patch_embeds)
    if dist is not None:
        x = dist.constrain_seq(x)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, xs):
        x, aux = carry
        if cfg.layer_pattern == "local_global":
            p_loc, m_loc, p_glb, m_glb = xs
            x, a1 = _block(cfg, p_loc, x, positions, m_loc,
                           window=cfg.sliding_window, dist=dist)
            x, a2 = _block(cfg, p_glb, x, positions, m_glb,
                           window=0, dist=dist)
            if dist is not None:
                x = dist.constrain_seq(x)
            return (x, aux + a1 + a2), None
        p_l, m_l = xs
        x, a = _block(cfg, p_l, x, positions, m_l,
                      window=cfg.sliding_window, dist=dist)
        if dist is not None:
            x = dist.constrain_seq(x)
        return (x, aux + a), None

    if cfg.remat:
        from repro.models.layers import remat_policy
        body = jax.checkpoint(body, policy=remat_policy(cfg))

    if cfg.layer_pattern == "local_global":
        xs = (params["layers_local"], _layer_masks(masks, "layers_local"),
              params["layers_global"], _layer_masks(masks, "layers_global"))
    else:
        xs = (params["layers"], _layer_masks(masks, "layers"))
    (x, aux), _ = jax.lax.scan(body, (x, 0.0), xs)
    return logits_from_hidden(cfg, params, x, dist), aux


# ------------------------------------------------------------------ decode
def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    ns, per = n_stacks(cfg)
    _, kv = attn.eff_heads(cfg)
    shape = (ns * per, batch, max_len, kv, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def abstract_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    ns, per = n_stacks(cfg)
    _, kv = attn.eff_heads(cfg)
    shape = (ns * per, batch, max_len, kv, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def init_paged_cache(cfg, n_pages: int, page_size: int,
                     dtype=jnp.bfloat16):
    """Paged KV pool: (layers, n_pages, page_size, KV, hd), SHARED by
    every lane — lanes map logical slots to pool pages through per-lane
    block tables (engine.py), and total servable context is bounded by
    ``n_pages * page_size`` instead of ``max_batch * max_len``. A pool
    page is allocated for a lane across ALL layers at once, so the block
    table is layer-independent."""
    ns, per = n_stacks(cfg)
    _, kv = attn.eff_heads(cfg)
    shape = (ns * per, n_pages, page_size, kv, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _run_stack(cfg, params, cache, x, masks, dist, attn_fn):
    """Scan the layer stack with a pluggable attention core — the single
    implementation behind contiguous/paged decode and chunked prefill
    (they differ ONLY in how attention reads/writes the cache).

    ``attn_fn(p_attn, h, ck, cv, window) -> (attn_out, new_k, new_v)``
    where ck/cv are this layer's cache slices.
    Returns (hidden, new_cache)."""
    def one(window, p_l, m_l, x, aux, ck, cv):
        h = norm(cfg.norm_kind, x, p_l["ln_attn_scale"],
                 p_l.get("ln_attn_bias"))
        a, nk, nv = attn_fn(p_l["attn"], h, ck, cv, window)
        x = x + a
        h = norm(cfg.norm_kind, x, p_l["ln_mlp_scale"],
                 p_l.get("ln_mlp_bias"))
        m, al = mlp_forward(cfg, p_l["mlp"], h, m_l, dist)
        return x + m, aux + al, nk, nv

    def body(carry, xs):
        x, aux = carry
        if cfg.layer_pattern == "local_global":
            p_loc, m_loc, p_glb, m_glb, ck, cv = xs
            x, aux, nk0, nv0 = one(cfg.sliding_window, p_loc, m_loc,
                                   x, aux, ck[0], cv[0])
            x, aux, nk1, nv1 = one(0, p_glb, m_glb, x, aux, ck[1], cv[1])
            return (x, aux), (jnp.stack([nk0, nk1]),
                              jnp.stack([nv0, nv1]))
        p_l, m_l, ck, cv = xs
        x, aux, nk, nv = one(cfg.sliding_window, p_l, m_l, x, aux, ck, cv)
        return (x, aux), (nk, nv)

    ns, per = n_stacks(cfg)
    if cfg.layer_pattern == "local_global":
        ck = cache["k"].reshape(ns, per, *cache["k"].shape[1:])
        cv = cache["v"].reshape(ns, per, *cache["v"].shape[1:])
        xs = (params["layers_local"], _layer_masks(masks, "layers_local"),
              params["layers_global"], _layer_masks(masks, "layers_global"),
              ck, cv)
    else:
        xs = (params["layers"], _layer_masks(masks, "layers"),
              cache["k"], cache["v"])
    (x, _), (nk, nv) = jax.lax.scan(body, (x, 0.0), xs)
    return x, {"k": nk.reshape(cache["k"].shape),
               "v": nv.reshape(cache["v"].shape)}


def decode_step(cfg, params, cache, tokens, pos, *, masks=None, dist=None,
                offsets=None):
    """One decode step. tokens: (B,1); pos: CACHE SLOT — scalar int32
    (synchronized batch) or (B,) int32 vector (per-lane frontiers: lane
    b writes slot ``pos[b]``; out-of-range slots drop the write —
    engine slab decode parks finished lanes at Smax).

    ``offsets`` (B,) makes the batch ragged: lane b's logical position
    is ``pos[b] - offsets[b]`` (engine.py). ``None`` with scalar ``pos``
    keeps the synchronized path bitwise-unchanged.
    Returns (logits (B,1,V), new_cache)."""
    x = embed_inputs(cfg, params, tokens)

    def attn_fn(p_a, h, ck, cv, window):
        return attn.decode_attention(cfg, p_a, h, ck, cv, pos,
                                     window=window, offsets=offsets)

    x, new_cache = _run_stack(cfg, params, cache, x, masks, dist, attn_fn)
    return logits_from_hidden(cfg, params, x), new_cache


def paged_decode_step(cfg, params, cache, tokens, pos, block_tables, *,
                      read_pages: int, masks=None, dist=None,
                      offsets=None, attn_backend: str = "xla"):
    """One decode step over the PAGED pool cache (init_paged_cache).
    tokens: (B,1); pos: (B,) logical cache slots (parked lanes carry
    ``max_pages * page_size`` — the write drops); block_tables:
    (B, max_pages) int32; ``read_pages`` STATIC — attention reads only
    each lane's first ``read_pages`` pages, so per-token attention bytes
    scale with the live frontier, not the cache extent.
    Returns (logits (B,1,V), new_cache)."""
    x = embed_inputs(cfg, params, tokens)

    def attn_fn(p_a, h, ck, cv, window):
        return attn.paged_decode_attention(
            cfg, p_a, h, ck, cv, block_tables, pos,
            read_pages=read_pages, window=window, offsets=offsets,
            backend=attn_backend)

    x, new_cache = _run_stack(cfg, params, cache, x, masks, dist, attn_fn)
    return logits_from_hidden(cfg, params, x), new_cache


def prefill_chunk(cfg, params, cache, tokens, slot, offsets, *,
                  masks=None, dist=None, lane_mask=None):
    """Batched chunked prefill: run a whole (B, C) chunk of right-aligned
    prompt tokens through every layer in one jitted call, writing K/V at
    cache slots [slot, slot+C) — replaces the token-by-token Python
    prefill loop (paper §5.2 serving setting, continuous batching).

    tokens: (B,C); slot: scalar int32 start slot; offsets: (B,) left-pad
    per lane (logical position of slot s is ``s - offsets[b]``);
    ``lane_mask`` (B,) bool — lanes with False keep their existing cache
    rows untouched (they are mid-decode while new lanes prefill behind
    their frontier). Returns (logits (B,C,V) f32, new_cache)."""
    x = embed_inputs(cfg, params, tokens)

    def attn_fn(p_a, h, ck, cv, window):
        return attn.chunk_attention(cfg, p_a, h, ck, cv, slot, offsets,
                                    window=window, lane_mask=lane_mask)

    x, new_cache = _run_stack(cfg, params, cache, x, masks, dist, attn_fn)
    return logits_from_hidden(cfg, params, x), new_cache


def paged_prefill_chunk(cfg, params, cache, tokens, slot, offsets,
                        block_tables, *, read_pages: int, masks=None,
                        dist=None, lane_mask=None, q_lens=None):
    """Chunked prefill over the PAGED pool: the chunk's K/V lands at
    logical slots [slot, slot+C) through each lane's block table (pages
    pre-allocated by the engine); attention reads each lane's first
    ``read_pages`` pages (STATIC — must cover slot+C).

    ``slot`` may also be a (B,) vector of per-lane start slots and
    ``q_lens`` a (B,) per-lane query-run length — the MIXED batch shape
    (serving/step.py make_mixed_step): decode lanes contribute one
    token (q_len 1 at their frontier) while admitting lanes contribute
    a prefill chunk, through one pass of the same ``_run_stack`` core.
    Returns (logits (B,C,V) f32, new_cache)."""
    x = embed_inputs(cfg, params, tokens)

    def attn_fn(p_a, h, ck, cv, window):
        return attn.paged_chunk_attention(
            cfg, p_a, h, ck, cv, block_tables, slot, offsets,
            read_pages=read_pages, window=window, lane_mask=lane_mask,
            q_lens=q_lens)

    x, new_cache = _run_stack(cfg, params, cache, x, masks, dist, attn_fn)
    return logits_from_hidden(cfg, params, x), new_cache
