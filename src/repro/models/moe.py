"""Mixture-of-Experts layer (gshard-style top-k with capacity), designed
for expert parallelism over the ``model`` mesh axis via shard_map
(DESIGN.md §4): tokens are replicated across the model axis, each shard
runs its local experts with a static capacity, partial outputs are
psum-combined. Static shapes, perfectly balanced per-shard work.

BLaST applies per-expert block masks to the expert weights (paper §2.2:
MoE is the functional equivariant of the MLP).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import sparse_mlp as sm
from repro.models.params import ParamSpec


def moe_param_specs(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    specs = {
        "router": ParamSpec((d, e), ("embed", None)),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", "ff")),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "ff")),
        "w_down": ParamSpec((e, f, d), ("experts", "ff", "embed"),
                            scale=1.0 / math.sqrt(2 * cfg.num_layers)),
    }
    if cfg.num_shared_experts:
        fs = cfg.moe_d_ff * cfg.num_shared_experts
        specs.update({
            "ws_gate": ParamSpec((d, fs), ("embed", None)),
            "ws_up": ParamSpec((d, fs), ("embed", None)),
            "ws_down": ParamSpec((fs, d), (None, "embed"),
                                 scale=1.0 / math.sqrt(2 * cfg.num_layers)),
        })
    return specs


def capacity(cfg, n_tokens: int) -> int:
    """Static per-expert capacity (GShard)."""
    c = math.ceil(cfg.top_k * n_tokens * cfg.capacity_factor
                  / cfg.num_experts)
    return max(c, 1)


def route(cfg, x_flat: jax.Array, router: jax.Array):
    """-> (top_vals (T,k) f32 normalized, top_idx (T,k) i32, aux_loss)."""
    logits = (x_flat.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, cfg.top_k)
    top_vals = top_vals / jnp.maximum(
        top_vals.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    e = cfg.num_experts
    hits = jax.nn.one_hot(top_idx[:, 0], e, dtype=jnp.float32)
    aux = e * jnp.mean(hits.mean(0) * probs.mean(0)) * e
    return top_vals, top_idx, aux


def local_expert_forward(cfg, x_flat, top_vals, top_idx, w_gate, w_up,
                         w_down, masks=None, expert_offset=0):
    """Compute the contribution of ``E_local`` experts (a shard's slice)
    to every token. All shapes static; runs identically under shard_map
    (with expert_offset = axis_index * E_local) and on a single device
    (offset 0, E_local = E).

    x_flat: (T, D); w_*: (E_l, D, F) / (E_l, F, D). Returns (T, D)."""
    t = x_flat.shape[0]
    packed = sm._is_packed(w_gate)
    e_l = w_gate.idx.shape[0] if packed else w_gate.shape[0]
    c = capacity(cfg, t)
    spec = cfg.blast
    if masks is not None and spec.enabled and not packed:
        w_gate = sm.apply_mask_ste(w_gate, masks["w_gate"], spec.b_in,
                                   spec.b_out)
        w_up = sm.apply_mask_ste(w_up, masks["w_up"], spec.b_in,
                                 spec.b_out)
        w_down = sm.apply_mask_ste(w_down, masks["w_down"], spec.b_out,
                                   spec.b_in)

    local_ids = expert_offset + jnp.arange(e_l)
    onehot = top_idx[:, :, None] == local_ids          # (T, k, E_l)
    gate = (top_vals[:, :, None] * onehot).sum(1)      # (T, E_l) f32
    hit = onehot.any(1)                                # (T, E_l)
    # per-expert token lists: kept tokens first, capped at capacity
    order = jnp.argsort(~hit, axis=0, stable=True)[:c]          # (C, E_l)
    valid = jnp.take_along_axis(hit, order, axis=0)             # (C, E_l)
    idx = jnp.where(valid, order, 0).T.astype(jnp.int32)        # (E_l, C)
    valid = valid.T                                             # (E_l, C)

    xe = jnp.take(x_flat, idx, axis=0)                          # (E_l,C,D)
    if packed:
        from repro.kernels import ops
        ye = jax.vmap(lambda x2, pg, pu, pd: ops.sparse_mlp_apply(
            x2, pg, pu, pd, act=cfg.mlp_act))(xe, w_gate, w_up, w_down)
    else:
        h = sm.act_fn(cfg.mlp_act)(
            jnp.einsum("ecd,edf->ecf", xe, w_gate.astype(xe.dtype))
        ) * jnp.einsum("ecd,edf->ecf", xe, w_up.astype(xe.dtype))
        ye = jnp.einsum("ecf,efd->ecd", h, w_down.astype(xe.dtype))
    gate_ec = jnp.take_along_axis(gate.T, idx, axis=1)          # (E_l, C)
    ye = ye * (gate_ec * valid)[..., None].astype(ye.dtype)
    out = jnp.zeros_like(x_flat)
    out = out.at[idx.reshape(-1)].add(ye.reshape(-1, ye.shape[-1]))
    return out


def shared_expert_forward(cfg, x, p, masks=None):
    """Replicated shared experts (deepseek) — a plain GLU MLP."""
    mm = None
    if masks is not None and cfg.blast.enabled:
        mm = {"w_gate": masks.get("ws_gate"), "w_up": masks.get("ws_up"),
              "w_down": masks.get("ws_down")}
    return sm.glu_mlp(x, p["ws_gate"], p["ws_up"], p["ws_down"],
                      act=cfg.mlp_act, masks=mm, spec=cfg.blast)


def moe_forward(cfg, p, x, masks=None, axis_name: str | None = None):
    """Full MoE layer. x: (B, S, D).

    ``axis_name``: if set, we are inside shard_map — p["w_*"] are the
    LOCAL expert slices and the result is psum'd by the caller; router is
    replicated. If None: single-device (all experts local)."""
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    top_vals, top_idx, aux = route(cfg, x_flat, p["router"])
    e_l = (p["w_gate"].idx.shape[0] if sm._is_packed(p["w_gate"])
           else p["w_gate"].shape[0])
    off = 0
    if axis_name is not None:
        off = jax.lax.axis_index(axis_name) * e_l
    y = local_expert_forward(cfg, x_flat, top_vals.astype(x.dtype),
                             top_idx, p["w_gate"], p["w_up"], p["w_down"],
                             masks=masks, expert_offset=off)
    if axis_name is not None:
        y = jax.lax.psum(y, axis_name)
        aux = aux  # router replicated: aux identical on all shards
    if cfg.num_shared_experts:
        y = y + shared_expert_forward(cfg, x, p, masks).reshape(-1, d)
    return y.reshape(b, s, d), aux
