"""Model registry: family -> implementation module, plus generic
init / abstract-params / forward / decode entry points used by the
training loop, serving loop, and dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import params as pmod
from repro.models import rwkv6, transformer, whisper, zamba


def module_for(cfg):
    return {
        "dense": transformer, "moe": transformer, "vlm": transformer,
        "ssm": rwkv6, "hybrid": zamba, "audio": whisper,
    }[cfg.family]


def param_specs(cfg):
    return module_for(cfg).param_specs(cfg)


def init_params(cfg, rng):
    return pmod.init_params(param_specs(cfg), rng)


def abstract_params(cfg):
    return pmod.abstract_params(param_specs(cfg))


def axes_tree(cfg):
    return pmod.axes_tree(param_specs(cfg))


def sparse_paths(cfg):
    return module_for(cfg).sparse_paths(cfg)


def dense_layer_flags(cfg):
    return module_for(cfg).dense_layer_flags(cfg)


def forward(cfg, params, tokens, **kw):
    return module_for(cfg).forward(cfg, params, tokens, **kw)


def init_cache(cfg, batch, max_len, **kw):
    return module_for(cfg).init_cache(cfg, batch, max_len, **kw)


def abstract_cache(cfg, batch, max_len, **kw):
    return module_for(cfg).abstract_cache(cfg, batch, max_len, **kw)


def decode_step(cfg, params, cache, tokens, pos, **kw):
    """One decode step. ``pos`` is the cache write slot — scalar for a
    synchronized batch, (B,) vector for per-lane frontiers (transformer
    families only; see transformer.decode_step)."""
    return module_for(cfg).decode_step(cfg, params, cache, tokens, pos,
                                       **kw)


def supports_prefill_chunk(cfg) -> bool:
    return hasattr(module_for(cfg), "prefill_chunk")


def supports_paged(cfg) -> bool:
    """Paged KV pool + block-table attention (transformer families)."""
    return hasattr(module_for(cfg), "paged_decode_step")


def supports_mixed(cfg) -> bool:
    """Mixed decode+prefill batches: ``paged_prefill_chunk`` accepting
    per-lane start slots + ``q_lens`` (transformer families — the mixed
    step rides on the paged chunk path, so paged support implies it)."""
    return supports_paged(cfg)


def init_paged_cache(cfg, n_pages, page_size, **kw):
    """Shared paged KV pool (layers, n_pages, page_size, KV, hd); see
    transformer.init_paged_cache."""
    return module_for(cfg).init_paged_cache(cfg, n_pages, page_size, **kw)


def paged_decode_step(cfg, params, cache, tokens, pos, block_tables, *,
                      read_pages, **kw):
    """One decode step over the paged pool: ``pos`` (B,) logical slots,
    ``block_tables`` (B, max_pages), ``read_pages`` static — attention
    reads only each lane's first ``read_pages`` pages."""
    return module_for(cfg).paged_decode_step(
        cfg, params, cache, tokens, pos, block_tables,
        read_pages=read_pages, **kw)


def paged_prefill_chunk(cfg, params, cache, tokens, slot, offsets,
                        block_tables, *, read_pages, **kw):
    """Chunked prefill through the block tables (paged pool)."""
    return module_for(cfg).paged_prefill_chunk(
        cfg, params, cache, tokens, slot, offsets, block_tables,
        read_pages=read_pages, **kw)


def prefill_chunk(cfg, params, cache, tokens, slot, offsets, **kw):
    """Batched chunked prefill (KV-cache families). Writes the chunk's
    K/V at cache slots [slot, slot+C); see transformer.prefill_chunk."""
    mod = module_for(cfg)
    if not hasattr(mod, "prefill_chunk"):
        raise NotImplementedError(
            f"family {cfg.family!r} has no chunked prefill; use the "
            "token-by-token serve_loop.prefill_with_decode path")
    return mod.prefill_chunk(cfg, params, cache, tokens, slot, offsets,
                             **kw)


def count_params(cfg, active_only: bool = False) -> int:
    """Parameter count from the spec tree (no allocation). With
    ``active_only`` MoE expert stacks count only top_k (+shared) experts
    — the N in MODEL_FLOPS = 6·N_active·D."""
    specs = param_specs(cfg)
    leaves, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, pmod.ParamSpec))
    total = 0
    for s in leaves:
        n = 1
        for d in s.shape:
            n *= d
        if active_only and "experts" in s.axes:
            n = n // cfg.num_experts * cfg.top_k
        total += n
    return total


def init_masks(cfg, params):
    """BLaST mask tree for this model (all-kept at init)."""
    from repro.core import sparse_mlp as sm
    if not cfg.blast.enabled:
        return {}
    return sm.init_masks(cfg.blast, params, sparse_paths(cfg),
                         dense_layer_flags(cfg))
