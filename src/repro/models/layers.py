"""Shared building blocks: norms, rotary embeddings, softcap."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jax.Array, weight: jax.Array, bias: jax.Array | None,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def norm(kind: str, x, weight, bias=None):
    if kind == "rmsnorm":
        return rmsnorm(x, weight)
    return layernorm(x, weight, bias)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """gemma2-style logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32. Half-split convention."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def remat_policy(cfg):
    import jax
    return {
        "nothing_saveable": jax.checkpoint_policies.nothing_saveable,
        "dots_saveable": jax.checkpoint_policies.dots_saveable,
        "dots_with_no_batch_dims_saveable":
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[cfg.remat_policy]
