"""RWKV6 "Finch" (arXiv:2404.05892): attention-free LM with
data-dependent per-channel decay.

Per layer: time-mix (the token mixer — chunked linear attention from
``linear_scan.py``, heads of 64) and channel-mix (the MLP analogue:
square-ReLU two-matrix MLP — this is where BLaST applies).

Simplifications vs the reference (DESIGN.md §8): static token-shift
interpolation weights (mu) instead of the data-dependent ddlerp; the
data-dependent decay LoRA (the defining Finch feature) IS implemented.
Heads are zero-padded 40→48 for TP divisibility.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import sparse_mlp as sm
from repro.models.layers import layernorm
from repro.models.linear_scan import (chunked_linear_attention,
                                      recurrent_step)
from repro.models.params import ParamSpec

LORA_RANK = 64


def _heads(cfg):
    h = max(cfg.num_heads, cfg.pad_heads_to or 0)
    return h, cfg.head_dim, h * cfg.head_dim   # (H, dk, inner)


def layer_param_specs(cfg) -> dict:
    d = cfg.d_model
    h, dk, inner = _heads(cfg)
    f = cfg.d_ff
    out_scale = 1.0 / math.sqrt(2 * cfg.num_layers)
    return {
        "ln1_scale": ParamSpec((d,), ("embed",), init="ones"),
        "ln1_bias": ParamSpec((d,), ("embed",), init="zeros"),
        "ln2_scale": ParamSpec((d,), ("embed",), init="ones"),
        "ln2_bias": ParamSpec((d,), ("embed",), init="zeros"),
        "tmix": {
            "mu_r": ParamSpec((d,), ("embed",), init="zeros"),
            "mu_k": ParamSpec((d,), ("embed",), init="zeros"),
            "mu_v": ParamSpec((d,), ("embed",), init="zeros"),
            "mu_g": ParamSpec((d,), ("embed",), init="zeros"),
            "mu_w": ParamSpec((d,), ("embed",), init="zeros"),
            "w_r": ParamSpec((d, h, dk), ("embed", "heads", "head_dim")),
            "w_k": ParamSpec((d, h, dk), ("embed", "heads", "head_dim")),
            "w_v": ParamSpec((d, h, dk), ("embed", "heads", "head_dim")),
            "w_g": ParamSpec((d, h, dk), ("embed", "heads", "head_dim")),
            "w_o": ParamSpec((h, dk, d), ("heads", "head_dim", "embed"),
                             scale=out_scale),
            # data-dependent decay: w = w0 + tanh(x A) B   (Finch LoRA)
            "w0": ParamSpec((h, dk), ("heads", "head_dim"), init="zeros"),
            "lora_a": ParamSpec((d, LORA_RANK), ("embed", None)),
            "lora_b": ParamSpec((LORA_RANK, h, dk),
                                (None, "heads", "head_dim")),
            "u": ParamSpec((h, dk), ("heads", "head_dim"), init="zeros"),
            "ln_x_scale": ParamSpec((h, dk), ("heads", "head_dim"),
                                    init="ones"),
        },
        "mlp": {
            "mu_k": ParamSpec((d,), ("embed",), init="zeros"),
            "mu_r": ParamSpec((d,), ("embed",), init="zeros"),
            "w_in": ParamSpec((d, f), ("embed", "ff")),
            "w_out": ParamSpec((f, d), ("ff", "embed"), scale=out_scale),
            "w_recept": ParamSpec((d, d), ("embed", "embed2")),
        },
    }


def param_specs(cfg) -> dict:
    from repro.models.transformer import _norm_specs, _stack_specs
    specs = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                           ("vocab", "embed"), init="embed"),
        "layers": _stack_specs(layer_param_specs(cfg), cfg.num_layers),
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab_size),
                             ("embed", "vocab"), init="embed"),
    }
    specs.update(_norm_specs(cfg, "ln_f"))
    return specs


def sparse_paths(cfg) -> list[str]:
    return ["layers/mlp/w_in", "layers/mlp/w_out"]


def dense_layer_flags(cfg):
    idx = jnp.arange(cfg.num_layers)
    return idx >= (cfg.num_layers - cfg.blast.dense_last)


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / `last` at t=0). x: (B,S,D)."""
    pad = jnp.zeros_like(x[:, :1]) if last is None else last[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def time_mix(cfg, p, x, *, state=None, x_last=None, decode=False):
    """x: (B,S,D). Returns (y, (new_state, new_x_last))."""
    b, s, d = x.shape
    h, dk, inner = _heads(cfg)
    xs = _shift(x, x_last)
    r = jnp.einsum("bsd,dhk->bshk", _mix(x, xs, p["mu_r"]), p["w_r"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", _mix(x, xs, p["mu_k"]), p["w_k"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", _mix(x, xs, p["mu_v"]), p["w_v"].astype(x.dtype))
    g = jnp.einsum("bsd,dhk->bshk", _mix(x, xs, p["mu_g"]), p["w_g"].astype(x.dtype))
    xw = _mix(x, xs, p["mu_w"])
    lora = jnp.einsum("bsr,rhk->bshk",
                      jnp.tanh(xw @ p["lora_a"].astype(x.dtype)),
                      p["lora_b"].astype(x.dtype))
    log_w = -jnp.exp(jnp.clip(
        p["w0"].astype(jnp.float32) + lora.astype(jnp.float32), -8., 4.))
    if decode:
        y, new_state = recurrent_step(
            r[:, 0], k[:, 0], v[:, 0], log_w[:, 0], state,
            u=p["u"], chunk=cfg.chunk_size, include_diag="bonus")
        y = y[:, None]
    else:
        y, new_state = chunked_linear_attention(
            r, k, v, log_w, u=p["u"], chunk=cfg.chunk_size,
            initial_state=state, include_diag="bonus")
    # per-head groupnorm, then gate
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + 64e-5)
    yf = yf * p["ln_x_scale"].astype(jnp.float32)
    y = (yf.astype(x.dtype) * jax.nn.silu(g))
    out = jnp.einsum("bshk,hkd->bsd", y, p["w_o"].astype(x.dtype))
    return out, (new_state, x[:, -1])


def channel_mix(cfg, p, x, masks=None, x_last=None):
    """Square-ReLU channel mix — the BLaST-sparse MLP."""
    xs = _shift(x, x_last)
    xk = _mix(x, xs, p["mu_k"])
    xr = _mix(x, xs, p["mu_r"])
    y = sm.mlp2(xk, p["w_in"], p["w_out"], act="relu", masks=masks,
                spec=cfg.blast, square=True)
    recept = jax.nn.sigmoid(xr @ p["w_recept"].astype(x.dtype))
    return recept * y, x[:, -1]


def forward(cfg, params, tokens, *, masks=None, dist=None, **_):
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    from repro.models.transformer import _layer_masks, logits_from_hidden
    if dist is not None:
        x = dist.constrain_seq(x)
    lmasks = _layer_masks(masks, "layers")

    def body(carry, xs_):
        x, aux = carry
        p_l, m_l = xs_
        h = layernorm(x, p_l["ln1_scale"], p_l["ln1_bias"])
        a, _ = time_mix(cfg, p_l["tmix"], h)
        x = x + a
        h = layernorm(x, p_l["ln2_scale"], p_l["ln2_bias"])
        m, _ = channel_mix(cfg, p_l["mlp"], h, masks=m_l)
        x = x + m
        if dist is not None:
            x = dist.constrain_seq(x)
        return (x, aux), None

    if cfg.remat:
        from repro.models.layers import remat_policy
        body = jax.checkpoint(body, policy=remat_policy(cfg))
    (x, _), _ = jax.lax.scan(body, (x, 0.0), (params["layers"], lmasks))
    return logits_from_hidden(cfg, params, x, dist), 0.0


# ------------------------------------------------------------------ decode
def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    h, dk, _ = _heads(cfg)
    L = cfg.num_layers
    return {
        "state": jnp.zeros((L, batch, h, dk, dk), jnp.float32),
        "x_tmix": jnp.zeros((L, batch, cfg.d_model), dtype),
        "x_cmix": jnp.zeros((L, batch, cfg.d_model), dtype),
    }


def abstract_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


def decode_step(cfg, params, cache, tokens, pos, *, masks=None, dist=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    from repro.models.transformer import _layer_masks, logits_from_hidden
    lmasks = _layer_masks(masks, "layers")

    def body(carry, xs_):
        x, = carry
        p_l, m_l, st, xt, xc = xs_
        h = layernorm(x, p_l["ln1_scale"], p_l["ln1_bias"])
        a, (new_st, new_xt) = time_mix(cfg, p_l["tmix"], h,
                                       state=st, x_last=xt, decode=True)
        x = x + a
        h = layernorm(x, p_l["ln2_scale"], p_l["ln2_bias"])
        m, new_xc = channel_mix(cfg, p_l["mlp"], h, masks=m_l, x_last=xc)
        return (x + m,), (new_st, new_xt.astype(xt.dtype),
                          new_xc.astype(xc.dtype))

    (x,), (st, xt, xc) = jax.lax.scan(
        body, (x,), (params["layers"], lmasks, cache["state"],
                     cache["x_tmix"], cache["x_cmix"]))
    new_cache = {"state": st, "x_tmix": xt, "x_cmix": xc}
    return logits_from_hidden(cfg, params, x), new_cache
