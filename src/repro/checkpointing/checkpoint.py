"""Fault-tolerant checkpointing (no orbax in the container; pure
numpy + atomic renames).

Properties required at 1000-node scale (DESIGN.md §4, hardened per
ISSUE 8):
  * checkpoints are stored LOGICALLY (full arrays, path-keyed npz), not
    per-device — restore can reshard onto ANY mesh (elastic restart
    after losing a pod);
  * atomic AND non-destructive: write to <dir>.tmp, park any existing
    <dir> at <dir>.old, rename the tmp into place, then drop the .old —
    there is no instant at which the previous intact checkpoint has
    been deleted but its replacement is not yet in place (the old
    rmtree-then-replace scheme had exactly that crash window).
    Leftovers from a killed writer are recovered on the next start: a
    parked .old whose final rename never happened is promoted back, a
    stale .tmp is dropped, and ``steps()`` never lists either;
  * INTEGRITY: every array is crc32'd into a manifest in meta.json and
    verified on restore; ``restore(step=None)`` falls back to the
    newest INTACT checkpoint (counting ``fallbacks``), and keep-k GC
    never deletes the newest intact checkpoint even when newer corrupt
    ones exist;
  * async: the array->host gather runs in the caller, the file write in
    a background thread (training continues); a write failure is
    captured and re-raised on ``wait()`` / the next ``save()`` instead
    of dying silently in the daemon thread;
  * keep-k retention + latest-intact discovery for auto-resume;
  * the data-iterator state (step) and RNG are inside the state, so
    restart replays the exact batch sequence.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib

import jax
import numpy as np

from repro.obs.trace import NULL_TRACER
from repro.training.faults import CheckpointCorruptionError


def flatten_tree(tree, prefix=""):
    """Nested dict/list tree -> flat ``{"a/b/0": leaf}`` dict. Shared
    with serving/artifact.py, which layers packed-leaf handling on top."""
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(flatten_tree(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(flatten_tree(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def unflatten_tree(template, flat):
    def rec(node, prefix):
        if isinstance(node, dict):
            return {k: rec(v, f"{prefix}{k}/") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            vals = [rec(v, f"{prefix}{i}/") for i, v in enumerate(node)]
            return type(node)(vals)
        return flat[prefix.rstrip("/")]
    return rec(template, "")


def crc32_array(a: np.ndarray) -> int:
    """The integrity primitive shared by checkpoints (restore-time
    verify), the host KV offload store, and sealed serving artifacts."""
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


# module-internal aliases, kept for existing callers
_flatten = flatten_tree
_unflatten_into = unflatten_tree
_crc = crc32_array


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        self.fallbacks = 0         # corrupt/torn ckpts skipped on restore
        self.fault_hook = None     # training/faults.py corruption port
        self.tracer = NULL_TRACER  # obs/trace.py; train_loop installs
        os.makedirs(directory, exist_ok=True)
        self._recover_leftovers()

    def _recover_leftovers(self):
        """Crash cleanup: a parked ``.old`` whose final rename never
        happened is the non-destructive swap's crash window — promote
        it back into place (it was intact when parked). Stale ``.tmp``
        dirs from a killed writer are dropped."""
        for name in sorted(os.listdir(self.dir)):
            p = os.path.join(self.dir, name)
            if name.endswith(".old"):
                final = p[: -len(".old")]
                if os.path.exists(final):
                    shutil.rmtree(p, ignore_errors=True)
                else:
                    os.replace(p, final)
            elif name.endswith(".tmp"):
                shutil.rmtree(p, ignore_errors=True)

    # ----------------------------------------------------------- save
    def save(self, step: int, state, blocking: bool = False,
             extra: dict | None = None):
        """Gather to host synchronously, write asynchronously. Raises a
        previous async write's captured exception (if any) HERE, before
        gathering for the new save."""
        from repro.training.step import TrainState
        t_save = time.monotonic()
        tree = {"step": state.step, "params": state.params,
                "opt_state": state.opt_state, "masks": state.masks,
                "rng": state.rng} if isinstance(state, TrainState) \
            else state
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        self.wait()

        def write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            meta = {"step": int(step),
                    "checksums": {k: _crc(v) for k, v in host.items()},
                    **(extra or {})}
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                # non-destructive swap: park, rename in, then drop —
                # never a window with no complete checkpoint on disk
                old = final + ".old"
                if os.path.exists(old):
                    shutil.rmtree(old)
                os.replace(final, old)
                os.replace(tmp, final)
                shutil.rmtree(old, ignore_errors=True)
            else:
                os.replace(tmp, final)
            if self.fault_hook is not None:
                self.fault_hook(final, step)
            self._gc()

        if blocking:
            write()
        else:
            def runner():
                try:
                    write()
                except BaseException as e:   # surfaced on wait()/save()
                    self._error = e
            self._thread = threading.Thread(target=runner, daemon=True)
            self._thread.start()
        if self.tracer.enabled:
            # host gather + (blocking) write, or gather + dispatch for
            # the async path — the part that holds up training
            self.tracer.span_at("ckpt.save", t_save, time.monotonic(),
                                step=int(step), blocking=blocking)

    def wait(self):
        """Join any in-flight write and re-raise its exception."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        """Keep-k retention that NEVER deletes the newest intact
        checkpoint: when newer checkpoints are corrupt, the newest one
        that verifies is protected even if it falls outside keep-k."""
        steps = self.steps()
        if len(steps) <= self.keep:
            return
        protect = set(steps[-self.keep:])
        for s in reversed(steps):
            if self.verify(s):
                protect.add(s)
                break
        for s in steps:
            if s not in protect:
                shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                              ignore_errors=True)

    # -------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if (name.startswith("step_") and not name.endswith(".tmp")
                    and not name.endswith(".old")):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def verify(self, step: int) -> bool:
        """Full integrity check: meta.json parses, the array set
        matches the manifest, and every array's crc32 matches. Legacy
        checkpoints without a manifest pass on a load test alone."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(d, "meta.json")) as f:
                meta = json.load(f)
            cks = meta.get("checksums")
            with np.load(os.path.join(d, "arrays.npz")) as z:
                names = list(z.files)
                if cks is None:
                    for k in names:
                        z[k]
                    return True
                if set(names) != set(cks):
                    return False
                return all(_crc(z[k]) == cks[k] for k in names)
        except Exception:
            return False

    def latest_intact_step(self) -> int | None:
        for s in reversed(self.steps()):
            if self.verify(s):
                return s
        return None

    def _load(self, template, step: int, shardings):
        path = os.path.join(self.dir, f"step_{step:08d}", "arrays.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree

    def restore(self, template, step: int | None = None,
                shardings=None):
        """Restore into the structure of ``template``. With
        ``shardings`` (same tree structure), arrays are placed sharded —
        onto WHATEVER mesh the shardings reference (elastic reshard).

        An explicit ``step`` is verified and raises
        ``CheckpointCorruptionError`` on a mismatch. With ``step=None``
        the newest INTACT checkpoint is restored — corrupt or torn
        newer ones are skipped automatically (counted in
        ``fallbacks``)."""
        with self.tracer.span("ckpt.restore",
                              step=-1 if step is None else int(step)):
            if step is not None:
                if not self.verify(step):
                    raise CheckpointCorruptionError(step, self.dir)
                return self._load(template, step, shardings)
            steps = self.steps()
            if not steps:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
            for skipped, s in enumerate(reversed(steps)):
                if self.verify(s):
                    if skipped:
                        # newer checkpoints were corrupt/torn: the
                        # integrity manifest routed restore past them
                        self.tracer.event("ckpt.fallback",
                                          skipped=skipped, to_step=s)
                    self.fallbacks += skipped
                    return self._load(template, s, shardings)
            raise CheckpointCorruptionError(
                steps[-1], self.dir,
                "no intact checkpoint to fall back to")

    def restore_state(self, template_state, step: int | None = None,
                      shardings=None):
        """Restore a TrainState (template gives structure/dtypes)."""
        from repro.training.step import TrainState
        tmpl = {"step": template_state.step,
                "params": template_state.params,
                "opt_state": template_state.opt_state,
                "masks": template_state.masks,
                "rng": template_state.rng}
        shd = None
        if shardings is not None:
            shd = {"step": shardings.step, "params": shardings.params,
                   "opt_state": shardings.opt_state,
                   "masks": shardings.masks, "rng": shardings.rng}
        tree = self.restore(tmpl, step, shd)
        return TrainState(step=tree["step"], params=tree["params"],
                          opt_state=tree["opt_state"],
                          masks=tree["masks"], rng=tree["rng"])
