"""Fault-tolerant checkpointing (no orbax in the container; pure
numpy + atomic renames).

Properties required at 1000-node scale (DESIGN.md §4):
  * checkpoints are stored LOGICALLY (full arrays, path-keyed npz), not
    per-device — restore can reshard onto ANY mesh (elastic restart
    after losing a pod);
  * atomic: write to <dir>.tmp then os.replace; a crash mid-write never
    corrupts the latest checkpoint;
  * async: the array->host gather runs in the caller, the file write in
    a background thread (training continues);
  * keep-k retention + 'latest' discovery for auto-resume;
  * the data-iterator state (step) and RNG are inside the state, so
    restart replays the exact batch sequence.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten_into(template, flat):
    def rec(node, prefix):
        if isinstance(node, dict):
            return {k: rec(v, f"{prefix}{k}/") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            vals = [rec(v, f"{prefix}{i}/") for i, v in enumerate(node)]
            return type(node)(vals)
        return flat[prefix.rstrip("/")]
    return rec(template, "")


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------- save
    def save(self, step: int, state, blocking: bool = False,
             extra: dict | None = None):
        """Gather to host synchronously, write asynchronously."""
        from repro.training.step import TrainState
        tree = {"step": state.step, "params": state.params,
                "opt_state": state.opt_state, "masks": state.masks,
                "rng": state.rng} if isinstance(state, TrainState) \
            else state
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        self.wait()

        def write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            meta = {"step": int(step), **(extra or {})}
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template, step: int | None = None,
                shardings=None):
        """Restore into the structure of ``template``. With
        ``shardings`` (same tree structure), arrays are placed sharded —
        onto WHATEVER mesh the shardings reference (elastic reshard)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}", "arrays.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree

    def restore_state(self, template_state, step: int | None = None,
                      shardings=None):
        """Restore a TrainState (template gives structure/dtypes)."""
        from repro.training.step import TrainState
        tmpl = {"step": template_state.step,
                "params": template_state.params,
                "opt_state": template_state.opt_state,
                "masks": template_state.masks,
                "rng": template_state.rng}
        shd = None
        if shardings is not None:
            shd = {"step": shardings.step, "params": shardings.params,
                   "opt_state": shardings.opt_state,
                   "masks": shardings.masks, "rng": shardings.rng}
        tree = self.restore(tmpl, step, shd)
        return TrainState(step=tree["step"], params=tree["params"],
                          opt_state=tree["opt_state"],
                          masks=tree["masks"], rng=tree["rng"])
