"""Host-side KV offload store for lane preemption.

Under page pressure the engine may PREEMPT a low-priority lane instead
of leaving a more urgent request page-blocked: the lane's exclusively
owned pages are downloaded (device -> host) here, released to the pool
for the urgent admission, and scattered back into freshly allocated
pages when the lane is restored — decode resumes at the saved frontier
with zero re-prefilled tokens. This extends BLaST's memory story to
multi-tenant serving: KV that would otherwise be recomputed (a full
re-prefill) round-trips through host RAM instead.

Only the BOOKKEEPING lives here; the device transfers are the engine's
jitted gather/scatter steps (serving/step.py). Records are keyed by
request uid and carry the LOGICAL page indices the data came from, so
restore can interleave offloaded pages with the ones that never left
the device (prefix-cache-shared pages stay pinned through preemption —
their refcount keeps the on-device KV alive and they are never
offloaded while another reader holds them).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class OffloadRecord:
    """One preempted lane's host-resident KV.

    ``logical`` are the lane's logical page indices (positions in its
    block table) the arrays cover, in the same order as axis 1 of
    ``k``/``v`` ((layers, n, page_size, kv, hd) each)."""
    logical: list[int]
    k: np.ndarray
    v: np.ndarray

    @property
    def nbytes(self) -> int:
        return self.k.nbytes + self.v.nbytes


class HostKVStore:
    """uid -> OffloadRecord map with a bytes high-water mark.

    Deliberately dumb: no eviction, no spill-to-disk — host RAM is the
    backing tier and the engine bounds residency (a record lives only
    between a lane's preemption and its restore). ``bytes_peak`` is the
    observability hook the benchmark reports."""

    def __init__(self):
        self._recs: dict[int, OffloadRecord] = {}
        self.bytes_peak = 0

    def __len__(self) -> int:
        return len(self._recs)

    def __contains__(self, uid: int) -> bool:
        return uid in self._recs

    @property
    def nbytes(self) -> int:
        return sum(r.nbytes for r in self._recs.values())

    def save(self, uid: int, logical: list[int], k: np.ndarray,
             v: np.ndarray) -> None:
        """Stash a preempted lane's downloaded pages. One record per
        uid — a lane cannot be preempted twice without a restore in
        between (the engine clears the lane at preemption)."""
        assert uid not in self._recs, f"uid {uid} already offloaded"
        assert k.shape[1] == len(logical) and v.shape[1] == len(logical)
        self._recs[uid] = OffloadRecord(list(logical), k, v)
        self.bytes_peak = max(self.bytes_peak, self.nbytes)

    def pop(self, uid: int) -> OffloadRecord | None:
        """Take (and drop) the record for ``uid``; None when the lane
        had nothing to offload (every live page was pinned-shared)."""
        return self._recs.pop(uid, None)

    def reset_peaks(self) -> None:
        self.bytes_peak = max(self.nbytes, 0)
