"""Host-side KV offload store for lane preemption and crash recovery.

Under page pressure the engine may PREEMPT a low-priority lane instead
of leaving a more urgent request page-blocked: the lane's exclusively
owned pages are downloaded (device -> host) here, released to the pool
for the urgent admission, and scattered back into freshly allocated
pages when the lane is restored — decode resumes at the saved frontier
with zero re-prefilled tokens. Crash recovery (serving/recovery.py)
uses the same store to salvage live lanes' KV across an engine-thread
rebuild. This extends BLaST's memory story to multi-tenant serving: KV
that would otherwise be recomputed (a full re-prefill) round-trips
through host RAM instead.

Only the BOOKKEEPING lives here; the device transfers are the engine's
jitted gather/scatter steps (serving/step.py). Records are keyed by
request uid and carry the LOGICAL page indices the data came from, so
restore can interleave offloaded pages with the ones that never left
the device (prefix-cache-shared pages stay pinned through preemption —
their refcount keeps the on-device KV alive and they are never
offloaded while another reader holds them).

Two hard edges, both structured errors (serving/faults.py):

  * ``capacity_bytes`` bounds host residency — a ``save`` that would
    overrun raises ``OffloadCapacityError`` BEFORE any bookkeeping, so
    the caller's device state is untouched and it can fall back
    (skip the preemption / re-prefill instead of salvage);
  * every page is checksummed (crc32 over its K and V bytes) at save
    and verified at ``pop`` — host-RAM corruption of a parked page
    surfaces as ``OffloadCorruptionError`` naming the bad logical
    pages, failing ONLY that request instead of silently feeding
    garbage KV back into the pool.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.obs.trace import NULL_TRACER
from repro.serving.faults import OffloadCapacityError, OffloadCorruptionError


@dataclasses.dataclass
class OffloadRecord:
    """One preempted lane's host-resident KV.

    ``logical`` are the lane's logical page indices (positions in its
    block table) the arrays cover, in the same order as axis 1 of
    ``k``/``v`` ((layers, n, page_size, kv, hd) each). ``checksums``
    holds one crc32 per page over that page's K then V bytes."""
    logical: list[int]
    k: np.ndarray
    v: np.ndarray
    checksums: list[int] | None = None

    @property
    def nbytes(self) -> int:
        return self.k.nbytes + self.v.nbytes

    def page_crc(self, i: int) -> int:
        return zlib.crc32(np.ascontiguousarray(self.v[:, i]).tobytes(),
                          zlib.crc32(
                              np.ascontiguousarray(self.k[:, i]).tobytes()))


class HostKVStore:
    """uid -> OffloadRecord map with capacity + integrity enforcement.

    Deliberately dumb storage: no eviction, no spill-to-disk — host RAM
    is the backing tier, ``capacity_bytes`` bounds it (None = legacy
    unbounded), and the engine bounds residency (a record lives only
    between a lane's preemption/salvage and its restore).
    ``bytes_peak`` is the observability hook the benchmark reports
    against the limit. ``fault_hook`` (serving/faults.py) is called
    with each record AFTER its checksums are computed — the chaos
    suite's bit-flip port, standing in for real host-memory rot."""

    def __init__(self, capacity_bytes: int | None = None):
        self._recs: dict[int, OffloadRecord] = {}
        self.capacity_bytes = capacity_bytes
        self.bytes_peak = 0
        self.fault_hook = None
        # span tracer (obs/trace.py); the engine installs its own
        self.tracer = NULL_TRACER

    def __len__(self) -> int:
        return len(self._recs)

    def __contains__(self, uid: int) -> bool:
        return uid in self._recs

    @property
    def nbytes(self) -> int:
        return sum(r.nbytes for r in self._recs.values())

    def save(self, uid: int, logical: list[int], k: np.ndarray,
             v: np.ndarray) -> None:
        """Stash a preempted lane's downloaded pages. One record per
        uid — a lane cannot be preempted twice without a restore in
        between (the engine clears the lane at preemption). Raises
        ``OffloadCapacityError`` (with no state change) when the byte
        budget cannot hold the record."""
        assert uid not in self._recs, f"uid {uid} already offloaded"
        assert k.shape[1] == len(logical) and v.shape[1] == len(logical)
        rec = OffloadRecord(list(logical), k, v)
        if (self.capacity_bytes is not None
                and self.nbytes + rec.nbytes > self.capacity_bytes):
            raise OffloadCapacityError(rec.nbytes, self.capacity_bytes,
                                       self.nbytes)
        rec.checksums = [rec.page_crc(i) for i in range(len(logical))]
        if self.fault_hook is not None:
            self.fault_hook(rec)
        self._recs[uid] = rec
        self.bytes_peak = max(self.bytes_peak, self.nbytes)
        if self.tracer.enabled:
            self.tracer.event("offload.save", uid=uid,
                              pages=len(logical), bytes=rec.nbytes)

    def pop(self, uid: int) -> OffloadRecord | None:
        """Take (and drop) the record for ``uid``, verifying every
        page's checksum; None when the lane had nothing to offload
        (every live page was pinned-shared). A failed verify raises
        ``OffloadCorruptionError`` — the record is already dropped, so
        the engine fails that one request and moves on."""
        rec = self._recs.pop(uid, None)
        if rec is None:
            return None
        if rec.checksums is not None:
            bad = [lg for i, lg in enumerate(rec.logical)
                   if rec.page_crc(i) != rec.checksums[i]]
            if bad:
                self.tracer.event("offload.corrupt", uid=uid,
                                  bad_pages=bad)
                raise OffloadCorruptionError(uid, bad)
        if self.tracer.enabled:
            self.tracer.event("offload.pop", uid=uid,
                              pages=len(rec.logical), bytes=rec.nbytes)
        return rec

    def extend(self, uid: int, logical: list[int], k: np.ndarray,
               v: np.ndarray) -> None:
        """Append extra pages to an EXISTING record (crash salvage of a
        lane whose shared pages were pinned on-device at preemption:
        the device is going away, so the pinned remainder joins the
        offloaded pages). Same capacity/checksum discipline as save."""
        rec = self._recs[uid]
        add_bytes = k.nbytes + v.nbytes
        if (self.capacity_bytes is not None
                and self.nbytes + add_bytes > self.capacity_bytes):
            raise OffloadCapacityError(add_bytes, self.capacity_bytes,
                                       self.nbytes)
        merged = OffloadRecord(
            rec.logical + list(logical),
            np.concatenate([rec.k, k], axis=1),
            np.concatenate([rec.v, v], axis=1))
        merged.checksums = [merged.page_crc(i)
                            for i in range(len(merged.logical))]
        if self.fault_hook is not None:
            self.fault_hook(merged)
        self._recs[uid] = merged
        self.bytes_peak = max(self.bytes_peak, self.nbytes)

    def drop(self, uid: int) -> None:
        """Discard a record without restoring it (cancelled request)."""
        self._recs.pop(uid, None)

    def reset_peaks(self) -> None:
        self.bytes_peak = max(self.nbytes, 0)
