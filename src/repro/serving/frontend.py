"""Asyncio front door over the serving engine (the production API).

The engine itself is a synchronous step loop — by design: every jitted
call blocks, and bitwise parity with the oracle is proven against the
stepped form (engine.py). This module makes it servable behind real
traffic without touching that core: ONE dedicated thread steps the
engine continuously, an asyncio facade submits requests into it and
streams tokens back out as slabs / mixed steps complete.

    async with AsyncEngine(engine) as front:
        stream = await front.submit_async(prompt, max_new_tokens=64)
        async for toks in stream:      # list[int] per engine sync
            ...
        res = await stream.result()    # the engine's GenResult

Concurrency model — deliberately minimal, no locks:

  * the EVENT LOOP side only appends to plain deques (inbox, cancels)
    and sets a ``threading.Event`` (all atomic under the GIL) —
    ``submit_async`` never blocks the loop on engine work;
  * the ENGINE THREAD owns the engine exclusively: it drains the inbox
    (calling ``engine.submit`` — infeasible or load-shed requests
    reject there and the error is routed back through the caller's
    future), steps the engine while any work is in flight, and pushes
    newly generated tokens to each request's stream;
  * every hop back to the loop goes through
    ``loop.call_soon_threadsafe`` — the ONLY asyncio-sanctioned
    cross-thread entry point.

Fault tolerance (serving/faults.py + recovery.py): a WATCHDOG thread
(``watchdog_s`` / ``max_recoveries``) heartbeats the stepper. When the
stepper dies (any exception) or a step overruns the hung-step deadline,
the watchdog tears it down, runs ``Supervisor.recover`` — salvaging
live lanes' KV to the host store so they resume with zero re-prefilled
tokens, relaunching the rest deterministically — and restarts stepping;
open streams just see a pause. Only when the recovery budget is
exhausted (or recovery itself fails) do the remaining streams fail with
the structured error. A request that fails individually (quarantined
lane, cancellation, SLA deadline) surfaces as that ONE stream raising
its structured error; everyone else streams on, bitwise-unchanged.

Tokens stream per-request with slab granularity: the engine syncs the
host once per decode slab (``slab_k`` tokens) or mixed step, so that is
the natural flush unit — each ``__anext__`` yields the batch of tokens
that landed at one sync. Backpressure is the engine's own admission
control (lanes + page gate + SLA scheduler + bounded-queue load
shedding); the front end adds none.

``await front.aclose()`` (or leaving the ``async with``) drains all
in-flight work, then joins the threads and finalizes engine stats —
``engine.stats`` is complete afterwards. Any stream still unfinished at
teardown (a crashed engine past its recovery budget, or inbox entries
that never submitted) is failed with ``RequestCancelledError`` instead
of hanging its consumer forever.
"""
from __future__ import annotations

import asyncio
import threading
import time
from collections import deque

import numpy as np

from repro.serving.faults import EngineHangError, RequestCancelledError
from repro.serving.recovery import Supervisor

_DONE = object()


class TokenStream:
    """One request's async token stream + final result.

    Async-iterating yields ``list[int]`` batches (one per engine host
    sync — slab-granular); ``await stream.result()`` returns the
    engine's ``GenResult`` once the request finishes, or raises its
    structured error if it failed (quarantine / cancel / deadline).
    Created by ``AsyncEngine.submit_async``; all mutation happens on
    the engine thread through the ``*_threadsafe`` methods."""

    def __init__(self, loop: asyncio.AbstractEventLoop):
        self._loop = loop
        self._q: asyncio.Queue = asyncio.Queue()
        self._submitted = loop.create_future()   # -> uid, or raises
        self._result = loop.create_future()      # -> GenResult
        self._front: "AsyncEngine | None" = None
        self._cancelled = False

    @property
    def uid(self) -> int:
        """Engine-assigned request uid (valid once submitted)."""
        return self._submitted.result()

    # ---- engine-thread side (cross-thread via call_soon_threadsafe)
    def _call(self, fn) -> None:
        try:
            self._loop.call_soon_threadsafe(fn)
        except RuntimeError:
            pass   # loop already closed: the consumer is gone

    def _submit_ok_threadsafe(self, uid: int) -> None:
        self._call(lambda: self._submitted.set_result(uid))

    def _reject_threadsafe(self, exc: BaseException) -> None:
        # submit-time rejection (infeasible or load-shed request): the
        # exception surfaces from ``await submit_async`` — the stream is
        # never handed to the caller, so the result future just closes
        def fail():
            if not self._submitted.done():
                self._submitted.set_exception(exc)
            if not self._result.done():
                self._result.set_result(None)
            self._q.put_nowait(_DONE)
        self._call(fail)

    def _push_threadsafe(self, toks: list[int]) -> None:
        self._call(lambda: self._q.put_nowait(list(toks)))

    def _finish_threadsafe(self, res) -> None:
        def fin():
            if not self._result.done():
                self._result.set_result(res)
            self._q.put_nowait(_DONE)
        self._call(fin)

    def _fail_threadsafe(self, exc: BaseException) -> None:
        # structured per-request failure, engine crash past its
        # recovery budget, or shutdown sweep: iteration ends and
        # ``result()`` raises. No-op on an already-finished stream —
        # that is what makes the shutdown sweep and double-cancel safe.
        def fail():
            if not self._submitted.done():
                self._submitted.set_exception(exc)
            if not self._result.done():
                self._result.set_exception(exc)
            self._q.put_nowait(_DONE)
        self._call(fail)

    # ---- event-loop side
    def __aiter__(self) -> "TokenStream":
        return self

    async def __anext__(self) -> list[int]:
        item = await self._q.get()
        if item is _DONE:
            raise StopAsyncIteration
        return item

    async def result(self):
        """The engine's ``GenResult`` (awaits completion); raises the
        structured error when the request failed."""
        return await self._result

    async def cancel(self) -> None:
        """Cancel this request wherever it is — still in the inbox,
        queued, decoding, or preempted. The engine frees its lane and
        pages (nothing is donated to the prefix cache) and the stream
        ends with ``RequestCancelledError`` swallowed here. Idempotent:
        safe to call twice, or after the request already finished (then
        it does nothing)."""
        if self._front is None or self._result.done():
            return
        self._cancelled = True
        if self._submitted.done():
            if self._submitted.exception() is None:
                self._front._cancels.append(self._submitted.result())
        else:
            # not yet submitted: the drain rejects flagged entries; if
            # submission already raced past the flag, route the cancel
            # once the uid lands
            def _then(fut):
                if not fut.cancelled() and fut.exception() is None:
                    self._front._cancels.append(fut.result())
                    self._front._wake.set()
            self._submitted.add_done_callback(_then)
        self._front._wake.set()
        try:
            await self._result
        except Exception:
            pass     # the cancellation (or any racing failure) itself


class AsyncEngine:
    """Asyncio facade stepping a serving ``Engine`` on its own thread.

    The engine must not be driven by anyone else while the front end
    owns it. ``idle_wait_s`` bounds the idle-poll latency between a
    submission landing in the inbox and the thread noticing (the wake
    event short-circuits it; the timeout is only the safety net).

    ``watchdog_s`` arms the hung-step deadline: a step stuck past it is
    condemned and recovered. ``max_recoveries`` bounds how many times
    the supervisor may rebuild the engine after crashes/hangs before
    giving up and failing the remaining streams (0 = legacy behavior:
    first crash fails everything). ``recovery_log`` keeps one summary
    dict per recovery (latency, lanes salvaged/relaunched)."""

    def __init__(self, engine, *, idle_wait_s: float = 0.002,
                 watchdog_s: float | None = None,
                 max_recoveries: int = 0,
                 metrics_port: int | None = None):
        self.engine = engine
        self._idle_wait_s = idle_wait_s
        # deque.append / popleft are GIL-atomic: the loop side appends,
        # the engine thread pops — no lock needed
        self._inbox: deque = deque()
        self._cancels: deque = deque()
        # hot-swap requests (serving/hotswap.py): the engine thread
        # drains these BETWEEN steps — the slab-boundary requirement
        self._swaps: deque = deque()
        # live Prometheus scrape endpoint (None = off, 0 = ephemeral
        # port; the bound address lands in ``metrics_addr``)
        self._metrics_port = metrics_port
        self._metrics_srv = None
        self._metrics_thread: threading.Thread | None = None
        self.metrics_addr: tuple[str, int] | None = None
        self._wake = threading.Event()
        self._stop = False
        self._thread: threading.Thread | None = None
        self._streams: dict[int, TokenStream] = {}
        self._sent: dict[int, int] = {}   # uid -> tokens already pushed
        # watchdog / recovery state
        self._watchdog_s = watchdog_s
        self._max_recoveries = max_recoveries
        self._recoveries = 0
        self.recovery_log: list[dict] = []
        self._beat = time.monotonic()
        self._busy = False
        self._crash: BaseException | None = None
        self._monitor: threading.Thread | None = None
        self._mon_stop = threading.Event()

    # ------------------------------------------------------ lifecycle
    def _recovery_enabled(self) -> bool:
        return (self._recoveries < self._max_recoveries
                and self._monitor is not None and not self._stop)

    def start(self) -> "AsyncEngine":
        if self._thread is None:
            self._stop = False
            self._thread = threading.Thread(
                target=self._run, name="serving-engine", daemon=True)
            self._thread.start()
        if (self._monitor is None
                and (self._watchdog_s is not None
                     or self._max_recoveries > 0)):
            self._mon_stop.clear()
            self._monitor = threading.Thread(
                target=self._watch, name="serving-watchdog", daemon=True)
            self._monitor.start()
        if self._metrics_port is not None and self._metrics_srv is None:
            self._start_metrics_server()
        return self

    def _start_metrics_server(self) -> None:
        """Stdlib-only live ``/metrics`` endpoint: a tiny threaded HTTP
        server rendering the engine's typed registry as Prometheus text
        on every scrape. Reads are GIL-atomic snapshots of plain
        numbers — no lock against the stepping thread needed."""
        import http.server
        eng = self.engine

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):          # noqa: N802 (stdlib API name)
                if self.path.split("?")[0] not in ("/", "/metrics"):
                    self.send_response(404)
                    self.end_headers()
                    return
                body = eng.metrics.prometheus_text().encode()
                self.send_response(200)
                self.send_header(
                    "Content-Type",
                    "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):
                pass               # scrapes must not spam stderr

        srv = http.server.ThreadingHTTPServer(
            ("127.0.0.1", self._metrics_port), Handler)
        self._metrics_srv = srv
        self.metrics_addr = srv.server_address[:2]
        self._metrics_thread = threading.Thread(
            target=srv.serve_forever, name="serving-metrics",
            daemon=True)
        self._metrics_thread.start()

    async def __aenter__(self) -> "AsyncEngine":
        return self.start()

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    async def aclose(self) -> None:
        """Drain all in-flight work, stop the engine + watchdog
        threads, finalize engine stats, and fail any stream that could
        no longer finish (crashed engine past its recovery budget,
        never-submitted inbox entries) so no consumer hangs. Safe to
        call twice. Submissions after this raise."""
        self._stop = True
        self._wake.set()
        loop = asyncio.get_running_loop()
        # monitor first: no recovery may restart a stepper under us
        if self._monitor is not None:
            self._mon_stop.set()
            await loop.run_in_executor(None, self._monitor.join)
            self._monitor = None
        self.engine._condemned.set()   # abort a wedged device call
        if self._thread is not None:
            await loop.run_in_executor(None, self._thread.join)
            self._thread = None
        self.engine._condemned.clear()
        # the drain loop completes every completable request; whatever
        # is left can only be finalized by failing it
        leftovers = list(self._streams.values())
        self._streams.clear()
        self._sent.clear()
        while self._inbox:
            leftovers.append(self._inbox.popleft()[-1])
        exc = RequestCancelledError(-1, "cancelled: engine shut down")
        for s in leftovers:
            s._fail_threadsafe(exc)
        while self._swaps:
            _, _, fut, floop = self._swaps.popleft()
            try:
                floop.call_soon_threadsafe(
                    lambda f=fut: f.done() or f.set_exception(
                        RuntimeError("engine shut down mid-swap")))
            except RuntimeError:
                pass
        if self._metrics_srv is not None:
            self._metrics_srv.shutdown()
            self._metrics_srv.server_close()
            self._metrics_srv = None
            if self._metrics_thread is not None:
                await loop.run_in_executor(
                    None, self._metrics_thread.join)
                self._metrics_thread = None

    # --------------------------------------------------------- submit
    async def submit_async(self, prompt, max_new_tokens: int = 32, *,
                           priority: int = 0,
                           deadline_s: float | None = None) -> TokenStream:
        """Queue one request; resolves once the engine accepted it (an
        infeasible request raises ``ValueError`` here and a load-shed
        one ``BackpressureError``, synchronously with the engine's own
        submit semantics). ``priority`` / ``deadline_s`` pass through
        to the scheduler — see serving/scheduler.py."""
        if self._thread is None or self._stop:
            raise RuntimeError(
                "AsyncEngine is not running — use 'async with "
                "AsyncEngine(engine)' or call start()")
        stream = TokenStream(asyncio.get_running_loop())
        stream._front = self
        self._inbox.append((np.asarray(prompt, np.int32), max_new_tokens,
                            priority, deadline_s, stream))
        self._wake.set()
        await stream._submitted
        return stream

    async def swap_weights_async(self, artifact_dir: str, **kw):
        """Hot-swap the serving weights from a sealed artifact without
        stopping the engine: the swap request is queued to the engine
        thread, which runs validate/stage/canary/flip BETWEEN steps (a
        slab boundary). Resolves to the live ``SwapReport`` once the
        swap FLIPPED; raises the typed ``ArtifactError`` (weights
        untouched) when the artifact fails validation or its canaries.
        Keyword args pass through to ``hotswap.swap_weights``."""
        if self._thread is None or self._stop:
            raise RuntimeError(
                "AsyncEngine is not running — use 'async with "
                "AsyncEngine(engine)' or call start()")
        loop = asyncio.get_running_loop()
        fut = loop.create_future()
        self._swaps.append((artifact_dir, kw, fut, loop))
        self._wake.set()
        return await fut

    # -------------------------------------------------- engine thread
    def _drain_swaps(self) -> None:
        while self._swaps:
            d, kw, fut, floop = self._swaps.popleft()
            try:
                out = self.engine.swap_weights(d, **kw)
                done = lambda f=fut, r=out: (  # noqa: E731
                    f.done() or f.set_result(r))
            except BaseException as e:
                done = lambda f=fut, e=e: (    # noqa: E731
                    f.done() or f.set_exception(e))
            try:
                floop.call_soon_threadsafe(done)
            except RuntimeError:
                pass           # loop gone: nobody is awaiting

    def _drain_inbox(self) -> None:
        eng = self.engine
        while self._inbox:
            prompt, mnt, prio, dl, stream = self._inbox.popleft()
            if stream._cancelled:
                stream._reject_threadsafe(RequestCancelledError(
                    -1, "cancelled before submission"))
                continue
            try:
                uid = eng.submit(prompt, mnt, priority=prio,
                                 deadline_s=dl)
            except Exception as e:
                stream._reject_threadsafe(e)
                continue
            self._streams[uid] = stream
            self._sent[uid] = 0
            stream._submit_ok_threadsafe(uid)

    def _drain_cancels(self) -> None:
        while self._cancels:
            self.engine.cancel(self._cancels.popleft())

    def _has_work(self) -> bool:
        eng = self.engine
        return bool(eng.active_lanes or len(eng.scheduler)
                    or getattr(eng, "_preempted", None)
                    or getattr(eng, "_pending_results", None))

    def _pump(self, finished) -> None:
        """Push tokens that landed at this step's host sync: the delta
        of each live lane's ``generated`` past what was already sent
        (preempted lanes simply pause — their counter survives until
        restore; crash-relaunched lanes re-base against the tokens
        emitted before the crash), then the finished requests' tails +
        results — failed requests route their structured error."""
        eng = self.engine
        recovered = getattr(eng, "_recovered_prefix", {})
        for i in eng.active_lanes:
            lane = eng.lanes[i]
            stream = self._streams.get(lane.req.uid)
            if stream is None:
                continue
            gen = lane.generated
            pre = recovered.get(lane.req.uid)
            if pre is not None:
                gen = list(pre[1]) + gen
            sent = self._sent[lane.req.uid]
            if len(gen) > sent:
                stream._push_threadsafe(gen[sent:])
                self._sent[lane.req.uid] = len(gen)
        for res in finished:
            stream = self._streams.pop(res.uid, None)
            sent = self._sent.pop(res.uid, 0)
            if stream is None:
                continue
            if res.error is not None:
                stream._fail_threadsafe(res.error)
                continue
            if len(res.generated) > sent:
                stream._push_threadsafe(
                    [int(t) for t in res.generated[sent:]])
            stream._finish_threadsafe(res)

    def _run(self) -> None:
        eng = self.engine
        try:
            while True:
                self._beat = time.monotonic()
                self._drain_cancels()
                self._drain_swaps()
                self._drain_inbox()
                if self._has_work():
                    self._busy = True
                    try:
                        finished = eng.step()
                    finally:
                        self._busy = False
                    self._pump(finished)
                elif self._stop and not self._inbox:
                    break
                else:
                    self._wake.wait(self._idle_wait_s)
                    self._wake.clear()
        except BaseException as e:
            if self._recovery_enabled():
                # die quietly with the crash stashed: streams stay
                # open, the watchdog recovers and restarts stepping
                self._crash = e
                return
            for stream in list(self._streams.values()):
                stream._fail_threadsafe(e)
            self._streams.clear()
            raise
        finally:
            eng.finalize_stats()

    # ------------------------------------------------- watchdog thread
    def _watch(self) -> None:
        """Heartbeat monitor: recovers a DEAD stepper (crash stashed by
        ``_run``) and condemns+recovers a HUNG one (a step running past
        ``watchdog_s``). Runs until aclose; every recovery spends one
        unit of ``max_recoveries``."""
        poll = min(0.01, (self._watchdog_s or 1.0) / 4)
        while not self._mon_stop.wait(poll):
            t = self._thread
            if t is None:
                continue
            if not t.is_alive():
                crash, self._crash = self._crash, None
                if crash is not None:
                    self.engine.stats["engine_crashes"] += 1
                    self._do_recover(crash)
                continue
            if (self._watchdog_s is not None and self._busy
                    and time.monotonic() - self._beat > self._watchdog_s):
                self.engine._condemned.set()
                t.join(self._watchdog_s + 1.0)
                if t.is_alive():
                    # the step overran the deadline but the call did
                    # not abort under condemnation: it is SLOW (a jit
                    # compile, a long legitimate step), not wedged — an
                    # in-process watchdog cannot kill a running device
                    # call (a real deployment would kill the device
                    # stream here). Stand down and give it a fresh
                    # deadline window.
                    self.engine._condemned.clear()
                    self._beat = time.monotonic()
                    continue
                # the condemned thread is down. If it stashed its OWN
                # exception (a crash raced the condemnation), that is
                # the real cause — classify it as a crash, not a hang
                crash, self._crash = self._crash, None
                if crash is not None and not isinstance(crash,
                                                        EngineHangError):
                    self.engine.stats["engine_crashes"] += 1
                    self._do_recover(crash)
                else:
                    self.engine.stats["watchdog_hangs"] += 1
                    self._do_recover(EngineHangError())

    def _do_recover(self, exc: BaseException) -> None:
        """One supervisor pass + stepper restart (watchdog thread; the
        stepper is confirmed dead, so the engine is ours to touch)."""
        # crash flight-recorder dump FIRST — even a budget-exhausted
        # failure leaves the last-N-spans timeline behind
        self.engine.tracer.postmortem(
            "watchdog_" + ("hang" if isinstance(exc, EngineHangError)
                           else "crash"),
            error=type(exc).__name__, recoveries=self._recoveries,
            budget=self._max_recoveries,
            open_streams=sorted(self._streams))
        if self._recoveries >= self._max_recoveries or self._stop:
            for s in list(self._streams.values()):
                s._fail_threadsafe(exc)
            self._streams.clear()
            return
        self._recoveries += 1
        try:
            summary = Supervisor(self.engine).recover(exc)
        except BaseException as e2:
            for s in list(self._streams.values()):
                s._fail_threadsafe(e2)
            self._streams.clear()
            return
        self.recovery_log.append(summary)
        self._thread = None
        self._stop = False
        self._thread = threading.Thread(
            target=self._run, name="serving-engine", daemon=True)
        self._thread.start()
        self._wake.set()
