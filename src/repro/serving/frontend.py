"""Asyncio front door over the serving engine (the production API).

The engine itself is a synchronous step loop — by design: every jitted
call blocks, and bitwise parity with the oracle is proven against the
stepped form (engine.py). This module makes it servable behind real
traffic without touching that core: ONE dedicated thread steps the
engine continuously, an asyncio facade submits requests into it and
streams tokens back out as slabs / mixed steps complete.

    async with AsyncEngine(engine) as front:
        stream = await front.submit_async(prompt, max_new_tokens=64)
        async for toks in stream:      # list[int] per engine sync
            ...
        res = await stream.result()    # the engine's GenResult

Concurrency model — deliberately minimal, no locks:

  * the EVENT LOOP side only appends to a plain deque inbox and sets a
    ``threading.Event`` (both atomic under the GIL) — ``submit_async``
    never blocks the loop on engine work;
  * the ENGINE THREAD owns the engine exclusively: it drains the inbox
    (calling ``engine.submit`` — infeasible requests reject there and
    the error is routed back through the caller's future), steps the
    engine while any work is in flight, and pushes newly generated
    tokens to each request's stream;
  * every hop back to the loop goes through
    ``loop.call_soon_threadsafe`` — the ONLY asyncio-sanctioned
    cross-thread entry point.

Tokens stream per-request with slab granularity: the engine syncs the
host once per decode slab (``slab_k`` tokens) or mixed step, so that is
the natural flush unit — each ``__anext__`` yields the batch of tokens
that landed at one sync. Backpressure is the engine's own admission
control (lanes + page gate + SLA scheduler); the front end adds none.

``await front.aclose()`` (or leaving the ``async with``) drains all
in-flight work, then joins the thread and finalizes engine stats —
``engine.stats`` is complete afterwards.
"""
from __future__ import annotations

import asyncio
import threading
from collections import deque

import numpy as np

_DONE = object()


class TokenStream:
    """One request's async token stream + final result.

    Async-iterating yields ``list[int]`` batches (one per engine host
    sync — slab-granular); ``await stream.result()`` returns the
    engine's ``GenResult`` once the request finishes. Created by
    ``AsyncEngine.submit_async``; all mutation happens on the engine
    thread through the ``*_threadsafe`` methods."""

    def __init__(self, loop: asyncio.AbstractEventLoop):
        self._loop = loop
        self._q: asyncio.Queue = asyncio.Queue()
        self._submitted = loop.create_future()   # -> uid, or raises
        self._result = loop.create_future()      # -> GenResult

    @property
    def uid(self) -> int:
        """Engine-assigned request uid (valid once submitted)."""
        return self._submitted.result()

    # ---- engine-thread side (cross-thread via call_soon_threadsafe)
    def _call(self, fn) -> None:
        try:
            self._loop.call_soon_threadsafe(fn)
        except RuntimeError:
            pass   # loop already closed: the consumer is gone

    def _submit_ok_threadsafe(self, uid: int) -> None:
        self._call(lambda: self._submitted.set_result(uid))

    def _reject_threadsafe(self, exc: BaseException) -> None:
        # submit-time rejection (infeasible request): the exception
        # surfaces from ``await submit_async`` — the stream is never
        # handed to the caller, so the result future just closes
        def fail():
            self._submitted.set_exception(exc)
            if not self._result.done():
                self._result.set_result(None)
            self._q.put_nowait(_DONE)
        self._call(fail)

    def _push_threadsafe(self, toks: list[int]) -> None:
        self._call(lambda: self._q.put_nowait(list(toks)))

    def _finish_threadsafe(self, res) -> None:
        def fin():
            if not self._result.done():
                self._result.set_result(res)
            self._q.put_nowait(_DONE)
        self._call(fin)

    def _fail_threadsafe(self, exc: BaseException) -> None:
        # engine-thread crash mid-run: every open stream raises
        def fail():
            if not self._submitted.done():
                self._submitted.set_exception(exc)
            if not self._result.done():
                self._result.set_exception(exc)
            self._q.put_nowait(_DONE)
        self._call(fail)

    # ---- event-loop side
    def __aiter__(self) -> "TokenStream":
        return self

    async def __anext__(self) -> list[int]:
        item = await self._q.get()
        if item is _DONE:
            raise StopAsyncIteration
        return item

    async def result(self):
        """The engine's ``GenResult`` (awaits completion)."""
        return await self._result


class AsyncEngine:
    """Asyncio facade stepping a serving ``Engine`` on its own thread.

    The engine must not be driven by anyone else while the front end
    owns it. ``idle_wait_s`` bounds the idle-poll latency between a
    submission landing in the inbox and the thread noticing (the wake
    event short-circuits it; the timeout is only the safety net)."""

    def __init__(self, engine, *, idle_wait_s: float = 0.002):
        self.engine = engine
        self._idle_wait_s = idle_wait_s
        # deque.append / popleft are GIL-atomic: the loop side appends,
        # the engine thread pops — no lock needed
        self._inbox: deque = deque()
        self._wake = threading.Event()
        self._stop = False
        self._thread: threading.Thread | None = None
        self._streams: dict[int, TokenStream] = {}
        self._sent: dict[int, int] = {}   # uid -> tokens already pushed

    # ------------------------------------------------------ lifecycle
    def start(self) -> "AsyncEngine":
        if self._thread is None:
            self._stop = False
            self._thread = threading.Thread(
                target=self._run, name="serving-engine", daemon=True)
            self._thread.start()
        return self

    async def __aenter__(self) -> "AsyncEngine":
        return self.start()

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    async def aclose(self) -> None:
        """Drain all in-flight work, stop the engine thread, finalize
        engine stats. Submissions after this raise."""
        self._stop = True
        self._wake.set()
        if self._thread is not None:
            loop = asyncio.get_running_loop()
            await loop.run_in_executor(None, self._thread.join)
            self._thread = None

    # --------------------------------------------------------- submit
    async def submit_async(self, prompt, max_new_tokens: int = 32, *,
                           priority: int = 0,
                           deadline_s: float | None = None) -> TokenStream:
        """Queue one request; resolves once the engine accepted it (an
        infeasible request raises ``ValueError`` here, synchronously
        with the engine's own submit semantics). ``priority`` /
        ``deadline_s`` pass through to the scheduler — see
        serving/scheduler.py."""
        if self._thread is None or self._stop:
            raise RuntimeError(
                "AsyncEngine is not running — use 'async with "
                "AsyncEngine(engine)' or call start()")
        stream = TokenStream(asyncio.get_running_loop())
        self._inbox.append((np.asarray(prompt, np.int32), max_new_tokens,
                            priority, deadline_s, stream))
        self._wake.set()
        await stream._submitted
        return stream

    # -------------------------------------------------- engine thread
    def _drain_inbox(self) -> None:
        eng = self.engine
        while self._inbox:
            prompt, mnt, prio, dl, stream = self._inbox.popleft()
            try:
                uid = eng.submit(prompt, mnt, priority=prio,
                                 deadline_s=dl)
            except Exception as e:
                stream._reject_threadsafe(e)
                continue
            self._streams[uid] = stream
            self._sent[uid] = 0
            stream._submit_ok_threadsafe(uid)

    def _pump(self, finished) -> None:
        """Push tokens that landed at this step's host sync: the delta
        of each live lane's ``generated`` past what was already sent
        (preempted lanes simply pause — their counter survives until
        restore), then the finished requests' tails + results."""
        eng = self.engine
        for i in eng.active_lanes:
            lane = eng.lanes[i]
            stream = self._streams.get(lane.req.uid)
            if stream is None:
                continue
            n = len(lane.generated)
            if n > self._sent[lane.req.uid]:
                stream._push_threadsafe(
                    lane.generated[self._sent[lane.req.uid]:n])
                self._sent[lane.req.uid] = n
        for res in finished:
            stream = self._streams.pop(res.uid, None)
            sent = self._sent.pop(res.uid, 0)
            if stream is None:
                continue
            if len(res.generated) > sent:
                stream._push_threadsafe(
                    [int(t) for t in res.generated[sent:]])
            stream._finish_threadsafe(res)

    def _run(self) -> None:
        eng = self.engine
        try:
            while True:
                self._drain_inbox()
                if (eng.active_lanes or len(eng.scheduler)
                        or getattr(eng, "_preempted", None)):
                    self._pump(eng.step())
                elif self._stop and not self._inbox:
                    break
                else:
                    self._wake.wait(self._idle_wait_s)
                    self._wake.clear()
        except BaseException as e:
            for stream in list(self._streams.values()):
                stream._fail_threadsafe(e)
            self._streams.clear()
            raise
        finally:
            eng.finalize_stats()
