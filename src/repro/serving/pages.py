"""Free-list page allocator for the shared paged KV pool.

The engine's pool holds ``n_pages`` pages of ``page_size`` cache slots
each, shared by every lane across all layers (one pool page = that page
index in EVERY layer of the (layers, n_pages, page_size, KV, hd) pool
arrays — block tables stay layer-independent). This class is the pure
host-side bookkeeping: which pages are free, which lane owns which, and
the peak-in-use watermark the serving benchmark reports as the paged
cache's true memory footprint.

Pages are handed out low-index-first so a fresh engine's early block
tables are dense and the gather stays cache-friendly; `release` returns
pages for immediate reuse (stale K/V in a reused page needs no zeroing —
the causal/offset masking that hides the dense cache's garbage tail
hides it identically through the block table, models/attention.py).
"""
from __future__ import annotations


class PagePool:
    """Host-side free list over ``n_pages`` pool pages."""

    def __init__(self, n_pages: int, page_size: int):
        assert n_pages >= 1 and page_size >= 1
        self.n_pages = n_pages
        self.page_size = page_size
        # stack, highest index on top -> alloc pops lowest-numbered first
        self._free = list(range(n_pages - 1, -1, -1))
        self.peak_in_use = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Pop ``n`` free pages; raises RuntimeError when the pool can't
        supply them (the engine's admission gate makes that a bug, not a
        runtime condition)."""
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: requested {n} pages, "
                f"{len(self._free)} free of {self.n_pages}")
        pages = [self._free.pop() for _ in range(n)]
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pages

    def release(self, pages: list[int]) -> None:
        for p in pages:
            assert 0 <= p < self.n_pages
        self._free.extend(reversed(pages))

    def slots_for(self, n_slots: int) -> int:
        """Pages covering ``n_slots`` logical cache slots."""
        return -(-n_slots // self.page_size)
