"""Refcounted free-list page allocator for the shared paged KV pool.

The engine's pool holds ``n_pages`` pages of ``page_size`` cache slots
each, shared by every lane across all layers (one pool page = that page
index in EVERY layer of the (layers, n_pages, page_size, KV, hd) pool
arrays — block tables stay layer-independent). This class is the pure
host-side bookkeeping: which pages are free, how many references each
owned page carries, and the peak-occupancy watermark the serving
benchmark reports as the paged cache's true memory footprint.

With the prefix cache (serving/prefix_cache.py) one physical page can
back the SAME prompt prefix in several lanes at once, so ownership is a
refcount, not a single owner, and every page is in exactly one of three
states:

  * **free** — on the free list (``refcount == 0``, not cached);
  * **referenced** — pinned by one or more lanes (``refcount >= 1``);
    the prefix cache may ALSO hold it (``cached``), which only matters
    once the last lane lets go;
  * **cached-idle** — held only by the prefix cache (``refcount == 0``
    and ``cached``): its KV is valid and matchable but no lane reads it,
    so it is reclaimable — LRU eviction of cold tree nodes turns it back
    into a free page under pressure.

``free_pages + referenced + cached_idle == n_pages`` always (the
allocator asserts it after every mutation). Decode NEVER writes a page
with ``refcount > 1`` — the engine copy-on-writes the shared boundary
page before a lane may touch it.

Pages are handed out low-index-first so a fresh engine's early block
tables are dense and the gather stays cache-friendly; a released page
returns for immediate reuse (stale K/V in a reused page needs no
zeroing — the causal/offset masking that hides the dense cache's
garbage tail hides it identically through the block table,
models/attention.py). Releasing a page that is already free raises
``RuntimeError`` instead of silently double-listing it — a double-free
would later hand ONE physical page to TWO lanes as if each owned it
exclusively (cross-lane KV corruption).
"""
from __future__ import annotations


class PagePool:
    """Host-side refcounted free list over ``n_pages`` pool pages."""

    def __init__(self, n_pages: int, page_size: int):
        assert n_pages >= 1 and page_size >= 1
        self.n_pages = n_pages
        self.page_size = page_size
        # stack, highest index on top -> alloc pops lowest-numbered first
        self._free = list(range(n_pages - 1, -1, -1))
        self._rc = [0] * n_pages          # lane references per page
        self._cached = [False] * n_pages  # held by the prefix cache
        self._n_ref = 0                   # pages with rc > 0
        self._n_cached_idle = 0           # cached pages with rc == 0
        self.peak_in_use = 0
        # fault-injection port (serving/faults.py): called with the
        # request size at the top of alloc; returning True fails that
        # one allocation as if the free list could not supply it
        self.fault_hook = None
        # high-water of REFERENCED pages: what live lanes pin at once.
        # This is the memory a rightsized pool must provide (cached-idle
        # pages are reclaimable on demand), and the apples-to-apples
        # peak the benchmark compares sharing-on vs sharing-off — shared
        # pages count ONCE however many lanes read them.
        self.peak_referenced = 0

    # ------------------------------------------------------------ queries
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        """Occupied pages (referenced + cached-idle): the pool's live
        memory footprint."""
        return self.n_pages - len(self._free)

    @property
    def referenced(self) -> int:
        """Pages pinned by at least one lane."""
        return self._n_ref

    @property
    def cached_idle(self) -> int:
        """Pages held ONLY by the prefix cache — reclaimable via tree
        eviction, but not on the free list."""
        return self._n_cached_idle

    @property
    def cached_pages(self) -> int:
        """Pages the prefix cache holds (idle or also lane-referenced)."""
        return sum(self._cached)

    def refcount(self, page: int) -> int:
        return self._rc[page]

    def exclusive(self, page: int) -> bool:
        """True when ONE lane holds the page and the prefix cache does
        not: releasing it frees the physical page, so its KV may be
        offloaded to the host and the page handed to someone else. A
        shared or cached page must stay pinned instead — other readers
        (or future radix matches) still need its on-device KV."""
        return self._rc[page] == 1 and not self._cached[page]

    def is_cached(self, page: int) -> bool:
        return self._cached[page]

    def _check(self) -> None:
        # O(1): the incremental counters must always partition the pool
        # (tests/test_pages_properties.py cross-checks them against a
        # full shadow model)
        free, ref, ci = len(self._free), self._n_ref, self._n_cached_idle
        assert free + ref + ci == self.n_pages, (
            f"page accounting broke: {free} free + {ref} referenced + "
            f"{ci} cached-idle != {self.n_pages}")

    # ---------------------------------------------------------- lifecycle
    def alloc(self, n: int) -> list[int]:
        """Pop ``n`` free pages at refcount 1; raises RuntimeError when
        the free list can't supply them (the engine's admission gate —
        which counts cached-idle pages it can evict first — makes that a
        bug, not a runtime condition)."""
        if self.fault_hook is not None and self.fault_hook(n):
            raise RuntimeError(
                f"injected page allocation failure ({n} pages)")
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: requested {n} pages, "
                f"{len(self._free)} free of {self.n_pages}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._rc[p] = 1
        self._n_ref += n
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        self.peak_referenced = max(self.peak_referenced, self._n_ref)
        self._check()
        return pages

    def retain(self, pages: list[int]) -> None:
        """Add one lane reference per page. A cached-idle page moves to
        referenced (prefix-cache hit pins the shared pages); a free page
        cannot be retained — it holds no live KV."""
        for p in pages:
            assert 0 <= p < self.n_pages
            if self._rc[p] == 0 and not self._cached[p]:
                raise RuntimeError(
                    f"retain of free page {p}: nothing owns it")
        for p in pages:
            if self._rc[p] == 0:            # cached-idle -> referenced
                self._n_ref += 1
                self._n_cached_idle -= 1
            self._rc[p] += 1
        self.peak_referenced = max(self.peak_referenced, self._n_ref)
        self._check()

    def release(self, pages: list[int]) -> None:
        """Drop one lane reference per page. The last reference frees
        the page — unless the prefix cache holds it, in which case it
        parks as cached-idle (evictable, not free). Releasing an
        unreferenced page raises: a double-free would put one physical
        page on the free list twice and the allocator would later hand
        it to two lanes."""
        for p in pages:
            assert 0 <= p < self.n_pages
            if self._rc[p] == 0:
                state = "cached-idle" if self._cached[p] else "free"
                raise RuntimeError(
                    f"double free of page {p}: it is already {state}")
        freed = []
        for p in pages:
            self._rc[p] -= 1
            if self._rc[p] == 0:
                self._n_ref -= 1
                if self._cached[p]:
                    self._n_cached_idle += 1
                else:
                    freed.append(p)
        self._free.extend(reversed(freed))   # recycle low-index-first
        self._check()

    # -------------------------------------------------------- prefix cache
    def cache_add(self, pages: list[int]) -> None:
        """The prefix cache takes (shared) ownership of ``pages``. Called
        while the donating lane still holds its reference, so the page
        never transits the free list; once the lane releases, the page
        parks as cached-idle instead of freeing."""
        for p in pages:
            assert 0 <= p < self.n_pages
            if self._rc[p] == 0 and not self._cached[p]:
                raise RuntimeError(
                    f"cache_add of free page {p}: donate before release")
        for p in pages:
            self._cached[p] = True
        self._check()

    def cache_drop(self, pages: list[int]) -> None:
        """Prefix-cache eviction: a cached page with no lane references
        returns to the free list. Dropping a page some lane still reads
        is a bug (the tree must only evict idle nodes)."""
        for p in pages:
            assert 0 <= p < self.n_pages
            if not self._cached[p]:
                raise RuntimeError(f"cache_drop of uncached page {p}")
            if self._rc[p] > 0:
                raise RuntimeError(
                    f"cache_drop of page {p} still referenced by "
                    f"{self._rc[p]} lane(s)")
        for p in pages:
            self._cached[p] = False
            self._n_cached_idle -= 1
            self._free.append(p)
        self._check()

    def uncache(self, pages: list[int]) -> int:
        """Revoke the prefix cache's ownership of ``pages`` regardless
        of reference state — the weight hot-swap flush (hotswap.py):
        cached KV computed under retired weights must never be matched
        again. An idle page frees immediately; a page some lane still
        reads just loses its cached flag and frees on the lane's final
        release (the lane's own read of it stays valid — its KV belongs
        to the lane's admission-time generation). Uncached/free entries
        are ignored (idempotent). Returns pages freed right now."""
        freed = []
        for p in pages:
            assert 0 <= p < self.n_pages
            if not self._cached[p]:
                continue
            self._cached[p] = False
            if self._rc[p] == 0:
                self._n_cached_idle -= 1
                freed.append(p)
        self._free.extend(freed)
        self._check()
        return len(freed)

    # ------------------------------------------------------------- helpers
    def reset_peaks(self) -> None:
        """Restart both watermarks from the CURRENT state (the engine's
        ``reset_stats`` calls this so per-run peak measurements don't
        inherit earlier runs' high-water marks)."""
        self.peak_in_use = self.in_use
        self.peak_referenced = self._n_ref

    def slots_for(self, n_slots: int) -> int:
        """Pages covering ``n_slots`` logical cache slots."""
        return -(-n_slots // self.page_size)
