"""Crash recovery: rebuild a dead engine's device state, keep its work.

The engine's step loop is ATOMIC at host syncs by construction: every
jitted call is functional (``self.cache = step(...)`` only rebinds on
success) and the host mirror folds results only after
``block_until_ready`` — so however an engine thread dies (injected
crash, real exception, watchdog-condemned hang), the host-visible
``(cache, mirror, lanes)`` triple is exactly the snapshot of the last
COMPLETED sync. ``Supervisor.recover`` turns that snapshot back into a
running engine:

  1. **salvage** — every live decode lane's KV pages (slots
     ``[0, frontier)``) are downloaded to the host offload store and
     the lane is parked as a ``_Preempted`` record (``recovered=True``)
     with its exact decode state (pending token, frontier, remaining
     budget). Restore is PR 6's zero-re-prefill path: the lane resumes
     at its saved frontier, bitwise-identical to an uninterrupted run,
     with ``re_prefilled_tokens == 0``. Skipped when the fault lost the
     device (``exc.device_lost``) — there is nothing left to download;
  2. **relaunch** — lanes that could not salvage (device lost,
     mid-prefill, host store full) are re-queued AT THE HEAD as
     ``prompt + emitted`` with the remaining budget. Greedy decode is
     deterministic, so the re-prefilled continuation is bitwise what
     the dead lane would have produced; the engine re-splits the result
     at the original prompt boundary (``_recovered_prefix``);
  3. **rebuild** — fresh page pool, fresh (zeroed) device cache and
     slab state, fresh prefix cache (the old tree indexed pages of the
     dead pool); pre-existing preempted records keep their host KV —
     records with device-pinned shared pages get those pages salvaged
     into the record first (or relaunch, if the device is gone);
  4. finished-but-unswept lanes are synthesized into normal results —
     a completed request never re-runs just because the sweep had not
     reached it yet.

The jitted step functions are REUSED — shapes and donation patterns are
unchanged, so recovery costs no recompilation. Queued requests are
untouched (the scheduler is host state). The watchdog in
serving/frontend.py is the caller: it detects the dead/hung stepper
thread, invokes ``recover``, and restarts stepping.
"""
from __future__ import annotations

import time

import numpy as np

from repro.models import registry
from repro.serving.engine import GenResult, _Preempted
from repro.serving.faults import LaneFaultError, OffloadCapacityError
from repro.serving.pages import PagePool
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import Request


class Supervisor:
    """Owns crash recovery for one engine (see module docstring)."""

    def __init__(self, engine):
        self.engine = engine

    # ------------------------------------------------------------ parts
    def _classify_lanes(self, device_lost: bool, results: list,
                        relaunch: list, salvaged: list) -> None:
        eng = self.engine
        m = eng._mirror
        for i in eng.active_lanes:
            lane = eng.lanes[i]
            req, gen = lane.req, lane.generated
            done = (len(gen) >= req.max_new_tokens
                    or (eng.eos_id is not None and gen
                        and gen[-1] == eng.eos_id))
            trunc = not done and int(m["frontier"][i]) >= eng.max_len
            if done or trunc:
                # finished before the crash, sweep never reached it
                prompt, full = req.prompt, list(gen)
                pre = eng._recovered_prefix.pop(req.uid, None)
                if pre is not None:
                    prompt, full = pre[0], list(pre[1]) + full
                tt = lane.token_times
                ttft = max(0.0, tt[0] - req.queued_at) if tt else 0.0
                results.append(GenResult(req.uid, prompt,
                                         np.asarray(full, np.int32),
                                         truncated=trunc, ttft_s=ttft))
                continue
            if bool(m["faulted"][i]):
                # the finite-check verdict landed but the crash beat
                # the harvest: quarantine now
                eng.stats["lanes_quarantined"] += 1
                results.append(eng._failed_result(
                    req, gen, LaneFaultError(req.uid, i)))
                continue
            if (eng.paged and not device_lost and bool(m["live"][i])
                    and i not in eng._prefilling):
                try:
                    n_live = eng.pool.slots_for(int(m["frontier"][i]))
                    k, v = eng._download_pages(lane.pages[:n_live])
                    eng._offload.save(req.uid, list(range(n_live)), k, v)
                    eng.stats["offloaded_pages"] += n_live
                    salvaged.append(_Preempted(
                        req=req, offset=lane.offset, generated=gen,
                        token_times=lane.token_times,
                        pending=int(m["pending"][i]),
                        frontier=int(m["frontier"][i]),
                        remaining=int(m["remaining"][i]),
                        n_pages=len(lane.pages), pinned={},
                        recovered=True, gen=lane.gen))
                    continue
                except OffloadCapacityError:
                    pass        # host store full: fall through
                except Exception:
                    pass        # device download failed: fall through
            relaunch.append((req, list(gen), lane.gen))

    def _resolve_preempted(self, device_lost: bool,
                           relaunch: list) -> list:
        """Pre-existing preempted records survive on the host; ones
        with device-pinned shared pages need those pages pulled down
        (device alive) or a full relaunch (device lost)."""
        eng = self.engine
        keep = []
        for pre in eng._preempted:
            if not pre.pinned:
                keep.append(pre)
                continue
            if not device_lost:
                try:
                    logical = sorted(pre.pinned)
                    pages = [pre.pinned[j] for j in logical]
                    k, v = eng._download_pages(pages)
                    if pre.req.uid in eng._offload:
                        eng._offload.extend(pre.req.uid, logical, k, v)
                    else:
                        eng._offload.save(pre.req.uid, logical, k, v)
                    eng.stats["offloaded_pages"] += len(pages)
                    pre.pinned = {}
                    keep.append(pre)
                    continue
                except Exception:
                    pass
            eng._offload.drop(pre.req.uid)
            relaunch.append((pre.req, list(pre.generated), pre.gen))
        return keep

    def _rebuild(self, keep_preempted: list) -> None:
        eng = self.engine
        if eng.paged:
            eng.pool = PagePool(eng.n_pages, eng.page_size)
            if eng._faults is not None:
                eng.pool.fault_hook = eng._faults.on_alloc
            eng.cache = registry.init_paged_cache(
                eng.cfg, eng.n_pages, eng.page_size)
            if eng.pcache is not None:
                # the old radix tree indexed pages of the dead pool
                eng.pcache = PrefixCache(eng.pool)
        else:
            eng.cache = registry.init_cache(eng.cfg, eng.max_batch,
                                            eng.max_len)
        eng.lanes = [None] * eng.max_batch
        for key in eng._mirror:
            eng._mirror[key][:] = 0
        eng._prefilling.clear()
        eng._preempted = keep_preempted
        eng._dstate = None
        eng._dirty = True
        eng._condemned.clear()

    def _relaunch(self, relaunch: list) -> None:
        eng = self.engine
        reqs, deadlines = [], []
        for req, emitted, gen in relaunch:
            # remember the ORIGINAL split so results re-split there;
            # chains across repeated crashes (prompt may already be
            # orig + earlier emissions)
            orig, prev = eng._recovered_prefix.get(
                req.uid, (req.prompt, []))
            eng._recovered_prefix[req.uid] = (orig,
                                              list(prev) + list(emitted))
            # a relaunch mid-swap must re-prefill and continue under
            # its ADMISSION-TIME weights — greedy-decode determinism
            # (the bitwise recovery guarantee) only holds against the
            # same generation; the pin is dropped when the lane retires
            eng._gen_pins[req.uid] = gen
            nr = Request(
                req.uid,
                np.concatenate([req.prompt,
                                np.asarray(emitted, np.int32)]),
                req.max_new_tokens - len(emitted),
                priority=req.priority, deadline_s=req.deadline_s)
            eng.stats["re_prefilled_tokens"] += nr.prompt_len
            reqs.append(nr)
            deadlines.append(req.deadline_at)
        eng.scheduler.reinstate(reqs)
        for nr, dl in zip(reqs, deadlines):
            if dl is not None:
                nr.deadline_at = dl   # the SLA clock does not reset

    # ---------------------------------------------------------- recover
    def recover(self, exc: BaseException) -> dict:
        """Rebuild the engine after its stepper died with ``exc``.
        Returns a summary dict (latency, lanes salvaged/relaunched) —
        also appended to the engine's pending results are any requests
        that had already finished. Safe to call repeatedly (each call
        recovers the CURRENT snapshot)."""
        eng = self.engine
        t0 = time.monotonic()
        device_lost = bool(getattr(exc, "device_lost", False))
        # the flight recorder holds the last N spans BEFORE the crash:
        # freeze them first, so the rebuild below (which clears lanes)
        # cannot disturb the timeline being reported
        eng.tracer.postmortem(
            "supervisor_recover", error=type(exc).__name__,
            device_lost=device_lost,
            active_uids=[eng.lanes[i].req.uid for i in eng.active_lanes])
        results: list = []
        relaunch: list = []
        salvaged: list = []
        self._classify_lanes(device_lost, results, relaunch, salvaged)
        keep = (self._resolve_preempted(device_lost, relaunch)
                if eng.paged else [])
        self._rebuild(keep + salvaged)
        self._relaunch(relaunch)
        eng._pending_results.extend(results)
        eng.stats["recoveries"] += 1
        if eng.paged:
            eng.stats["offload_bytes_peak"] = max(
                eng.stats["offload_bytes_peak"],
                eng._offload.bytes_peak)
        latency = time.monotonic() - t0
        if eng.tracer.enabled:
            eng.tracer.span_at("recovery", t0, t0 + latency,
                               error=type(exc).__name__,
                               device_lost=device_lost,
                               salvaged=len(salvaged),
                               relaunched=len(relaunch))
        return {"latency_s": latency,
                "device_lost": device_lost,
                "salvaged_lanes": len(salvaged),
                "relaunched_lanes": len(relaunch),
                "finished_lanes": len(results)}
