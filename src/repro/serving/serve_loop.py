"""Batched serving loop: prefill a batch of prompts token-by-token into
the caches (exact w.r.t. decode numerics), then decode with the jitted
single-token step. Weights are PRUNED (and optionally PACKED) — the
paper's inference setting (§5.2).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.serving.step import make_decode_step


def prefill_with_decode(cfg, params, prompts, max_len: int, dist=None,
                        frames=None):
    """Seed caches by running the decode step over the prompt tokens
    (bitwise-consistent with decode; fine for CPU-scale serving).
    For whisper, ``frames`` seeds the cross-attention cache."""
    b, plen = prompts.shape
    kw = {"enc_len": max_len} if cfg.family == "audio" else {}
    cache = registry.init_cache(cfg, b, max_len, **kw)
    if cfg.family == "audio":
        from repro.models import whisper as whisper_mod
        assert frames is not None, "whisper serving needs frames"
        ck, cv = whisper_mod.prefill_cross(cfg, params, frames, dist=dist)
        cache = dict(cache, ck=ck.astype(cache["ck"].dtype),
                     cv=cv.astype(cache["cv"].dtype))
    step = jax.jit(lambda p, c, t, i: registry.decode_step(
        cfg, p, c, t, i, masks=None, dist=dist))
    logits = None
    for i in range(plen):
        logits, cache = step(params, cache, prompts[:, i:i + 1],
                             jnp.int32(i))
    return logits[:, -1], cache


def generate(cfg, params, prompts, *, max_new_tokens: int = 32,
             max_len: int | None = None, temperature: float = 0.0,
             dist=None, rng=None, frames=None):
    """Greedy/temperature generation for a batch of equal-length prompts.

    Returns (tokens (B, plen+new), stats dict)."""
    b, plen = prompts.shape
    max_len = max_len or (plen + max_new_tokens)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    last_logits, cache = prefill_with_decode(cfg, params, prompts,
                                             max_len, dist, frames=frames)
    decode = jax.jit(make_decode_step(cfg, dist=dist,
                                      temperature=temperature))
    nxt = jnp.argmax(last_logits, -1)[:, None].astype(jnp.int32)
    out = [prompts, nxt]
    t0 = time.monotonic()
    for i in range(max_new_tokens - 1):
        pos = jnp.int32(plen + i)
        nxt, cache, _, rng = decode(params, cache, nxt, pos, rng)
        out.append(nxt)
    jax.block_until_ready(nxt)
    dt = time.monotonic() - t0
    toks = jnp.concatenate(out, axis=1)
    stats = {"decode_s": dt,
             "tok_per_s": b * (max_new_tokens - 1) / max(dt, 1e-9)}
    return np.asarray(toks), stats
