"""Radix-tree prefix cache: refcounted copy-on-write KV page sharing.

BLaST's thesis is that inference cost is data movement; the paged pool
(serving/pages.py) already bounds attention reads by live context, but
every request still RE-PREFILLS and RE-STORES its prompt even when
thousands of requests share a system prompt or few-shot prefix. This
module deduplicates that: a host-side radix tree over token-ID
sequences whose nodes own pool pages, so a new request's longest cached
prefix is matched at admission, its block table is populated with the
SHARED page indices (zero prefill compute and zero KV writes for the
matched pages), and only the uncovered tail is chunk-prefilled.

Layout — the tree is PAGE-CHUNKED so page ownership is never split
across nodes:

  * an **edge** is a run of full ``page_size``-token chunks, one pool
    page per chunk (edges split only at page boundaries, so a radix
    split just redistributes ``(chunk, page)`` pairs between the two
    halves);
  * each node additionally carries **tails**: partially-filled boundary
    pages — a cached sequence that ends mid-page parks its last
    ``1..page_size-1`` tokens here. A request may match INTO a tail, but
    since it will keep writing the same physical page (its own prompt
    tail, then decode), the engine **copy-on-writes** the tail page
    first: shared pages are read-only to everyone — decode never
    touches a page with ``refcount > 1``.

Sharing is positional: a pool page caches K/V with rope applied at the
CANONICAL logical positions ``[j*page_size, (j+1)*page_size)``, so a
page is only valid for a lane whose cache slot ``s`` holds logical
position ``s`` — i.e. lanes admitted at ``offset == 0``. The engine
guarantees that by prefilling prefix-cached admissions per-lane
(width = own prompt length) instead of as a right-aligned ragged group.

Lifecycle against the pool's three page states (pages.py):

  * ``match``      — pure lookup; the engine then ``retain``s the
    matched pages (cached-idle -> referenced) before any eviction or
    allocation can reclaim them;
  * ``insert``     — called when a request finishes, BEFORE the lane
    releases its pages: full pages (and the partial boundary page) of
    the finished sequence are donated via ``cache_add``, so when the
    lane's reference drops they park as cached-idle instead of freeing.
    Chunks the tree already holds are not duplicated — the lane's own
    copy simply frees;
  * ``evict``      — LRU reclamation of cold, unreferenced tails and
    leaf-edge suffixes; "free" capacity for admission is
    ``free + cached_idle`` (pages.py), and the engine calls ``evict``
    to convert the cached-idle part into free pages on demand.

The tree itself stores no tensor data — pages live in the device pool;
matching, insertion and eviction are O(prompt) host work.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.pages import PagePool


@dataclasses.dataclass
class Match:
    """Result of a prefix lookup for one prompt.

    ``pages``: fully-valid shared pages, logical order — they cover
    slots ``[0, len(pages) * page_size)`` and may go straight into the
    lane's block table (after ``retain``). ``tail_page``/``tail_matched``
    name a partially-valid boundary page: its first ``tail_matched``
    rows continue the prefix, but the lane must copy-on-write it before
    writing the rest of the page. ``matched_tokens`` counts both parts
    (always < prompt length: at least one token is left to prefill so
    admission can produce the first logits)."""
    pages: list[int]
    matched_tokens: int
    tail_page: int | None = None
    tail_matched: int = 0


@dataclasses.dataclass
class _Tail:
    tokens: tuple          # 1..page_size-1 tokens past the node's chunks
    page: int              # pool page; rows [0, len(tokens)) are valid
    last_access: int


class _Node:
    __slots__ = ("edge", "pages", "children", "tails", "parent",
                 "last_access")

    def __init__(self, edge, pages, parent, clock=0):
        self.edge: list[tuple] = edge      # full page_size-token chunks
        self.pages: list[int] = pages      # one pool page per chunk
        self.children: dict[tuple, _Node] = {}
        self.tails: list[_Tail] = []
        self.parent: _Node | None = parent
        self.last_access = clock


class PrefixCache:
    """Host-side radix tree mapping token prefixes to pool pages."""

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.page_size = pool.page_size
        self.root = _Node([], [], None)
        self._clock = 0

    # ------------------------------------------------------------- lookup
    def reclaimable(self) -> int:
        """Pages eviction could free right now. Every cached-idle page
        is reachable: lanes retain root-path prefixes, so an idle page's
        whole subtree is idle and leaf-first eviction cascades to it."""
        return self.pool.cached_idle

    def match(self, tokens: np.ndarray) -> Match:
        """Longest cached prefix of ``tokens``, capped at
        ``len(tokens) - 1`` so the tail prefill always runs at least one
        token (the engine needs last-token logits to start decoding).
        Pure lookup apart from the LRU touch — the caller pins the
        result with ``pool.retain`` (including ``tail_page``, which
        must survive until its CoW copy lands) before anything can
        evict it; hit/miss accounting lives in the engine's stats."""
        self._clock += 1
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        cap = len(toks) - 1
        ps = self.page_size

        def common(cached_toks):
            t = 0
            for a, b in zip(cached_toks, toks[depth:cap]):
                if a != b:
                    break
                t += 1
            return t

        pages: list[int] = []
        node = self.root
        depth = 0
        tail_page, tail_matched = None, 0
        while depth + ps <= cap:
            child = node.children.get(tuple(toks[depth:depth + ps]))
            if child is None:
                break
            child.last_access = self._clock
            i = 0
            while (i < len(child.edge) and depth + ps <= cap
                   and tuple(toks[depth:depth + ps]) == child.edge[i]):
                pages.append(child.pages[i])
                depth += ps
                i += 1
            if i < len(child.edge):
                # stopped INSIDE the edge (cap or divergence mid-page):
                # the next cached page is fully valid but only its first
                # rows continue this prompt — a CoW boundary page, same
                # as a tail
                t = common(child.edge[i])
                if t:
                    tail_page, tail_matched = child.pages[i], t
                return Match(pages, depth + tail_matched, tail_page,
                             tail_matched)
            node = child
        # at a node boundary: the best partial continuation among the
        # node's tails and its children's FIRST pages (an exact-chunk
        # child was already consumed by the walk above)
        best = None
        for tail in node.tails:
            t = common(tail.tokens)
            if t > tail_matched:
                tail_matched, tail_page, best = t, tail.page, tail
        for child in node.children.values():
            t = common(child.edge[0])
            if t > tail_matched:
                tail_matched, tail_page, best = t, child.pages[0], child
        if best is not None:
            best.last_access = self._clock
        matched = depth + tail_matched
        return Match(pages, matched, tail_page, tail_matched)

    # ------------------------------------------------------------- insert
    def insert(self, tokens: np.ndarray, pages: list[int]) -> int:
        """Insert a finished sequence's KV coverage into the tree.

        ``tokens`` are the ``frontier`` tokens whose K/V the lane
        actually wrote (prompt + emitted continuation — a future prompt
        extending this request's whole output still hits);``pages`` are
        the lane's pages covering them in logical order
        (``ceil(len(tokens) / page_size)`` entries, shared pages
        included). Pages backing chunks the tree does not yet hold are
        DONATED (``cache_add``) — the caller releases its references
        afterwards as usual and donated pages park as cached-idle.
        Duplicated coverage (another identical request finished first)
        is not donated; the lane's copy simply frees. Returns the number
        of donated pages."""
        self._clock += 1
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        ps = self.page_size
        chunks = [tuple(toks[j * ps:(j + 1) * ps])
                  for j in range(len(toks) // ps)]
        assert len(pages) >= -(-len(toks) // ps), "pages don't cover tokens"
        node, i, donated = self.root, 0, 0
        node.last_access = self._clock
        while i < len(chunks):
            child = node.children.get(chunks[i])
            if child is None:
                donate = list(pages[i:len(chunks)])
                self.pool.cache_add(donate)
                donated += len(donate)
                leaf = _Node(list(chunks[i:]), donate, node, self._clock)
                node.children[chunks[i]] = leaf
                node = leaf
                i = len(chunks)
                break
            child.last_access = self._clock
            k = 0
            while (k < len(child.edge) and i + k < len(chunks)
                   and child.edge[k] == chunks[i + k]):
                k += 1
            if k < len(child.edge):
                # split at the page boundary after k matched chunks
                # (k >= 1 — the child is keyed by its first chunk); the
                # upper half keeps its children/tails, pages move with
                # their chunks
                mid = _Node(child.edge[:k], child.pages[:k], node,
                            self._clock)
                mid.children[child.edge[k]] = child
                child.edge = child.edge[k:]
                child.pages = child.pages[k:]
                child.parent = mid
                node.children[mid.edge[0]] = mid
                node = mid
            else:
                node = child
            i += k
        rest = tuple(toks[len(chunks) * ps:])
        if rest:
            t = len(rest)
            covered = any(tail.tokens[:t] == rest for tail in node.tails)
            if not covered:
                page = pages[len(chunks)]
                self.pool.cache_add([page])
                donated += 1
                node.tails.append(_Tail(rest, page, self._clock))
        return donated

    # ------------------------------------------------------------ eviction
    def evict(self, need: int) -> int:
        """Free at least ``need`` pages by dropping cold cache entries,
        LRU-first: unreferenced tails, then unreferenced suffixes of
        leaf edges (an emptied leaf detaches and may expose its parent).
        Pages some lane still reads (``refcount > 0``) are untouchable.
        Returns how many pages were actually freed (< ``need`` when the
        cache runs out of idle entries)."""
        freed = 0
        progress = True
        while freed < need and progress:
            # ONE DFS collects every current candidate; they are then
            # dropped in LRU order. The outer loop re-scans only when a
            # detached leaf may have exposed its parent as a new leaf
            # (cascading reclaim) and more pages are still needed.
            progress = False
            cands: list[tuple[int, int, _Node, _Tail | None]] = []
            stack = [self.root]
            while stack:
                nd = stack.pop()
                stack.extend(nd.children.values())
                for tail in nd.tails:
                    if self.pool.refcount(tail.page) == 0:
                        cands.append((tail.last_access, 1, nd, tail))
                if (nd.parent is not None and not nd.children
                        and not nd.tails
                        and self.pool.refcount(nd.pages[-1]) == 0):
                    cands.append((nd.last_access, 0, nd, None))
            for _, kind, nd, tail in sorted(cands, key=lambda c: c[:2]):
                if freed >= need:
                    break
                if kind == 1:
                    # a tail drop above may have turned this node into a
                    # bare leaf candidate already handled; tails
                    # themselves never invalidate each other
                    nd.tails.remove(tail)
                    self.pool.cache_drop([tail.page])
                    freed += 1
                    progress = True
                    continue
                key = nd.edge[0]
                while (nd.edge and freed < need
                       and self.pool.refcount(nd.pages[-1]) == 0):
                    nd.edge.pop()
                    self.pool.cache_drop([nd.pages.pop()])
                    freed += 1
                    progress = True
                if not nd.edge:
                    del nd.parent.children[key]
                    nd.parent = None
        return freed

    def flush(self) -> int:
        """Drop EVERY cached entry at once — the weight hot-swap barrier
        (serving/hotswap.py): all cached KV was computed under weights
        that are being retired, so no future admission may match it.
        Unlike ``evict``, referenced pages are handled too: they lose
        their cached flag now (``pool.uncache``) and free when the last
        reading lane releases them — the reading lanes themselves are
        unaffected (their KV matches their own admission generation).
        Returns the number of pages dropped from the tree."""
        pages: list[int] = []
        stack = [self.root]
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            pages.extend(nd.pages)
            pages.extend(t.page for t in nd.tails)
        self.pool.uncache(pages)
        self.root = _Node([], [], None)
        return len(pages)

    # ---------------------------------------------------------- inspection
    def __len__(self) -> int:
        """Cached pages currently held by the tree."""
        n = 0
        stack = [self.root]
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            n += len(nd.pages) + len(nd.tails)
        return n
