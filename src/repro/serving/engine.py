"""Continuous-batching serving engine (paper §5.2 made servable).

``serve_loop.generate`` — the parity oracle — prefills token-by-token in
a Python loop and only handles one batch of equal-length prompts.  This
engine turns the same pruned/packed weights into a subsystem that keeps
the accelerator saturated across ragged, continuously-arriving requests:

  * **lanes** — ``max_batch`` batch rows over one shared KV cache
    ``(layers, max_batch, max_len, kv, hd)``; a completed sequence frees
    its lane for the next queued request (slot reuse);
  * **time-indexed cache** — all active lanes decode at one shared
    cache-slot *frontier*, so the jitted decode step keeps the scalar
    write position (bitwise-identical numerics to the oracle);
  * **right-aligned ragged prompts** — an admitted prompt is placed so
    it *ends* at the frontier, slots ``[frontier-plen, frontier)``; the
    left-pad ``offset = frontier - plen`` feeds rope/masking the true
    logical positions (models/attention.py ``_cache_positions``);
  * **chunked batched prefill** — prompts enter through
    ``registry.prefill_chunk`` in whole ``(B, C)`` chunks per jitted
    call instead of one token per Python iteration; running lanes are
    shielded from the writes by ``lane_mask``;
  * **admission** — ``scheduler.FIFOScheduler``: a request joins a
    running batch only if its prompt fits behind the frontier; when the
    batch drains the frontier resets to 0 and the cache is reused
    (stale K/V needs no zeroing — causal masking hides slots beyond the
    frontier and offset masking hides slots before the prompt).

Greedy decode only (the paper's serving benchmark); temperature sampling
stays on the ``serve_loop`` oracle path.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.serving.scheduler import FIFOScheduler, Request
from repro.serving.step import (make_engine_decode_step,
                                make_prefill_chunk_step)


@dataclasses.dataclass
class GenResult:
    """Finished request: prompt + generated tokens (greedy)."""
    uid: int
    prompt: np.ndarray
    generated: np.ndarray
    truncated: bool = False    # hit max_len before max_new_tokens

    @property
    def tokens(self) -> np.ndarray:
        return np.concatenate([self.prompt, self.generated])


@dataclasses.dataclass
class _Lane:
    req: Request
    offset: int                # left-pad: frontier_at_admission - plen
    pending: int               # next token to feed the decode step
    generated: list[int]


class Engine:
    """Continuous-batching greedy generation over pruned/packed weights.

    >>> eng = Engine(cfg, params, max_batch=4, max_len=64)
    >>> uid = eng.submit(prompt_ids, max_new_tokens=32)
    >>> results = eng.run()          # {uid: GenResult}
    """

    def __init__(self, cfg, params, *, max_batch: int, max_len: int,
                 prefill_chunk: int = 16, eos_id: int | None = None,
                 dist=None, scheduler: FIFOScheduler | None = None):
        if not registry.supports_prefill_chunk(cfg):
            raise NotImplementedError(
                f"family {cfg.family!r} is not KV-cache servable by the "
                "engine; use serve_loop.generate")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.chunk = max(1, min(prefill_chunk, max_len))
        self.eos_id = eos_id
        self.scheduler = scheduler or FIFOScheduler(max_batch, max_len)
        self.cache = registry.init_cache(cfg, max_batch, max_len)
        self._prefill = jax.jit(make_prefill_chunk_step(cfg, dist=dist))
        self._decode = jax.jit(make_engine_decode_step(cfg, dist=dist))
        self.lanes: list[_Lane | None] = [None] * max_batch
        self.frontier = 0
        self._uid = 0
        self.reset_stats()

    def reset_stats(self):
        self.stats = {"prefill_chunks": 0, "prefill_tokens": 0,
                      "decode_steps": 0, "decode_tokens": 0,
                      "generated_tokens": 0, "prefill_s": 0.0,
                      "decode_s": 0.0, "admitted": 0, "evicted": 0,
                      "truncated": 0}

    # ------------------------------------------------------------ submit
    def submit(self, prompt, max_new_tokens: int = 32,
               uid: int | None = None) -> int:
        uid = self._uid if uid is None else uid
        self._uid = max(self._uid, uid) + 1
        self.scheduler.submit(Request(uid, np.asarray(prompt),
                                      max_new_tokens))
        return uid

    # ------------------------------------------------------- lane helpers
    @property
    def active_lanes(self) -> list[int]:
        return [i for i, l in enumerate(self.lanes) if l is not None]

    def _offsets(self) -> jnp.ndarray:
        return jnp.asarray([l.offset if l is not None else 0
                            for l in self.lanes], jnp.int32)

    def _finish(self, i: int, truncated: bool = False) -> GenResult:
        lane = self.lanes[i]
        self.lanes[i] = None
        self.stats["evicted"] += 1
        self.stats["truncated"] += int(truncated)
        return GenResult(lane.req.uid, lane.req.prompt,
                         np.asarray(lane.generated, np.int32), truncated)

    # ----------------------------------------------------------- admission
    def _admit(self) -> None:
        free = [i for i, l in enumerate(self.lanes) if l is None]
        reqs = self.scheduler.admit(len(free), self.frontier)
        if not reqs:
            return
        if self.frontier == 0:      # fresh batch: group sets the frontier
            self.frontier = max(r.prompt_len for r in reqs)
        new_lanes = []
        for r in reqs:
            i = free.pop(0)
            self.lanes[i] = _Lane(r, self.frontier - r.prompt_len, -1, [])
            new_lanes.append(i)
        self.stats["admitted"] += len(reqs)

        # chunked batched prefill over [start, frontier), right-aligned;
        # first chunk may be short (width % C), the rest are C wide so
        # the jit cache sees at most C distinct shapes.
        maxp = max(r.prompt_len for r in reqs)
        width = min(self.frontier, -(-maxp // self.chunk) * self.chunk)
        start = self.frontier - width
        tokens = np.zeros((self.max_batch, width), np.int32)
        for i in new_lanes:
            p = self.lanes[i].req.prompt
            tokens[i, width - p.size:] = p
        lane_mask = np.zeros((self.max_batch,), bool)
        lane_mask[new_lanes] = True
        offsets = self._offsets()
        mask_j = jnp.asarray(lane_mask)
        toks_j = jnp.asarray(tokens)
        last = None
        pos = 0
        rem = width % self.chunk
        sizes = ([rem] if rem else []) + [self.chunk] * (width // self.chunk)
        t0 = time.time()
        for c in sizes:
            last, self.cache = self._prefill(
                self.params, self.cache, toks_j[:, pos:pos + c],
                jnp.int32(start + pos), offsets, mask_j)
            pos += c
            self.stats["prefill_chunks"] += 1
        first = np.asarray(jax.block_until_ready(jnp.argmax(last, -1)))
        self.stats["prefill_s"] += time.time() - t0
        self.stats["prefill_tokens"] += sum(r.prompt_len for r in reqs)
        for i in new_lanes:
            self.lanes[i].pending = int(first[i])
            self.lanes[i].generated.append(int(first[i]))
            self.stats["generated_tokens"] += 1

    def _sweep_finished(self, finished: list[GenResult]) -> None:
        """Evict lanes whose budget is spent or that emitted eos (the
        first prefill token may already do either)."""
        for i in self.active_lanes:
            lane = self.lanes[i]
            if len(lane.generated) >= lane.req.max_new_tokens or \
                    (self.eos_id is not None and lane.generated and
                     lane.generated[-1] == self.eos_id):
                finished.append(self._finish(i))

    # --------------------------------------------------------------- step
    def step(self) -> list[GenResult]:
        """One engine iteration: evict, (re)admit, one decode step.
        Returns requests finished during this step."""
        finished: list[GenResult] = []
        self._sweep_finished(finished)
        if not self.active_lanes:
            self.frontier = 0           # batch drained: reuse the cache
        self._admit()
        self._sweep_finished(finished)   # e.g. max_new_tokens == 1
        active = self.active_lanes
        if not active:
            return finished
        if self.frontier >= self.max_len:   # out of cache: truncate
            for i in active:
                finished.append(self._finish(i, truncated=True))
            return finished

        tokens = np.zeros((self.max_batch, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.lanes[i].pending
        t0 = time.time()
        nxt, self.cache, _ = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.int32(self.frontier), self._offsets())
        nxt = np.asarray(jax.block_until_ready(nxt))
        self.stats["decode_s"] += time.time() - t0
        self.stats["decode_steps"] += 1
        self.frontier += 1
        for i in active:
            tok = int(nxt[i, 0])
            lane = self.lanes[i]
            lane.pending = tok
            lane.generated.append(tok)
            self.stats["generated_tokens"] += 1
            self.stats["decode_tokens"] += 1
        return finished

    # ---------------------------------------------------------------- run
    def run(self) -> dict[int, GenResult]:
        """Drain the queue and all active lanes; {uid: GenResult}."""
        out: dict[int, GenResult] = {}
        while len(self.scheduler) or self.active_lanes:
            for r in self.step():
                out[r.uid] = r
        # decode throughput (oracle semantics: decode-emitted tokens over
        # decode time); end-to-end adds prefill in both terms
        self.stats["tok_per_s"] = (
            self.stats["decode_tokens"] / self.stats["decode_s"]
            if self.stats["decode_s"] > 0 else 0.0)
        total_s = self.stats["decode_s"] + self.stats["prefill_s"]
        self.stats["e2e_tok_per_s"] = (
            self.stats["generated_tokens"] / total_s
            if total_s > 0 else 0.0)
        return out


def generate(cfg, params, prompts, *, max_new_tokens: int = 32,
             max_len: int | None = None, eos_id: int | None = None,
             prefill_chunk: int = 16, max_batch: int | None = None,
             dist=None):
    """Batch-convenience wrapper: list of ragged 1-D prompts (or a 2-D
    equal-length array) -> (list of per-request token arrays, stats).

    Greedy; equal-length batches are bitwise-identical to
    ``serve_loop.generate`` (tests/test_serving_engine.py). A request
    that runs out of cache headroom returns fewer than
    ``max_new_tokens`` tokens — ``stats["truncated"]`` counts them
    (use ``Engine`` directly for per-request ``GenResult.truncated``)."""
    prompts = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
    maxp = max(p.size for p in prompts)
    max_len = max_len or (maxp + max_new_tokens)
    eng = Engine(cfg, params, max_batch=max_batch or len(prompts),
                 max_len=max_len, prefill_chunk=prefill_chunk,
                 eos_id=eos_id, dist=dist)
    uids = [eng.submit(p, max_new_tokens) for p in prompts]
    res = eng.run()
    return [res[u].tokens for u in uids], eng.stats
