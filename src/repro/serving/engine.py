"""Continuous-batching serving engine (paper §5.2 made servable).

``serve_loop.generate`` — the parity oracle — prefills token-by-token in
a Python loop and only handles one batch of equal-length prompts.  This
engine turns the same pruned/packed weights into a subsystem that keeps
the accelerator saturated across ragged, continuously-arriving requests:

  * **lanes** — ``max_batch`` batch rows over one shared KV cache
    ``(layers, max_batch, max_len, kv, hd)``; a completed sequence frees
    its lane for the next queued request (slot reuse);
  * **per-lane frontiers** — every lane carries its OWN cache-slot write
    position (a ``(max_batch,)`` vector, not a shared scalar), so a
    freed lane resets its frontier to 0 and admits a new prompt
    immediately instead of leaking cache slots until the batch drains;
  * **decode slabs** — the token loop runs ON-DEVICE: one jitted
    ``lax.scan`` over ``slab_k`` greedy steps (serving/step.py) carries
    per-lane pending token / frontier / remaining budget / live flags
    and emits a ``(max_batch, slab_k)`` token block, so the host syncs
    once per slab instead of once per token; lanes that hit eos, their
    budget, or the cache end mid-slab are masked out on-device and
    their trailing tokens discarded on the host — greedy decode stays
    bitwise-identical to the per-token path and the oracle;
  * **persistent device state** — pending/frontier/offsets/remaining/
    live live on the accelerator between slabs; the host re-uploads
    them only at admission/eviction events (never per token);
  * **right-aligned ragged prompts** — prompts admitted together are
    prefilled as one group in slots ``[0, W)`` (``W`` = longest prompt
    in the group); the left-pad ``offset = W - plen`` feeds rope/masking
    the true logical positions (models/attention.py
    ``_cache_positions``);
  * **chunked batched prefill** — prompts enter through
    ``registry.prefill_chunk`` in whole ``(B, C)`` chunks per jitted
    call instead of one token per Python iteration; running lanes are
    shielded from the writes by ``lane_mask`` (stale K/V needs no
    zeroing — causal masking hides slots beyond a lane's frontier and
    offset masking hides slots before its prompt);
  * **admission** — ``scheduler.FIFOScheduler``: with per-lane
    frontiers any free lane takes the head request immediately.

Greedy decode only (the paper's serving benchmark); temperature sampling
stays on the ``serve_loop`` oracle path.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.serving.scheduler import FIFOScheduler, Request
from repro.serving.step import (make_decode_slab_step,
                                make_prefill_chunk_step)


@dataclasses.dataclass
class GenResult:
    """Finished request: prompt + generated tokens (greedy)."""
    uid: int
    prompt: np.ndarray
    generated: np.ndarray
    truncated: bool = False    # hit max_len before max_new_tokens

    @property
    def tokens(self) -> np.ndarray:
        return np.concatenate([self.prompt, self.generated])


@dataclasses.dataclass
class _Lane:
    req: Request
    offset: int                # left-pad: group width - plen
    generated: list[int]


class Engine:
    """Continuous-batching greedy generation over pruned/packed weights.

    >>> eng = Engine(cfg, params, max_batch=4, max_len=64, slab_k=8)
    >>> uid = eng.submit(prompt_ids, max_new_tokens=32)
    >>> results = eng.run()          # {uid: GenResult}

    ``slab_k`` is the number of decode steps per jitted slab (host syncs
    once per slab); ``slab_k=1`` is the per-token baseline.
    """

    def __init__(self, cfg, params, *, max_batch: int, max_len: int,
                 prefill_chunk: int = 16, slab_k: int = 8,
                 eos_id: int | None = None, dist=None,
                 scheduler: FIFOScheduler | None = None):
        if not registry.supports_prefill_chunk(cfg):
            raise NotImplementedError(
                f"family {cfg.family!r} is not KV-cache servable by the "
                "engine; use serve_loop.generate")
        assert slab_k >= 1
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.chunk = max(1, min(prefill_chunk, max_len))
        self.slab_k = slab_k
        self.eos_id = eos_id
        self.scheduler = scheduler or FIFOScheduler(max_batch, max_len)
        self.cache = registry.init_cache(cfg, max_batch, max_len)
        self._prefill = jax.jit(make_prefill_chunk_step(cfg, dist=dist))
        self._slab = jax.jit(make_decode_slab_step(
            cfg, slab_k, max_len, eos_id=eos_id, dist=dist))
        self.lanes: list[_Lane | None] = [None] * max_batch
        # host mirror of the on-device per-lane state; uploaded to the
        # device ONLY when admission/eviction edits it (self._dirty)
        self._mirror = {
            "pending": np.zeros(max_batch, np.int32),
            "frontier": np.zeros(max_batch, np.int32),
            "offsets": np.zeros(max_batch, np.int32),
            "remaining": np.zeros(max_batch, np.int32),
            "live": np.zeros(max_batch, bool),
        }
        self._dstate = None
        self._dirty = True
        self._uid = 0
        self.reset_stats()

    def reset_stats(self):
        self.stats = {"prefill_chunks": 0, "prefill_tokens": 0,
                      "decode_slabs": 0, "decode_steps": 0,
                      "decode_tokens": 0, "generated_tokens": 0,
                      "prefill_s": 0.0, "decode_s": 0.0, "admitted": 0,
                      "evicted": 0, "truncated": 0}

    # ------------------------------------------------------------ submit
    def submit(self, prompt, max_new_tokens: int = 32,
               uid: int | None = None) -> int:
        uid = self._uid if uid is None else uid
        self._uid = max(self._uid, uid) + 1
        self.scheduler.submit(Request(uid, np.asarray(prompt),
                                      max_new_tokens))
        return uid

    # ------------------------------------------------------- lane helpers
    @property
    def active_lanes(self) -> list[int]:
        return [i for i, l in enumerate(self.lanes) if l is not None]

    @property
    def frontiers(self) -> np.ndarray:
        """(max_batch,) per-lane cache-slot write positions."""
        return self._mirror["frontier"].copy()

    def _sync_dstate(self):
        """Upload the host mirror as the device-side slab state — called
        lazily, only after admission/eviction edits."""
        if self._dirty:
            self._dstate = {k: jnp.asarray(v)
                            for k, v in self._mirror.items()}
            self._dirty = False

    def _finish(self, i: int, truncated: bool = False) -> GenResult:
        lane = self.lanes[i]
        self.lanes[i] = None
        self._mirror["live"][i] = False
        self._dirty = True
        self.stats["evicted"] += 1
        self.stats["truncated"] += int(truncated)
        return GenResult(lane.req.uid, lane.req.prompt,
                         np.asarray(lane.generated, np.int32), truncated)

    # ----------------------------------------------------------- admission
    def _admit(self) -> None:
        free = [i for i, l in enumerate(self.lanes) if l is None]
        reqs = self.scheduler.admit(len(free))
        if not reqs:
            return
        # the admitted group prefills right-aligned in slots [0, W):
        # a lane freed mid-traffic restarts at slot 0 immediately
        width = max(r.prompt_len for r in reqs)
        new_lanes = []
        m = self._mirror
        for r in reqs:
            i = free.pop(0)
            off = width - r.prompt_len
            self.lanes[i] = _Lane(r, off, [])
            m["offsets"][i] = off
            m["frontier"][i] = width
            m["remaining"][i] = r.max_new_tokens - 1
            m["pending"][i] = 0
            m["live"][i] = True
            new_lanes.append(i)
        self._dirty = True     # one upload, in step() before the slab
        self.stats["admitted"] += len(reqs)

        # chunked batched prefill over [0, width), right-aligned; the
        # first chunk may be short (width % C), the rest are C wide so
        # the jit cache sees at most C distinct shapes.
        tokens = np.zeros((self.max_batch, width), np.int32)
        for i in new_lanes:
            p = self.lanes[i].req.prompt
            tokens[i, width - p.size:] = p
        lane_mask = np.zeros((self.max_batch,), bool)
        lane_mask[new_lanes] = True
        offsets = jnp.asarray(m["offsets"])
        mask_j = jnp.asarray(lane_mask)
        toks_j = jnp.asarray(tokens)
        last = None
        pos = 0
        rem = width % self.chunk
        sizes = ([rem] if rem else []) + [self.chunk] * (width // self.chunk)
        t0 = time.time()
        for c in sizes:
            last, self.cache = self._prefill(
                self.params, self.cache, toks_j[:, pos:pos + c],
                jnp.int32(pos), offsets, mask_j)
            pos += c
            self.stats["prefill_chunks"] += 1
        first = np.asarray(jax.block_until_ready(jnp.argmax(last, -1)))
        self.stats["prefill_s"] += time.time() - t0
        self.stats["prefill_tokens"] += sum(r.prompt_len for r in reqs)
        for i in new_lanes:
            m["pending"][i] = int(first[i])
            self.lanes[i].generated.append(int(first[i]))
            self.stats["generated_tokens"] += 1

    def _sweep_finished(self, finished: list[GenResult]) -> None:
        """Evict lanes whose budget is spent, that emitted eos (the
        first prefill token may already do either), or that ran out of
        cache slots (per-lane truncation)."""
        m = self._mirror
        for i in self.active_lanes:
            lane = self.lanes[i]
            done = (len(lane.generated) >= lane.req.max_new_tokens or
                    (self.eos_id is not None and lane.generated and
                     lane.generated[-1] == self.eos_id))
            if done:
                finished.append(self._finish(i))
            elif m["frontier"][i] >= self.max_len:
                finished.append(self._finish(i, truncated=True))

    # --------------------------------------------------------------- step
    def step(self) -> list[GenResult]:
        """One engine iteration: evict, (re)admit, one decode SLAB
        (``slab_k`` on-device steps, one host sync). Returns requests
        finished during this step."""
        finished: list[GenResult] = []
        self._sweep_finished(finished)
        self._admit()
        self._sweep_finished(finished)   # e.g. max_new_tokens == 1
        if not self.active_lanes:
            return finished
        self._sync_dstate()
        t0 = time.time()
        block, self._dstate, self.cache = self._slab(
            self.params, self.cache, self._dstate)
        block = np.asarray(jax.block_until_ready(block))
        self.stats["decode_s"] += time.time() - t0
        self.stats["decode_slabs"] += 1
        self.stats["decode_steps"] += self.slab_k
        self._replay(block)
        return finished

    def _replay(self, block: np.ndarray) -> None:
        """Fold a slab's token block into the host mirror using the
        per-lane state the slab returned (downloaded at the same sync —
        the device's stop logic is the single source of truth): lane i
        kept exactly ``new_frontier - old_frontier`` tokens; anything it
        emitted after its stop point is discarded here."""
        new = {k: np.array(v) for k, v in self._dstate.items()}
        for i in self.active_lanes:
            kept = int(new["frontier"][i] - self._mirror["frontier"][i])
            self.lanes[i].generated.extend(
                int(t) for t in block[i, :kept])
            self.stats["generated_tokens"] += kept
            self.stats["decode_tokens"] += kept
        self._mirror = new

    # ---------------------------------------------------------------- run
    def run(self) -> dict[int, GenResult]:
        """Drain the queue and all active lanes; {uid: GenResult}."""
        out: dict[int, GenResult] = {}
        while len(self.scheduler) or self.active_lanes:
            for r in self.step():
                out[r.uid] = r
        # decode throughput (oracle semantics: decode-emitted tokens over
        # decode time); end-to-end adds prefill in both terms
        self.stats["tok_per_s"] = (
            self.stats["decode_tokens"] / self.stats["decode_s"]
            if self.stats["decode_s"] > 0 else 0.0)
        total_s = self.stats["decode_s"] + self.stats["prefill_s"]
        self.stats["e2e_tok_per_s"] = (
            self.stats["generated_tokens"] / total_s
            if total_s > 0 else 0.0)
        return out


def generate(cfg, params, prompts, *, max_new_tokens: int = 32,
             max_len: int | None = None, eos_id: int | None = None,
             prefill_chunk: int = 16, slab_k: int = 8,
             max_batch: int | None = None, dist=None):
    """Batch-convenience wrapper: list of ragged 1-D prompts (or a 2-D
    equal-length array) -> (list of per-request token arrays, stats).

    Greedy; equal-length batches are bitwise-identical to
    ``serve_loop.generate`` for every slab size
    (tests/test_serving_engine.py). A request that runs out of cache
    headroom returns fewer than ``max_new_tokens`` tokens —
    ``stats["truncated"]`` counts them (use ``Engine`` directly for
    per-request ``GenResult.truncated``)."""
    prompts = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
    maxp = max(p.size for p in prompts)
    max_len = max_len or (maxp + max_new_tokens)
    eng = Engine(cfg, params, max_batch=max_batch or len(prompts),
                 max_len=max_len, prefill_chunk=prefill_chunk,
                 slab_k=slab_k, eos_id=eos_id, dist=dist)
    uids = [eng.submit(p, max_new_tokens) for p in prompts]
    res = eng.run()
    return [res[u].tokens for u in uids], eng.stats
