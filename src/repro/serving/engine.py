"""Continuous-batching serving engine (paper §5.2 made servable).

``serve_loop.generate`` — the parity oracle — prefills token-by-token in
a Python loop and only handles one batch of equal-length prompts.  This
engine turns the same pruned/packed weights into a subsystem that keeps
the accelerator saturated across ragged, continuously-arriving requests:

  * **paged KV cache** (default) — K/V lives in a SHARED page pool
    ``(layers, n_pages, page_size, kv, hd)`` with a host-side free-list
    allocator (serving/pages.py); each lane maps logical cache slots to
    pool pages through a ``(max_pages,)`` block table carried on-device
    through the decode slab. Attention gathers ONLY a lane's first
    ``read_pages`` pages — bucketed to the next power of two of the live
    frontier so the jit cache stays O(log max_pages) — so per-token
    attention bytes scale with ``ceil(frontier / page_size)`` instead of
    ``max_len``. Total servable context is bounded by POOL PAGES, not
    ``max_batch × max_len``: ``max_len`` can be set far beyond what a
    contiguous ``(B, max_len)`` slab could ever hold, and one lane may
    take nearly the whole pool. Greedy decode through the paged path is
    bitwise-identical to the contiguous one (``paged=False``) — the slot
    numbering, rope, and masking are shared; only the storage moves;
  * **lanes** — ``max_batch`` batch rows; a completed sequence frees its
    lane (and pages) for the next queued request (slot reuse);
  * **per-lane frontiers** — every lane carries its OWN cache-slot write
    position (a ``(max_batch,)`` vector, not a shared scalar), so a
    freed lane resets its frontier to 0 and admits a new prompt
    immediately instead of leaking cache slots until the batch drains;
  * **decode slabs** — the token loop runs ON-DEVICE: one jitted
    ``lax.scan`` over ``slab_k`` greedy steps (serving/step.py) carries
    per-lane pending token / frontier / remaining budget / live flags
    (+ block tables) and emits a ``(max_batch, slab_k)`` token block, so
    the host syncs once per slab instead of once per token; lanes that
    hit eos, their budget, or the cache end mid-slab are masked out
    on-device and their trailing tokens discarded on the host — greedy
    decode stays bitwise-identical to the per-token path and the oracle;
  * **persistent device state** — pending/frontier/offsets/remaining/
    live (and block tables) live on the accelerator between slabs; the
    host re-uploads them only at admission/eviction events;
  * **right-aligned ragged prompts** — prompts admitted together are
    prefilled as one group in slots ``[0, W)`` (``W`` = longest prompt
    in the group); the left-pad ``offset = W - plen`` feeds rope/masking
    the true logical positions (models/attention.py
    ``_cache_positions``);
  * **chunked batched prefill** — prompts enter through
    ``registry.prefill_chunk`` / ``paged_prefill_chunk`` in whole
    ``(B, C)`` chunks per jitted call; running lanes are shielded from
    the writes by ``lane_mask``;
  * **admission** — ``scheduler.FIFOScheduler``: any free lane takes the
    head request; paged engines additionally gate the admission group on
    FREE PAGES (a group that would overdraw the pool waits — strict
    FIFO, head-of-line blocking by design). Pages for a request's whole
    extent (group width + decode budget, capped at ``max_len``) are
    pinned at admission, so a slab can never run out of pages mid-slab;
  * **mixed batching** (``mixed=True``, paged only) — the phased loop
    above still STALLS decode during admission: ``_admit`` runs a
    blocking chunked-prefill loop, during which every running lane
    waits. The mixed engine fuses the two into one token-budgeted
    jitted step (serving/step.py ``make_mixed_step``): running lanes
    contribute ONE decode token each and admitting lanes contribute a
    prefill chunk, as per-lane variable-length query runs through the
    same transformer stack — decode throughput is never zeroed by an
    arriving prompt, and the tails of several prefix-cached admissions
    coalesce into one call. The scheduler becomes token-budgeted
    (``prefill_token_budget``): decode tokens are spent first, the
    remainder is split chunk-granularly across admitting prompts, so a
    long prompt is prefilled incrementally instead of monopolizing a
    step. When no prompt is in flight the engine drops back to decode
    slabs (one host sync per ``slab_k`` tokens). Greedy tokens are
    bitwise-identical to the phased engine and the oracle — the phased
    path (``mixed=False``, the default) is the parity baseline;
  * **prefix cache** (``prefix_cache=True``, paged only) — a host-side
    radix tree over token IDs (serving/prefix_cache.py) shares pool
    pages across requests: at admission the prompt's longest cached
    prefix is matched, the matched pages are REFCOUNT-pinned and dropped
    straight into the lane's block table (zero prefill compute, zero KV
    writes for them), and only the uncovered tail is chunk-prefilled; a
    partially-filled boundary page is COPY-ON-WRITE duplicated first, so
    decode never writes a page with refcount > 1. Finished sequences are
    inserted back into the tree (their pages park as cached-idle —
    reclaimed LRU-first under pool pressure), and the admission gate
    sees the EFFECTIVE page cost: shared pages are free, capacity is
    free + reclaimable-cached. Prefix-cached admissions prefill per-lane
    at ``offset == 0`` (sharing is positional: a pool page holds rope'd
    K at canonical positions), instead of as one right-aligned group —
    greedy tokens stay bitwise-identical either way.

Greedy decode only (the paper's serving benchmark); temperature sampling
stays on the ``serve_loop`` oracle path.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.serving.faults import (BackpressureError, DeadlineExceededError,
                                  LaneFaultError, OffloadCapacityError,
                                  OffloadCorruptionError,
                                  RequestCancelledError)
from repro.serving.offload import HostKVStore
from repro.serving.pages import PagePool
from repro.serving.prefix_cache import Match, PrefixCache
from repro.serving.scheduler import FIFOScheduler, Request
from repro.serving.step import (make_copy_pages_step,
                                make_decode_slab_step,
                                make_gather_pages_step,
                                make_mixed_step,
                                make_paged_decode_slab_step,
                                make_paged_prefill_chunk_step,
                                make_prefill_chunk_step,
                                make_scatter_pages_step)


@dataclasses.dataclass
class GenResult:
    """Finished request: prompt + generated tokens (greedy).

    A request that FAILED (quarantined lane, cancellation, deadline,
    corrupted offload record) still flows out through the same channel,
    with the structured exception in ``error`` and ``generated``
    holding whatever tokens it emitted before failing — the engine
    never silently drops a submitted uid."""
    uid: int
    prompt: np.ndarray
    generated: np.ndarray
    truncated: bool = False    # hit the lane's slot cap before budget
    ttft_s: float = 0.0        # submit -> first token (monotonic clock)
    error: Exception | None = None

    @property
    def ok(self) -> bool:
        return self.error is None

    @property
    def tokens(self) -> np.ndarray:
        return np.concatenate([self.prompt, self.generated])


@dataclasses.dataclass
class _Lane:
    req: Request
    offset: int                # left-pad: group width - plen
    generated: list[int]
    pages: list[int] = dataclasses.field(default_factory=list)
    # host-sync timestamp of each generated token (TTFT / inter-token
    # latency observability; tokens folded at one sync share it)
    token_times: list[float] = dataclasses.field(default_factory=list)
    # weight GENERATION the lane was admitted under (serving/hotswap.py):
    # the lane decodes with exactly these params until it finishes, so a
    # mid-stream hot-swap never changes an in-flight request's numerics
    gen: int = 0


@dataclasses.dataclass
class _Preempted:
    """A lane frozen off-device: everything needed to resume decode at
    the saved frontier with zero re-prefill. Exclusively owned pages
    went to the host offload store (keyed by ``req.uid``);
    prefix-shared pages stayed pinned on-device (``pinned``: logical
    block-table index -> pool page, reference HELD through the
    preemption)."""
    req: Request
    offset: int
    generated: list[int]
    token_times: list[float]
    pending: int               # next token to feed (KV not yet written)
    frontier: int              # cache slot decode resumes at
    remaining: int             # decode budget left
    n_pages: int               # logical pages the block table covered
    pinned: dict[int, int]
    # crash-salvaged (serving/recovery.py) rather than preempted: its
    # restore counts toward recovered_zero_reprefill
    recovered: bool = False
    # weight generation the lane decoded under; restore re-pins it so a
    # hot-swap while the lane was frozen cannot change its numerics
    gen: int = 0


def _pow2_bucket(n: int, cap: int) -> int:
    """Smallest power of two >= n, clipped to [1, cap] — the paged
    attention read width (bounds the jit cache to O(log cap) entries)."""
    return max(1, min(cap, 1 << max(0, (n - 1).bit_length())))


# every engine stat, declared ONCE with its kind (obs/metrics.py):
# reset_stats / snapshot / Prometheus exposition all derive from the
# registry, so adding a metric here is the whole job — there is no
# second list to forget (the drift bug class that bit PR 6 and PR 7)
_METRICS = [
    ("counter", "prefill_chunks", "jitted prefill chunk calls"),
    ("counter", "prefill_tokens", "prompt tokens actually computed"),
    ("counter", "decode_slabs", "on-device decode slab calls"),
    ("counter", "decode_steps", "decode steps (slab_k per slab)"),
    ("counter", "decode_tokens", "tokens emitted by decode"),
    ("counter", "generated_tokens", "all tokens emitted"),
    ("counter", "prefill_s", "seconds in prefill calls"),
    ("counter", "decode_s", "seconds in decode slabs"),
    ("counter", "admitted", "requests admitted to lanes"),
    ("counter", "evicted", "lanes freed (finish or failure)"),
    ("counter", "truncated", "requests that hit the slot cap"),
    # mixed batching: fused decode+prefill calls, the time spent in
    # them, and the stall counter — a stalled decode step is one
    # blocking prefill call that ran while live decode lanes waited
    # (phased admission; structurally 0 when mixed)
    ("counter", "mixed_steps", "fused decode+prefill calls"),
    ("counter", "mixed_s", "seconds in fused mixed calls"),
    ("counter", "stalled_decode_steps",
     "blocking prefill calls that stalled live decode lanes"),
    # paged attention read accounting (page units): what the
    # block-table gather touched vs a dense max_len read
    ("counter", "pages_read", "pages the paged attention gathered"),
    ("counter", "pages_read_dense_equiv",
     "pages a dense max_len read would have touched"),
    ("gauge", "peak_kv_pages", "page pool in-use high-water"),
    # scheduler observability: queue depth high-water, page-gate
    # rejections, request queued time
    ("gauge", "queue_depth_peak", "admission queue depth high-water"),
    ("counter", "admission_rejections",
     "distinct queue heads blocked by the page gate"),
    ("counter", "queued_s_total", "total seconds requests queued"),
    ("gauge", "queued_s_max", "longest single queued wait"),
    # prefix-cache accounting: prompt_tokens is the demand,
    # prefill_tokens what was computed, the difference the radix hits
    ("counter", "prompt_tokens", "prompt tokens submitted"),
    ("counter", "prefix_hits", "admissions with a radix-tree match"),
    ("counter", "prefix_misses", "admissions with no match"),
    ("counter", "prefill_tokens_skipped",
     "prompt tokens covered by shared prefix pages"),
    ("counter", "cow_copies", "boundary pages copy-on-write duplicated"),
    ("counter", "cache_evicted_pages",
     "cached-idle pages reclaimed under pressure"),
    # preemption/offload accounting: lanes frozen and resumed, pages
    # round-tripped through host RAM (vs pinned-shared pages that
    # never left), and the host store's bytes high-water
    ("counter", "preemptions", "lanes frozen off-device"),
    ("counter", "restores", "preempted lanes resumed"),
    ("counter", "offloaded_pages", "pages downloaded to the host store"),
    ("counter", "restored_pages", "pages scattered back on restore"),
    ("counter", "preempt_pinned_pages",
     "shared pages that stayed pinned through preemption"),
    ("gauge", "offload_bytes_peak",
     "host offload store bytes high-water"),
    # page-gate accounting: distinct blocked heads
    # (admission_rejections) vs blocked steps
    ("counter", "admission_rejected_steps",
     "admission attempts a blocked head held off"),
    # fault tolerance: injected faults that fired, lanes quarantined
    # (non-finite logits or a corrupted offload record), watchdog
    # recoveries (crashes + hangs, split out), lanes that came back
    # from offloaded KV with ZERO re-prefill, tokens re-prefilled by
    # relaunches, and requests shed/cancelled before or during decode
    ("counter", "faults_injected", "injected faults that fired"),
    ("counter", "lanes_quarantined", "lanes torn down as untrusted"),
    ("counter", "recoveries", "supervisor recoveries completed"),
    ("counter", "recovered_zero_reprefill",
     "crash-salvaged lanes restored with zero re-prefill"),
    ("counter", "re_prefilled_tokens",
     "tokens re-prefilled by relaunches"),
    ("counter", "shed_requests", "submits shed by the queue bound"),
    ("counter", "cancelled", "requests cancelled (any stage)"),
    ("counter", "deadline_cancelled", "cancelled by SLA deadline"),
    ("counter", "watchdog_hangs", "hung steps the watchdog condemned"),
    ("counter", "engine_crashes", "engine-thread crashes recovered"),
    # weight hot-swap (serving/hotswap.py): swaps flipped, canary-gate
    # rejections (no flip happened), automatic post-flip rollbacks,
    # canary decode cost, and the generation bookkeeping gauges
    ("counter", "weight_swaps", "weight hot-swaps flipped"),
    ("counter", "swap_canary_failures", "swaps rejected by the canary "
     "gate before flipping"),
    ("counter", "swap_rollbacks", "flipped swaps rolled back"),
    ("counter", "swap_canary_tokens", "tokens decoded by swap canaries"),
    ("counter", "swap_quarantines",
     "new-generation lanes quarantined inside a swap monitor window"),
    ("gauge", "weight_generation", "current weight generation id"),
    ("gauge", "weight_generations_held",
     "distinct param generations held on device"),
    # per-request latency samples (monotonic clock): TTFT and
    # inter-token gaps, folded into p50/p95 by finalize_stats
    ("histogram", "ttft_s", "submit -> first token seconds"),
    ("histogram", "itl_s", "inter-token gap seconds"),
]


class Engine:
    """Continuous-batching greedy generation over pruned/packed weights.

    >>> eng = Engine(cfg, params, max_batch=4, max_len=64, slab_k=8)
    >>> uid = eng.submit(prompt_ids, max_new_tokens=32)
    >>> results = eng.run()          # {uid: GenResult}

    ``slab_k`` is the number of decode steps per jitted slab (host syncs
    once per slab); ``slab_k=1`` is the per-token baseline.

    ``paged=True`` (default) stores K/V in the shared page pool:
    ``page_size`` slots per page, ``n_pages`` pool pages (default sized
    to the contiguous cache's ``max_batch × max_len`` so the two modes
    are memory-comparable; shrink it to serve with less, or grow
    ``max_len`` far past contiguous reach). ``paged=False`` keeps the
    dense ``(B, max_len)`` slab — the parity baseline.
    ``attn_backend`` picks the paged decode attention implementation:
    'xla' (gather, the oracle), 'pallas' (blocked-gather TPU kernel), or
    'pallas_interp' (kernel in interpret mode, CPU tests).

    ``prefix_cache=True`` (paged only) shares prompt-prefix KV pages
    across requests through a refcounted radix tree
    (serving/prefix_cache.py): matched pages skip prefill entirely, a
    shared boundary page is copy-on-write duplicated before the lane
    may write it, and finished sequences are re-inserted for future
    hits (LRU-evicted under pool pressure). Greedy tokens are
    bitwise-identical with sharing on or off.

    ``mixed=True`` (paged only) fuses chunked prefill INTO the decode
    step under a token budget (``prefill_token_budget``, default
    ``max_batch + prefill_chunk``: a full decode batch plus one full
    chunk per step): admission never stalls running lanes
    (``stats["stalled_decode_steps"] == 0``), prompts are admitted
    chunk-granularly, and requests are admitted per-lane at
    ``offset == 0`` (no group right-alignment — per-lane query runs
    make the padding pointless, and a lane keeps its full ``max_len``
    headroom). ``mixed=False`` keeps the phased admit-then-decode loop
    as the parity oracle.
    """

    def __init__(self, cfg, params, *, max_batch: int, max_len: int,
                 prefill_chunk: int = 16, slab_k: int = 8,
                 eos_id: int | None = None, dist=None,
                 scheduler: FIFOScheduler | None = None,
                 paged: bool = True, page_size: int = 16,
                 n_pages: int | None = None, attn_backend: str = "xla",
                 prefix_cache: bool = False, mixed: bool = False,
                 prefill_token_budget: int | None = None,
                 preempt: bool = False, offload_store=None,
                 offload_capacity_bytes: int | None = None,
                 admission_queue_limit: int | None = None,
                 enforce_deadlines: bool = False, faults=None,
                 tracer=None):
        if not registry.supports_prefill_chunk(cfg):
            raise NotImplementedError(
                f"family {cfg.family!r} is not KV-cache servable by the "
                "engine; use serve_loop.generate")
        if paged and not registry.supports_paged(cfg):
            raise NotImplementedError(
                f"family {cfg.family!r} has no paged KV cache; pass "
                "paged=False")
        if prefix_cache and not paged:
            raise ValueError("prefix_cache=True requires paged=True "
                             "(pages are the unit of sharing)")
        if mixed and not paged:
            raise ValueError("mixed=True requires paged=True (the mixed "
                             "step writes per-lane query runs through "
                             "block tables)")
        if mixed and not registry.supports_mixed(cfg):
            raise NotImplementedError(
                f"family {cfg.family!r} has no mixed decode+prefill "
                "step; pass mixed=False")
        if preempt and not paged:
            raise ValueError("preempt=True requires paged=True (pages "
                             "are the unit of offload)")
        assert slab_k >= 1
        # NOT ``tracer or ...``: same falsy-default bug class as the
        # scheduler below — a fresh Tracer with an empty ring is truthy
        # today, but the guard costs nothing and documents the intent
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.metrics = MetricsRegistry()
        for kind, name, help in _METRICS:
            getattr(self.metrics, kind)(name, help)
        # the backward-compatible dict view: every existing
        # ``self.stats[...]`` read/write lands on a typed metric
        self.stats = self.metrics.view()
        self.cfg = cfg
        self.dist = dist     # hotswap canaries rebuild decode with it
        self.params = params
        # generational weights (serving/hotswap.py): ``_gen`` is the
        # generation NEW admissions decode under, ``_gen_params`` every
        # param set still referenced by some in-flight lane (old
        # generations are freed by ``_gc_generations`` when their last
        # lane retires), ``_gen_pins`` uid -> generation for crash
        # relaunches that must resume on their admission-time weights,
        # and ``_swap_monitor`` the post-flip rollback watcher
        self._gen = 0
        self._gen_params: dict[int, object] = {0: params}
        self._gen_pins: dict[int, int] = {}
        self._swap_monitor = None
        self.max_batch = max_batch
        self.max_len = max_len
        self.chunk = max(1, min(prefill_chunk, max_len))
        self.slab_k = slab_k
        self.eos_id = eos_id
        self.paged = paged
        self.mixed = mixed
        # NOT ``scheduler or ...``: schedulers define __len__, and an
        # empty (freshly built) one is falsy — ``or`` would silently
        # swap a caller's SLAScheduler for a new FIFO
        self.scheduler = (scheduler if scheduler is not None
                          else FIFOScheduler(
                              max_batch, max_len,
                              prefill_token_budget=prefill_token_budget))
        self.scheduler.tracer = self.tracer
        if prefill_token_budget is not None:
            self.scheduler.prefill_token_budget = prefill_token_budget
        elif getattr(self.scheduler, "prefill_token_budget", None) is None:
            # one full decode batch + one full prefill chunk per step
            self.scheduler.prefill_token_budget = max_batch + self.chunk
        # lanes whose prompt is still being (chunk-)prefilled across
        # steps: lane -> next prompt position (admission order — the
        # token-budget planner hands chunks out FIFO). Mixed mode only;
        # the phased engine drains tails inside admission.
        self._prefilling: dict[int, int] = {}
        self.lanes: list[_Lane | None] = [None] * max_batch
        # host mirror of the on-device per-lane state; uploaded to the
        # device ONLY when admission/eviction edits it (self._dirty)
        self._mirror = {
            "pending": np.zeros(max_batch, np.int32),
            "frontier": np.zeros(max_batch, np.int32),
            "offsets": np.zeros(max_batch, np.int32),
            "remaining": np.zeros(max_batch, np.int32),
            "live": np.zeros(max_batch, bool),
            # fault containment (serving/step.py _run_slab): poison is
            # the injection port (added to the first in-slab step's
            # logits, normally all zero), faulted the device-side
            # per-lane finite-check verdict the host quarantines on
            "poison": np.zeros(max_batch, np.float32),
            "faulted": np.zeros(max_batch, bool),
        }
        # load shedding + SLA enforcement + failure routing
        self.admission_queue_limit = admission_queue_limit
        self.enforce_deadlines = enforce_deadlines
        self._finish_times: deque[float] = deque(maxlen=32)
        # uid -> (original prompt, tokens emitted before a crash
        # relaunch): a relaunched request decodes over prompt+emitted,
        # but its GenResult must report the ORIGINAL split
        self._recovered_prefix: dict[int, tuple[np.ndarray, list[int]]] = {}
        # failure results harvested outside step()'s return (cancel,
        # corrupted restore, recovery) — drained at the next step
        self._pending_results: list[GenResult] = []
        self._step_idx = 0
        # set by the watchdog/supervisor to abort a wedged device call
        # (the injected-stall hook polls it; a real deployment would
        # map this to killing the device stream)
        self._condemned = threading.Event()
        self._faults = None
        self.pcache: PrefixCache | None = None
        # lanes frozen off-device by preemption, awaiting restore (any
        # paged engine can be preempted explicitly via ``preempt()``;
        # ``preempt=True`` additionally lets admission preempt
        # lower-priority lanes for a page-blocked urgent head)
        self.preempt_enabled = preempt
        self._preempted: list[_Preempted] = []
        if paged:
            self.page_size = page_size
            per_lane = -(-max_len // page_size)
            self.n_pages = (max_batch * per_lane if n_pages is None
                            else n_pages)
            self.max_pages = min(per_lane, self.n_pages)
            self.pool = PagePool(self.n_pages, page_size)
            self.cache = registry.init_paged_cache(cfg, self.n_pages,
                                                   page_size)
            if prefix_cache:
                self.pcache = PrefixCache(self.pool)
                self._copy_pages = jax.jit(make_copy_pages_step())
            self._mirror["bt"] = np.zeros((max_batch, self.max_pages),
                                          np.int32)
            # preemption plumbing: host store for offloaded page KV and
            # the jitted device<->host page movers (pow2-padded index
            # vectors keep the jit cache O(log max_pages))
            self._offload = (offload_store if offload_store is not None
                             else HostKVStore(offload_capacity_bytes))
            self._offload.tracer = self.tracer
            self._gather = jax.jit(make_gather_pages_step())
            self._scatter = jax.jit(make_scatter_pages_step())
            # page-unit feasibility moves INTO the scheduler's submit
            # gate so slot- and page-infeasible requests both reject
            # synchronously at submit with a consistent error
            self.scheduler.feasibility = self._check_feasible
            self._prefill = jax.jit(
                make_paged_prefill_chunk_step(cfg, dist=dist),
                static_argnames=("read_pages",))
            # one fused decode+prefill call (mixed engine steps AND the
            # phased engine's batched cross-request tail prefill)
            self._mixed_fn = jax.jit(make_mixed_step(cfg, dist=dist),
                                     static_argnames=("read_pages",))
            # query-width bucket cap: smallest power of two >= chunk
            self._wcap = 1 << max(0, (self.chunk - 1).bit_length())
            self._slab = jax.jit(
                make_paged_decode_slab_step(
                    cfg, slab_k, max_len, page_size, eos_id=eos_id,
                    dist=dist, attn_backend=attn_backend),
                static_argnames=("read_pages",))
        else:
            self.cache = registry.init_cache(cfg, max_batch, max_len)
            self._prefill = jax.jit(make_prefill_chunk_step(cfg,
                                                            dist=dist))
            self._slab = jax.jit(make_decode_slab_step(
                cfg, slab_k, max_len, eos_id=eos_id, dist=dist))
        self._dstate = None
        self._dirty = True
        self._uid = 0
        self.reset_stats()
        if faults is not None:
            self.install_faults(faults)

    def install_faults(self, plan) -> None:
        """Wire a seeded ``FaultPlan`` (serving/faults.py) into every
        injection point: the step hooks, the page allocator, and the
        offload store. Chaos-test plumbing — a production engine runs
        with no plan installed and every hook is a no-op."""
        self._faults = plan
        plan._engine = self
        if self.paged:
            self.pool.fault_hook = plan.on_alloc
            self._offload.fault_hook = plan.on_offload_save

    def reset_stats(self):
        """Zero every registered metric — DERIVED from the registry
        (obs/metrics.py), so a metric added to ``_METRICS`` (or
        auto-registered through the view) can never be missed here;
        the old hand-listed dict rebuild is gone."""
        self.metrics.reset()
        if hasattr(self.scheduler, "reset_stats"):
            self.scheduler.reset_stats()
        if getattr(self, "pool", None) is not None:
            self.pool.reset_peaks()
        if getattr(self, "_offload", None) is not None:
            self._offload.reset_peaks()

    # raw latency sample lists, now registry histograms (reset() clears
    # them in place); exposed under the old names so existing callers
    # and tests keep appending/reading plain lists
    @property
    def _ttft(self) -> list[float]:
        return self.metrics.histogram("ttft_s").samples

    @property
    def _itl(self) -> list[float]:
        return self.metrics.histogram("itl_s").samples

    # ------------------------------------------------------------- memory
    @property
    def page_bytes(self) -> int:
        """Bytes of ONE pool page across all layers, K+V."""
        k = self.cache["k"]
        layers, kv, hd = k.shape[0], k.shape[-2], k.shape[-1]
        return 2 * layers * self.page_size * kv * hd * k.dtype.itemsize

    @property
    def kv_bytes_peak(self) -> int:
        """Peak bytes of live KV data: pages actually pinned (paged) or
        the whole dense slab (contiguous)."""
        if self.paged:
            return self.pool.peak_in_use * self.page_bytes
        return self.cache["k"].nbytes + self.cache["v"].nbytes

    @property
    def kv_bytes_contiguous_equiv(self) -> int:
        """What a dense (B, max_len) cache of this config would hold."""
        k = self.cache["k"]
        layers, kv, hd = k.shape[0], k.shape[-2], k.shape[-1]
        return (2 * layers * self.max_batch * self.max_len * kv * hd
                * k.dtype.itemsize)

    # ------------------------------------------------------------ submit
    def submit(self, prompt, max_new_tokens: int = 32,
               uid: int | None = None, *, priority: int = 0,
               deadline_s: float | None = None) -> int:
        """Queue one request. ``priority`` is the SLA class (smaller =
        more urgent; only ordering-relevant when the engine runs an
        ``SLAScheduler``) and ``deadline_s`` an optional target latency
        — see serving/scheduler.py. Infeasible requests (no decode
        headroom under ``max_len``, or a paged extent the pool could
        never hold) raise ``ValueError`` HERE, synchronously: the
        scheduler's submit gate runs both checks (``_check_feasible``
        is installed as its feasibility hook), so a request never
        queues only to surface an error later.

        With ``admission_queue_limit`` set, a submit that would push the
        queue past the bound is SHED instead of queued unboundedly:
        ``BackpressureError`` carries a retry-after hint derived from
        the recent request-completion rate — already-admitted work keeps
        its latency; new arrivals are told when capacity is likely."""
        if (self.admission_queue_limit is not None
                and len(self.scheduler) >= self.admission_queue_limit):
            self.stats["shed_requests"] += 1
            self.tracer.event("request.shed",
                              queue_depth=len(self.scheduler))
            raise BackpressureError(len(self.scheduler),
                                    self.admission_queue_limit,
                                    self._retry_after_hint())
        uid = self._uid if uid is None else uid
        self._uid = max(self._uid, uid) + 1
        req = Request(uid, np.asarray(prompt), max_new_tokens,
                      priority=priority, deadline_s=deadline_s)
        self.scheduler.submit(req)
        if self.tracer.enabled:
            self.tracer.event("request.queued", t=req.queued_at,
                              uid=uid, prompt_len=req.prompt_len,
                              max_new_tokens=max_new_tokens,
                              priority=priority)
        self.stats["queue_depth_peak"] = max(
            self.stats["queue_depth_peak"], len(self.scheduler))
        return uid

    def _retry_after_hint(self) -> float:
        """Seconds until one queue slot plausibly frees: the inverse of
        the recent completion rate (last ``_finish_times`` window),
        clamped to [0.05, 60]. A cold engine (nothing finished yet)
        hints 1s — a guess, and documented as such in the error."""
        ft = self._finish_times
        if len(ft) >= 2 and ft[-1] > ft[0]:
            est = (ft[-1] - ft[0]) / (len(ft) - 1)
        else:
            est = 1.0
        return float(min(60.0, max(0.05, est)))

    def _check_feasible(self, req: Request) -> None:
        """Page-unit submit gate (paged engines), installed on the
        scheduler as its ``feasibility`` hook: runs after the slot gate
        (so ``prompt_len < max_len`` already holds) and rejects a
        request whose solo extent could never fit the pool."""
        need = self._page_cost([req])
        if need > self.n_pages:
            raise ValueError(
                f"oversized request: prompt of {req.prompt_len} "
                f"tokens + budget of {req.max_new_tokens} new tokens "
                f"needs {need} pages ({self.page_size} slots each) "
                f"even admitted alone, but the pool holds only "
                f"{self.n_pages} pages "
                f"({self.n_pages * self.page_size} cache slots) — "
                "shrink the request or grow n_pages")

    # ------------------------------------------------------- lane helpers
    @property
    def active_lanes(self) -> list[int]:
        return [i for i, l in enumerate(self.lanes) if l is not None]

    @property
    def frontiers(self) -> np.ndarray:
        """(max_batch,) per-lane cache-slot write positions."""
        return self._mirror["frontier"].copy()

    @property
    def block_tables(self) -> np.ndarray:
        """(max_batch, max_pages) logical page -> pool page (paged)."""
        return self._mirror["bt"].copy()

    def _sync_dstate(self):
        """Upload the host mirror as the device-side slab state — called
        lazily, only after admission/eviction edits."""
        if self._dirty:
            self._dstate = {k: jnp.asarray(v)
                            for k, v in self._mirror.items()}
            self._dirty = False

    def _page_cost(self, group: list[Request]) -> int:
        """Pages a tentative admission group pins: the group prefills
        right-aligned to the LONGEST member, so every lane's extent is
        ``min(group_width + budget - 1, max_len)`` slots (prefill writes
        the pad slots too; decode writes at most budget-1 more past the
        width)."""
        w = max(r.prompt_len for r in group)
        # max(.., w): prefill writes the full width even if the budget
        # were ever allowed below 1 — never pin fewer slots than it
        return sum(self.pool.slots_for(
            min(max(w + r.max_new_tokens - 1, w), self.max_len))
            for r in group)

    def _extent_pages(self, r: Request) -> int:
        """Pages covering one prefix-cached lane's whole extent (its own
        prompt is the group width: admission is per-request so every
        lane sits at offset 0 — see ``_admit_one``)."""
        return self._page_cost([r])

    def _effective_match(self, r: Request):
        """Radix match for admission, with the boundary-page CoW DROPPED
        when the request's extent fills the whole pool: the CoW needs
        the shared original and the private copy alive at once (extent
        + 1 pages), which such a request could never pin — keeping the
        tail match would make it permanently inadmissible (livelock)
        even though it fits cold. Full-page sharing never costs more
        than a cold admission, so it is always kept.
        Returns (match, extent_pages)."""
        m = self.pcache.match(r.prompt)
        extent = self._extent_pages(r)
        if m.tail_page is not None and extent >= self.n_pages:
            m = Match(m.pages, len(m.pages) * self.page_size)
        return m, extent

    def _page_cost_shared(self):
        """EFFECTIVE page-cost gate for prefix-shared admission, to be
        compared against ``free + reclaimable``: pages already in the
        radix tree cost nothing NEW, but matched pages that are
        currently cached-idle must be counted once — the admission will
        pin them, so they stop being reclaimable. Returns a
        ``group -> cost`` callable that memoizes the per-request radix
        match: the scheduler probes growing trial prefixes of the same
        queue, so each request is matched ONCE per admission attempt,
        not once per trial."""
        memo: dict[int, tuple[int, list[int]]] = {}

        def per_request(r: Request) -> tuple[int, list[int]]:
            if id(r) not in memo:
                m, extent = self._effective_match(r)
                pinned = m.pages + ([m.tail_page]
                                    if m.tail_page is not None else [])
                memo[id(r)] = (
                    extent - len(m.pages),
                    [p for p in pinned if self.pool.refcount(p) == 0])
            return memo[id(r)]

        def cost(group: list[Request]) -> int:
            new_pages = 0
            idle_matched: set[int] = set()
            for r in group:
                new, idle = per_request(r)
                new_pages += new
                idle_matched.update(idle)
            return new_pages + len(idle_matched)
        return cost

    def _finish(self, i: int, truncated: bool = False) -> GenResult:
        lane = self.lanes[i]
        self.lanes[i] = None
        self._mirror["live"][i] = False
        self._gen_pins.pop(lane.req.uid, None)
        if self.paged and lane.pages:
            # donation additionally requires CURRENT-generation KV: a
            # hot-swap flushed the radix tree at flip, and an old-gen
            # straggler finishing afterwards must not reseed it with
            # KV computed under retired weights
            if (self.pcache is not None and lane.offset == 0
                    and lane.gen == self._gen):
                # insert-on-finish: donate the pages covering every slot
                # this lane actually wrote — prompt AND emitted
                # continuation (slot s holds token seq[s]; offset 0 means
                # slot == canonical position, the sharing precondition).
                # Donated pages park as cached-idle on release below;
                # coverage the tree already has just frees.
                frontier = int(self._mirror["frontier"][i])
                seq = np.concatenate(
                    [lane.req.prompt,
                     np.asarray(lane.generated, np.int32)])[:frontier]
                self.pcache.insert(seq,
                                   lane.pages[:self.pool.slots_for(frontier)])
            self.pool.release(lane.pages)
            self._mirror["bt"][i] = 0
        self._dirty = True
        self.stats["evicted"] += 1
        self.stats["truncated"] += int(truncated)
        tt = lane.token_times
        ttft = max(0.0, tt[0] - lane.req.queued_at) if tt else 0.0
        self._ttft.append(ttft)
        self._itl.extend(b - a for a, b in zip(tt, tt[1:]))
        self._finish_times.append(time.monotonic())
        # a crash-relaunched request decoded over prompt+emitted; its
        # result must report the ORIGINAL prompt/generated split (TTFT
        # is recovery-local — the pre-crash timeline died with the
        # thread)
        prompt, gen = lane.req.prompt, lane.generated
        pre = self._recovered_prefix.pop(lane.req.uid, None)
        if pre is not None:
            prompt, gen = pre[0], list(pre[1]) + gen
        if self.tracer.enabled:
            self.tracer.event("request.finish", uid=lane.req.uid,
                              lane=i, tokens=len(gen), ttft_s=ttft,
                              truncated=truncated)
        return GenResult(lane.req.uid, prompt,
                         np.asarray(gen, np.int32), truncated,
                         ttft_s=ttft)

    # --------------------------------------------- quarantine / cancel
    def _failed_result(self, req: Request, generated: list[int],
                       exc: Exception) -> GenResult:
        """Build the structured-failure GenResult for ``req``, merging
        any crash-relaunch prefix so the prompt/generated split is the
        original one. No TTFT/ITL samples — failed requests must not
        skew the latency percentiles."""
        prompt, gen = req.prompt, list(generated)
        pre = self._recovered_prefix.pop(req.uid, None)
        if pre is not None:
            prompt, gen = pre[0], list(pre[1]) + gen
        return GenResult(req.uid, prompt, np.asarray(gen, np.int32),
                         error=exc)

    def _fail_lane(self, i: int, exc: Exception) -> GenResult:
        """Tear down lane ``i`` with a structured error: free its pages
        (NEVER donating to the prefix cache — a quarantined lane's KV
        is not trusted; shared pages it pinned just unpin), clear its
        device state, and route the failure out as a GenResult. The
        other lanes' device state is untouched — their token streams
        stay bitwise-identical to a fault-free run."""
        lane = self.lanes[i]
        self.lanes[i] = None
        self._gen_pins.pop(lane.req.uid, None)
        if (self._swap_monitor is not None
                and isinstance(exc, (LaneFaultError,
                                     OffloadCorruptionError))):
            # post-flip rollback evidence: quarantines of lanes on the
            # freshly flipped generation (serving/hotswap.py)
            self._swap_monitor.note_quarantine(lane.gen, self)
        m = self._mirror
        m["live"][i] = False
        m["faulted"][i] = False
        m["poison"][i] = 0.0
        if self.paged and lane.pages:
            self.pool.release(lane.pages)
            m["bt"][i] = 0
        self._prefilling.pop(i, None)
        self._dirty = True
        self.stats["evicted"] += 1
        self._finish_times.append(time.monotonic())
        if self.tracer.enabled:
            name = ("request.quarantined"
                    if isinstance(exc, (LaneFaultError,
                                        OffloadCorruptionError))
                    else "request.failed")
            self.tracer.event(name, uid=lane.req.uid, lane=i,
                              error=type(exc).__name__,
                              tokens=len(lane.generated))
        return self._failed_result(lane.req, lane.generated, exc)

    def _harvest_faults(self, finished: list[GenResult]) -> None:
        """Quarantine every lane the device-side finite check flagged
        this step (slab carry or mixed-step verdict, already folded
        into the mirror): each fails ONLY its own request with
        ``LaneFaultError``."""
        m = self._mirror
        if not m["faulted"].any():
            return
        for i in self.active_lanes:
            if m["faulted"][i]:
                uid = self.lanes[i].req.uid
                self.stats["lanes_quarantined"] += 1
                finished.append(self._fail_lane(i, LaneFaultError(uid, i)))
        m["faulted"][:] = False
        self._dirty = True

    def _cancel_expired(self, finished: list[GenResult]) -> None:
        """SLA-deadline enforcement (``enforce_deadlines=True``): a
        lane whose absolute deadline passed is cancelled at this host
        sync — its pages free, the remaining lanes' device state (and
        token streams) are bitwise-unchanged."""
        now = time.monotonic()
        for i in self.active_lanes:
            req = self.lanes[i].req
            if req.deadline_at is not None and now > req.deadline_at:
                self.stats["deadline_cancelled"] += 1
                self.stats["cancelled"] += 1
                finished.append(
                    self._fail_lane(i, DeadlineExceededError(req.uid)))

    def cancel(self, uid: int) -> bool:
        """Cancel a request wherever it currently lives — queued,
        decoding on a lane, or frozen preempted — releasing every
        resource it held (lane, pages, offload record; prefix-cache
        state stays consistent: cancelled work is never donated). The
        failure surfaces as a ``RequestCancelledError`` GenResult at
        the next step. Idempotent: False when the uid is not in flight
        (already finished, or never submitted)."""
        req = None
        if hasattr(self.scheduler, "remove"):
            req = self.scheduler.remove(uid)
        if req is not None:
            self.stats["cancelled"] += 1
            self._gen_pins.pop(uid, None)
            self._pending_results.append(
                self._failed_result(req, [], RequestCancelledError(uid)))
            return True
        for j, pre in enumerate(self._preempted):
            if pre.req.uid == uid:
                self._preempted.pop(j)
                self._gen_pins.pop(uid, None)
                self._offload.drop(uid)
                if pre.pinned:
                    self.pool.release(list(pre.pinned.values()))
                self.stats["cancelled"] += 1
                self._pending_results.append(self._failed_result(
                    pre.req, pre.generated, RequestCancelledError(uid)))
                return True
        for i in self.active_lanes:
            if self.lanes[i].req.uid == uid:
                self.stats["cancelled"] += 1
                self._pending_results.append(
                    self._fail_lane(i, RequestCancelledError(uid)))
                return True
        return False

    # ---------------------------------------------------------- preemption
    def _download_pages(self, pages: list[int]):
        """Device -> host pull of ``pages`` (physical indices), padded
        to a power-of-two gather width so the jit cache stays
        O(log max_pages); the pad rows are sliced off on the host."""
        n = len(pages)
        w = 1 << max(0, (n - 1).bit_length())
        idx = np.asarray(pages + [pages[0]] * (w - n), np.int32)
        k, v = self._gather(self.cache, jnp.asarray(idx))
        k = np.asarray(jax.block_until_ready(k))[:, :n].copy()
        v = np.asarray(v)[:, :n].copy()
        return k, v

    def _upload_pages(self, dst: list[int], k: np.ndarray,
                      v: np.ndarray) -> None:
        """Host -> device scatter of offloaded page KV into freshly
        allocated pages ``dst``. Power-of-two padding repeats the first
        page WITH its own data — duplicate scatter indices then write
        identical values, a no-op."""
        n = len(dst)
        w = 1 << max(0, (n - 1).bit_length())
        if w > n:
            dst = dst + [dst[0]] * (w - n)
            k = np.concatenate([k] + [k[:, :1]] * (w - n), axis=1)
            v = np.concatenate([v] + [v[:, :1]] * (w - n), axis=1)
        self.cache = self._scatter(self.cache, jnp.asarray(dst, np.int32),
                                   jnp.asarray(k), jnp.asarray(v))

    def preempt(self, i: int) -> None:
        """Freeze lane ``i`` off-device: download its exclusively owned
        LIVE pages (slots ``[0, frontier)``) to the host offload store,
        keep prefix-shared/cached pages pinned on-device (their
        refcount keeps the KV alive for the other readers — they are
        NEVER offloaded while shared), and release everything releasable
        (downloaded pages + the garbage extent past the frontier) to
        the pool. The lane's decode state (pending token, frontier,
        remaining budget) is saved so restore resumes with zero
        re-prefilled tokens — bitwise-identical greedy continuation
        (tests/test_preemption.py).

        Only live decode lanes preempt: a lane mid-prefill holds no
        resumable decode state worth offloading (evicting it would mean
        re-prefill, exactly what preemption exists to avoid)."""
        assert self.paged, "preemption requires the paged engine"
        lane = self.lanes[i]
        assert lane is not None and i not in self._prefilling, \
            f"lane {i} is not preemptible"
        m = self._mirror
        assert bool(m["live"][i]), "only live decode lanes preempt"
        n_live = self.pool.slots_for(int(m["frontier"][i]))
        dl_logical: list[int] = []
        dl_pages: list[int] = []
        pinned: dict[int, int] = {}
        for j in range(n_live):
            p = lane.pages[j]
            if self.pool.exclusive(p):
                dl_logical.append(j)
                dl_pages.append(p)
            else:
                pinned[j] = p            # reference HELD through preempt
        if dl_pages:
            k, v = self._download_pages(dl_pages)
            self._offload.save(lane.req.uid, dl_logical, k, v)
            self.stats["offloaded_pages"] += len(dl_pages)
            self.stats["offload_bytes_peak"] = max(
                self.stats["offload_bytes_peak"], self._offload.bytes_peak)
        self.stats["preempt_pinned_pages"] += len(pinned)
        # garbage extent pages (past the frontier) free without download
        # — they are never shared: sharing covers at most the prompt,
        # and a live lane's frontier is at least its prompt width
        self.pool.release(dl_pages + lane.pages[n_live:])
        self._preempted.append(_Preempted(
            req=lane.req, offset=lane.offset, generated=lane.generated,
            token_times=lane.token_times, pending=int(m["pending"][i]),
            frontier=int(m["frontier"][i]),
            remaining=int(m["remaining"][i]), n_pages=len(lane.pages),
            pinned=pinned, gen=lane.gen))
        self.lanes[i] = None
        m["live"][i] = False
        m["bt"][i] = 0
        self._dirty = True
        self.stats["preemptions"] += 1
        if self.tracer.enabled:
            self.tracer.event("request.preempt", uid=self._preempted[-1].req.uid,
                              lane=i, offloaded_pages=len(dl_pages),
                              pinned_pages=len(pinned))

    def _restore_one(self, pre: _Preempted) -> bool:
        """Re-admit one preempted lane: alloc fresh pages for every
        logical slot that was offloaded (or garbage), interleave the
        still-pinned shared pages at their logical positions, scatter
        the host KV back, and rebuild the lane at the saved frontier.
        False when no lane is free or the pool can't cover it yet."""
        free = [i for i, l in enumerate(self.lanes) if l is None]
        if not free:
            return False
        own_need = pre.n_pages - len(pre.pinned)
        if self.pcache is not None:
            short = own_need - self.pool.free_pages
            if short > 0:
                self.stats["cache_evicted_pages"] += \
                    self.pcache.evict(short)
        if own_need > self.pool.free_pages:
            return False
        i = free[0]
        own = iter(self.pool.alloc(own_need))
        pages = [pre.pinned[j] if j in pre.pinned else next(own)
                 for j in range(pre.n_pages)]
        try:
            rec = self._offload.pop(pre.req.uid)
        except OffloadCorruptionError as e:
            # the parked KV rotted in host RAM: this request fails
            # structurally (its checksummed record is gone), everyone
            # else is untouched — release everything the lane held
            # (own pages at rc 1 free; pinned-shared ones just unpin)
            self.pool.release(pages)
            self._mirror["bt"][i] = 0
            self.stats["lanes_quarantined"] += 1
            self.tracer.event("request.quarantined", uid=pre.req.uid,
                              error=type(e).__name__,
                              tokens=len(pre.generated))
            self._pending_results.append(self._failed_result(
                pre.req, pre.generated,
                LaneFaultError(pre.req.uid, -1, reason=str(e))))
            return True          # entry resolved: _try_restore pops it
        if rec is not None:   # None: every live page was pinned-shared
            dst = [pages[j] for j in rec.logical]
            self._upload_pages(dst, rec.k, rec.v)
            self.stats["restored_pages"] += len(dst)
            if pre.recovered:
                self.stats["recovered_zero_reprefill"] += 1
        self.lanes[i] = _Lane(pre.req, pre.offset, pre.generated,
                              pages=pages, token_times=pre.token_times,
                              gen=pre.gen)
        m = self._mirror
        m["bt"][i] = 0
        m["bt"][i, :len(pages)] = pages
        m["offsets"][i] = pre.offset
        m["frontier"][i] = pre.frontier
        m["remaining"][i] = pre.remaining
        m["pending"][i] = pre.pending
        m["live"][i] = True
        self._dirty = True
        self.stats["restores"] += 1
        if self.tracer.enabled:
            self.tracer.event(
                "request.restore", uid=pre.req.uid, lane=i,
                frontier=pre.frontier, recovered=pre.recovered,
                restored_pages=(len(rec.logical) if rec is not None
                                else 0))
        return True

    def _try_restore(self) -> None:
        """Readmit preempted lanes, most urgent first, unless the queue
        head outranks them (then lanes/pages stay reserved for it —
        restoring a batch lane just to preempt it again would thrash).
        Head-of-line within the preempted set: a lane that does not fit
        yet blocks the less urgent ones behind it."""
        if not self._preempted:
            return
        self._preempted.sort(key=lambda p: (p.req.priority, p.req._seq))
        while self._preempted:
            head = self.scheduler.head()
            if (head is not None
                    and head.priority < self._preempted[0].req.priority):
                return
            if not self._restore_one(self._preempted[0]):
                return
            self._preempted.pop(0)

    def _releasable(self, i: int) -> int:
        """Pages preempting lane ``i`` would actually return to the
        pool (its exclusively owned ones; pinned-shared pages stay)."""
        return sum(1 for p in self.lanes[i].pages
                   if self.pool.exclusive(p))

    def _shortfall(self, head: Request) -> int:
        """Pages the queue head still needs beyond what the pool can
        provide right now (mode-aware: prefix-shared admission counts
        effective cost against free + reclaimable-cached)."""
        if self.pcache is not None:
            return (self._page_cost_shared()([head])
                    - self.pool.free_pages - self.pcache.reclaimable())
        return self._page_cost([head]) - self.pool.free_pages

    def _preempt_for_head(self) -> bool:
        """Make room for a more urgent page- or lane-blocked queue head
        by preempting strictly-lower-priority live lanes, least urgent
        (then latest-arrived) first. Stops as soon as the head fits, no
        candidate remains, or the next preemption would gain nothing
        (short of pages but the victim has none to release). Returns
        True when at least one lane was preempted (the caller re-runs
        admission)."""
        head = self.scheduler.head()
        if head is None:
            return False
        did = False
        while True:
            free_lane = any(l is None for l in self.lanes)
            short = self._shortfall(head)
            if free_lane and short <= 0:
                return did
            cands = [i for i in self.active_lanes
                     if bool(self._mirror["live"][i])
                     and i not in self._prefilling
                     and self.lanes[i].req.priority > head.priority]
            if not cands:
                return did
            victim = max(cands, key=lambda i: (self.lanes[i].req.priority,
                                               self.lanes[i].req._seq))
            if free_lane and short > 0 and self._releasable(victim) == 0:
                return did
            try:
                self.preempt(victim)
            except OffloadCapacityError:
                # host store full: the victim keeps running (preempt
                # raises before mutating anything) and the head waits
                # for capacity the normal way
                return did
            did = True

    # ----------------------------------------------------------- admission
    def _note_admitted(self, reqs: list[Request]) -> None:
        now = time.monotonic()
        tr = self.tracer
        for r in reqs:
            q = max(0.0, now - r.queued_at)
            self.stats["queued_s_total"] += q
            self.stats["queued_s_max"] = max(self.stats["queued_s_max"], q)
            if tr.enabled:
                tr.event("request.admitted", t=now, uid=r.uid,
                         queued_s=q, priority=r.priority)
        self.stats["admitted"] += len(reqs)

    def _admit(self) -> None:
        """Admission, with preemption as the fallback: when the plain
        pass leaves a queue head behind and ``preempt=True``, try to
        free lanes/pages by preempting strictly-lower-priority lanes,
        then admit again."""
        self._admit_once()
        if (self.paged and self.preempt_enabled and len(self.scheduler)
                and self._preempt_for_head()):
            self._admit_once()

    def _admit_once(self) -> None:
        free = [i for i, l in enumerate(self.lanes) if l is None]
        if self.pcache is not None:
            self._admit_shared(free)
            return
        if self.mixed:
            self._admit_mixed(free)
            return
        if self.paged:
            reqs = self.scheduler.admit(len(free), self.pool.free_pages,
                                        self._page_cost)
        else:
            reqs = self.scheduler.admit(len(free))
        if not reqs:
            return
        # partition by target weight generation: everything lands on the
        # current weights except crash relaunches pinned to their
        # admission-time generation (the common single-generation case
        # is one group — the exact pre-swap code path)
        groups: dict[int, list[Request]] = {}
        for r in reqs:
            groups.setdefault(self._gen_pins.get(r.uid, self._gen),
                              []).append(r)
        m = self._mirror
        built: list[tuple[int, int, list[int]]] = []   # (gen, W, lanes)
        try:
            for gen in sorted(groups):
                sub = groups[gen]
                # the admitted group prefills right-aligned in slots
                # [0, W): a lane freed mid-traffic restarts at slot 0
                width = max(r.prompt_len for r in sub)
                new_lanes = []
                for r in sub:
                    i = free.pop(0)
                    off = width - r.prompt_len
                    self.lanes[i] = _Lane(r, off, [], gen=gen)
                    if self.paged:
                        need = self.pool.slots_for(
                            min(max(width + r.max_new_tokens - 1, width),
                                self.max_len))
                        self.lanes[i].pages = self.pool.alloc(need)
                        m["bt"][i] = 0
                        m["bt"][i, :need] = self.lanes[i].pages
                    m["offsets"][i] = off
                    m["frontier"][i] = width
                    m["remaining"][i] = r.max_new_tokens - 1
                    m["pending"][i] = 0
                    m["live"][i] = True
                    new_lanes.append(i)
                built.append((gen, width, new_lanes))
        except BaseException:
            # crash-safe admission: a page-alloc failure mid-group must
            # not LOSE requests — whatever never reached a lane goes
            # back to the queue head (the one stranded on a half-built
            # lane relaunches through supervisor recovery); the crash
            # still propagates to the watchdog
            placed = {self.lanes[j].req.uid for j in range(
                self.max_batch) if self.lanes[j] is not None}
            self.scheduler.push_front(
                [r for r in reqs if r.uid not in placed])
            raise
        self._dirty = True     # one upload, in step() before the slab
        self._note_admitted(reqs)

        for gen, width, new_lanes in built:
            # chunked batched prefill over [0, width), right-aligned,
            # through this group's OWN generation of the weights
            tokens = np.zeros((self.max_batch, width), np.int32)
            for i in new_lanes:
                p = self.lanes[i].req.prompt
                tokens[i, width - p.size:] = p
            self._run_prefill(new_lanes, tokens, 0, width,
                              params=self._gen_params[gen])
        self.stats["prefill_tokens"] += sum(r.prompt_len for r in reqs)
        self.stats["prompt_tokens"] += sum(r.prompt_len for r in reqs)

    def _run_prefill(self, lane_ids: list[int], tokens: np.ndarray,
                     start: int, cover_slots: int, params=None) -> None:
        """The chunked-prefill loop shared by group admission (whole
        width from slot 0) and prefix-cached per-lane admission (tail
        only, from slot ``start``): runs ``tokens[:, start:]`` through
        ``prefill_chunk`` in whole chunks (the first may be short, the
        rest ``self.chunk`` wide, so the jit cache sees at most C
        distinct shapes), lanes outside ``lane_ids`` shielded by the
        lane mask, then folds each lane's FIRST generated token into
        the mirror. ``cover_slots`` bounds the paged attention read.
        Callers account prefill_tokens/prompt_tokens themselves (pad
        and shared-prefix slots don't count as prefilled tokens).
        ``params`` selects the weight generation (defaults to the
        current one)."""
        params = self.params if params is None else params
        width = tokens.shape[1]
        lane_mask = np.zeros((self.max_batch,), bool)
        lane_mask[lane_ids] = True
        offsets = jnp.asarray(self._mirror["offsets"])
        mask_j = jnp.asarray(lane_mask)
        toks_j = jnp.asarray(tokens)
        if self.paged:
            bt_j = jnp.asarray(self._mirror["bt"])
            r_pf = _pow2_bucket(self.pool.slots_for(cover_slots),
                                self.max_pages)
        last = None
        pos = start
        span = width - start
        rem = span % self.chunk
        sizes = ([rem] if rem else []) + [self.chunk] * (span // self.chunk)
        # phased-stall accounting: every one of these blocking calls
        # runs while the OTHER live lanes' decode waits
        stalled = any(bool(self._mirror["live"][j])
                      for j in self.active_lanes if not lane_mask[j])
        if stalled:
            self.stats["stalled_decode_steps"] += len(sizes)
        t0 = time.monotonic()
        for c in sizes:
            if self.paged:
                last, self.cache = self._prefill(
                    params, self.cache, toks_j[:, pos:pos + c],
                    jnp.int32(pos), offsets, mask_j, bt_j,
                    read_pages=r_pf)
                self.stats["pages_read"] += r_pf * len(lane_ids) * c
                self.stats["pages_read_dense_equiv"] += (
                    self.pool.slots_for(self.max_len)
                    * len(lane_ids) * c)
            else:
                last, self.cache = self._prefill(
                    params, self.cache, toks_j[:, pos:pos + c],
                    jnp.int32(pos), offsets, mask_j)
            pos += c
            self.stats["prefill_chunks"] += 1
        first = np.asarray(jax.block_until_ready(jnp.argmax(last, -1)))
        now = time.monotonic()
        self.stats["prefill_s"] += now - t0
        if self.tracer.enabled:
            # span from the timestamps this loop already took at its
            # sync points — tracing adds no sync of its own
            self.tracer.span_at(
                "prefill.chunks", t0, now, lanes=len(lane_ids),
                chunks=len(sizes), tokens=span,
                uids=[self.lanes[i].req.uid for i in lane_ids])
        for i in lane_ids:
            self._mirror["pending"][i] = int(first[i])
            self.lanes[i].generated.append(int(first[i]))
            self.lanes[i].token_times.append(now)
            self.stats["generated_tokens"] += 1

    # ------------------------------------------------- mixed admission
    def _admit_mixed(self, free: list[int]) -> None:
        """Chunk-granular admission (``mixed=True``, no prefix cache):
        each admitted request takes a lane at ``offset == 0`` (per-lane
        query runs need no group right-alignment, and the lane keeps
        its full ``max_len`` headroom), pins pages for its own extent,
        and registers as a PREFILLING lane — its prompt is fed to the
        fused mixed step chunk-by-chunk under the token budget instead
        of a blocking prefill loop here."""
        reqs = self.scheduler.admit(
            len(free), self.pool.free_pages,
            lambda group: sum(self._page_cost([r]) for r in group))
        m = self._mirror
        for j, r in enumerate(reqs):
            i = free.pop(0)
            need = self._page_cost([r])
            try:
                pages = self.pool.alloc(need)
            except BaseException:
                # crash-safe admission: un-placed requests go back to
                # the queue head; the crash propagates to the watchdog
                self.scheduler.push_front(reqs[j:])
                raise
            self.lanes[i] = _Lane(r, 0, [], pages=pages,
                                  gen=self._gen_pins.get(r.uid,
                                                         self._gen))
            m["bt"][i] = 0
            m["bt"][i, :need] = self.lanes[i].pages
            m["offsets"][i] = 0
            m["frontier"][i] = r.prompt_len
            m["remaining"][i] = r.max_new_tokens - 1
            m["pending"][i] = 0
            m["live"][i] = False          # decodable once the tail lands
            self._prefilling[i] = 0
            self.stats["prompt_tokens"] += r.prompt_len
        if reqs:
            self._dirty = True
            self._note_admitted(reqs)

    # ------------------------------------------- prefix-cached admission
    def _admit_shared(self, free: list[int]) -> None:
        """Admission with the radix-tree prefix cache: the scheduler
        gate sees the EFFECTIVE page cost (shared pages are free,
        capacity is free + reclaimable-cached), and each admitted
        request takes its own lane at ``offset == 0`` — sharing is
        positional, so every lane's cache slot must equal its logical
        position. A request whose re-checked match no longer covers
        what the gate assumed (a concurrent eviction inside this batch)
        is returned to the queue HEAD.

        The uncovered TAILS of every request admitted in this round are
        prefilled together: one batched cross-request loop through the
        mixed-step call (phased) or chunk-granular fusion into the
        decode steps (mixed) — never a per-lane chunk loop each."""
        avail = self.pool.free_pages + self.pcache.reclaimable()
        reqs = self.scheduler.admit(len(free), avail,
                                    self._page_cost_shared())
        tails: list[int] = []
        for j, r in enumerate(reqs):
            try:
                ok = self._admit_one(free[0], r)
            except BaseException:
                # crash-safe admission: see _admit_once
                self.scheduler.push_front(reqs[j:])
                raise
            if not ok:
                self.scheduler.push_front(reqs[j:])
                break
            tails.append(free.pop(0))
            self._note_admitted([r])
        if not self.mixed and tails:
            self._prefill_tails(tails)

    def _admit_one(self, i: int, r: Request) -> bool:
        """match -> pin shared pages -> evict-for-room -> alloc own
        pages -> CoW the boundary page -> register the tail prefill
        (``self._prefilling``; the caller batches it). Returns False
        when the pool can't cover the request — no lane/page state is
        held, but the eviction pass may already have dropped cold
        cached-idle entries (that reclaim is never undone)."""
        gen = self._gen_pins.get(r.uid, self._gen)
        if gen != self._gen:
            # a crash relaunch pinned to RETIRED weights must not match
            # the radix tree: cached KV always belongs to the current
            # generation (the hot-swap flushed everything older)
            m, extent = Match([], 0), self._extent_pages(r)
        else:
            m, extent = self._effective_match(r)
        # pin everything matched BEFORE eviction/allocation can touch
        # it: the tail page only until its copy lands, the full pages
        # for the lane's lifetime (they go into its block table)
        pin_tail = [m.tail_page] if m.tail_page is not None else []
        self.pool.retain(m.pages + pin_tail)
        own_need = extent - len(m.pages)
        short = own_need - self.pool.free_pages
        if short > 0:
            self.stats["cache_evicted_pages"] += self.pcache.evict(short)
        if own_need > self.pool.free_pages:
            self.pool.release(m.pages + pin_tail)   # un-pin, re-queue
            return False
        try:
            own = self.pool.alloc(own_need)
        except BaseException:
            self.pool.release(m.pages + pin_tail)   # no pins leak
            raise
        if m.tail_page is not None:
            # copy-on-write: the lane keeps writing this page (prompt
            # tail, then decode) — give it a private copy; the shared
            # original stays read-only in the tree
            self.cache = self._copy_pages(
                self.cache, jnp.asarray([m.tail_page], jnp.int32),
                jnp.asarray([own[0]], jnp.int32))
            self.pool.release(pin_tail)
            self.stats["cow_copies"] += 1
        pages = m.pages + own           # logical page order
        self.lanes[i] = _Lane(r, 0, [], pages=pages, gen=gen)
        mir = self._mirror
        mir["bt"][i] = 0
        mir["bt"][i, :len(pages)] = pages
        mir["offsets"][i] = 0
        mir["frontier"][i] = r.prompt_len
        mir["remaining"][i] = r.max_new_tokens - 1
        mir["pending"][i] = 0
        mir["live"][i] = False        # decodable once the tail lands
        self._prefilling[i] = m.matched_tokens
        self._dirty = True
        self.stats["prompt_tokens"] += r.prompt_len
        self.stats["prefix_hits"] += int(m.matched_tokens > 0)
        self.stats["prefix_misses"] += int(m.matched_tokens == 0)
        self.stats["prefill_tokens_skipped"] += m.matched_tokens
        return True

    def _prefill_tails(self, lane_ids: list[int]) -> None:
        """Batched cross-request tail prefill (phased engines): the
        uncovered tails ``[matched, plen)`` of every lane admitted in
        this round advance TOGETHER, one chunk each per fused call —
        ``ceil(max_tail / chunk)`` jitted calls total instead of a
        per-lane chunk loop each (the matched slots are already backed
        by shared or CoW-copied pages holding identical K/V, so the
        logits come out bitwise-equal to a full prefill). Blocking —
        running decode lanes stall (phased semantics, counted in
        ``stalled_decode_steps``); the mixed engine fuses these same
        tails into its decode steps instead."""
        while any(i in self._prefilling for i in lane_ids):
            plan = {i: min(self.lanes[i].req.prompt_len
                           - self._prefilling[i], self.chunk)
                    for i in lane_ids if i in self._prefilling}
            self._run_mixed([], plan)

    def _sweep_finished(self, finished: list[GenResult]) -> None:
        """Evict lanes whose budget is spent, that emitted eos (the
        first prefill token may already do either), or that ran out of
        cache slots (per-lane truncation)."""
        m = self._mirror
        for i in self.active_lanes:
            lane = self.lanes[i]
            done = (len(lane.generated) >= lane.req.max_new_tokens or
                    (self.eos_id is not None and lane.generated and
                     lane.generated[-1] == self.eos_id))
            if done:
                finished.append(self._finish(i))
            elif m["frontier"][i] >= self.max_len:
                finished.append(self._finish(i, truncated=True))

    # --------------------------------------------------------------- step
    def step(self) -> list[GenResult]:
        """One engine iteration. Phased (``mixed=False``): evict,
        (re)admit — which BLOCKS on the new prompts' whole prefill —
        then one decode SLAB (``slab_k`` on-device steps, one host
        sync). Mixed: evict, admit (chunk-granular, non-blocking), then
        either ONE fused decode+prefill call (whenever the token-budget
        planner assigned prompt chunks) or a decode slab (no prompt in
        flight — full slab throughput between admissions). Returns
        requests finished during this step — successes AND structured
        failures (quarantined / cancelled / expired), plus any failure
        results parked by out-of-band paths (cancel, recovery) since
        the last step.

        An installed ``FaultPlan`` fires here: host-side faults at the
        top (before any mutation — a crash leaves the engine at the
        previous step's consistent host-sync snapshot, which is what
        makes supervisor recovery possible), device-side faults at the
        jitted call sites."""
        idx = self._step_idx
        self._step_idx += 1
        if self._faults is not None:
            self._faults.on_step(idx, self)
        finished: list[GenResult] = self._pending_results
        self._pending_results = []
        self._sweep_finished(finished)
        if self.enforce_deadlines:
            self._cancel_expired(finished)
        if self._preempted:
            self._try_restore()    # older work first, unless outranked
        self._admit()
        self._sweep_finished(finished)   # e.g. max_new_tokens == 1
        if self.mixed:
            decode_lanes = [i for i in self.active_lanes
                            if self._mirror["live"][i]]
            tails = [(i, self.lanes[i].req.prompt_len - pos)
                     for i, pos in self._prefilling.items()]
            plan = self.scheduler.plan_chunks(tails, len(decode_lanes),
                                              self.chunk)
            if plan:
                self._run_mixed(decode_lanes, plan)
            elif decode_lanes:
                self._decode_slab()
        elif self.active_lanes:
            self._decode_slab()
        self._harvest_faults(finished)
        self._gc_generations()
        if self._swap_monitor is not None:
            self._swap_monitor.on_step_end(self)
        # failures parked DURING this step (e.g. a corrupted offload
        # record hit by _try_restore) come out with it, not one late
        finished.extend(self._pending_results)
        self._pending_results = []
        return finished

    def _gc_generations(self) -> None:
        """Drop weight generations no lane, preempted record, or pin
        references any more — the moment the last admission-time-pinned
        request retires, the pre-swap params are freed. The CURRENT
        generation is always held."""
        if len(self._gen_params) == 1:
            return
        held = {self._gen}
        held.update(l.gen for l in self.lanes if l is not None)
        held.update(p.gen for p in self._preempted)
        held.update(self._gen_pins.values())
        for g in [g for g in self._gen_params if g not in held]:
            del self._gen_params[g]
        self.stats["weight_generations_held"] = len(self._gen_params)

    def swap_weights(self, artifact_dir: str, **kw):
        """Zero-downtime weight hot-swap from a sealed artifact:
        validate -> stage -> canary -> generational flip -> monitored
        commit (or automatic rollback). See serving/hotswap.py for the
        state machine; this is a convenience wrapper so callers hold
        only an Engine. Must be called between steps (slab boundary)."""
        from repro.serving import hotswap
        return hotswap.swap_weights(self, artifact_dir, **kw)

    def _decode_slab(self) -> None:
        """One decode slab: the on-device ``lax.scan`` token loop, one
        host sync per ``slab_k`` steps.

        During a hot-swap transition window (serving/hotswap.py) the
        live lanes may span several WEIGHT GENERATIONS: the slab then
        runs once per generation with the other generations' lanes
        masked out of ``live`` (batched decode is row-independent, so a
        masked lane's stream is bitwise-untouched — the same property
        the prefill lane-mask and continuous-batching parity already
        lean on). Outside a transition window — always, before the
        first swap — there is exactly one generation and this is the
        original single-call path."""
        self._sync_dstate()
        if self._faults is not None:
            self._faults.on_device_step(self._step_idx - 1, self)
        gens = sorted({self.lanes[i].gen for i in self.active_lanes
                       if self._mirror["live"][i]})
        if len(gens) <= 1:
            params = self._gen_params[gens[0]] if gens else self.params
            self._slab_call(params, self.active_lanes)
            return
        for g in gens:
            part = [i for i in self.active_lanes
                    if self._mirror["live"][i]
                    and self.lanes[i].gen == g]
            mask = np.zeros(self.max_batch, bool)
            mask[part] = True
            save_live = self._mirror["live"].copy()
            save_poison = self._mirror["poison"].copy()
            # mask the other generations out of this call; restore
            # their live/poison below (the scan zeroes poison and the
            # download would otherwise clobber their saved state)
            self._mirror["live"] = save_live & mask
            self._mirror["poison"] = np.where(mask, save_poison, 0.0)
            self._dirty = True
            self._sync_dstate()
            self._slab_call(self._gen_params[g], part)
            m = self._mirror
            m["live"] = np.where(mask, m["live"], save_live)
            m["poison"] = np.where(mask, m["poison"], save_poison)
            self._dirty = True

    def _slab_call(self, params, lanes: list[int]) -> None:
        """One jitted slab dispatch + host fold for ``lanes`` (the
        other lanes ride along masked)."""
        t0 = time.monotonic()
        if self.paged:
            fmax = int(max(self._mirror["frontier"][i] for i in lanes))
            need = min(fmax + self.slab_k, self.max_len)
            r = _pow2_bucket(self.pool.slots_for(need), self.max_pages)
            block, self._dstate, self.cache = self._slab(
                params, self.cache, self._dstate, read_pages=r)
            n = len(lanes) * self.slab_k
            self.stats["pages_read"] += r * n
            self.stats["pages_read_dense_equiv"] += (
                self.pool.slots_for(self.max_len) * n)
        else:
            block, self._dstate, self.cache = self._slab(
                params, self.cache, self._dstate)
        block = np.asarray(jax.block_until_ready(block))
        now = time.monotonic()
        self.stats["decode_s"] += now - t0
        self.stats["decode_slabs"] += 1
        self.stats["decode_steps"] += self.slab_k
        if self.tracer.enabled:
            self.tracer.span_at(
                "decode.slab", t0, now, k=self.slab_k,
                lanes=len(lanes),
                uids=[self.lanes[i].req.uid for i in lanes])
        self._replay(block, now)

    def _run_mixed(self, decode_lanes: list[int],
                   plan: dict[int, int]) -> None:
        """ONE fused decode+prefill call: decode lanes contribute one
        token each (q_len 1 at their frontier), ``plan`` lanes a prompt
        chunk (q_len c at their prefill position), padded to a
        power-of-two query width (jit cache stays O(log chunk)). The
        host folds the returned per-lane next tokens: decode lanes
        advance with EXACTLY the slab's stop logic (frontier/remaining/
        eos — bitwise-identical greedy streams), prefill lanes advance
        their prompt position and go live when the tail lands (their
        argmax is the request's first generated token).

        Also the phased engine's batched tail-prefill core
        (``decode_lanes == []``): then the call time is prefill time
        and running decode lanes are stalled by it (counted).

        As in ``_decode_slab``, a hot-swap transition window may leave
        the participating lanes spanning several weight generations:
        the call then runs once per generation over that generation's
        lanes only (row independence keeps the split bitwise-exact);
        the single-generation case — always, outside a swap window —
        is the original one-call path."""
        gens = sorted({self.lanes[i].gen for i in decode_lanes}
                      | {self.lanes[i].gen for i in plan})
        if len(gens) <= 1:
            params = self._gen_params[gens[0]] if gens else self.params
            self._mixed_call(decode_lanes, plan, params)
            return
        for g in gens:
            dl = [i for i in decode_lanes if self.lanes[i].gen == g]
            pl = {i: c for i, c in plan.items()
                  if self.lanes[i].gen == g}
            if dl or pl:
                self._mixed_call(dl, pl, self._gen_params[g],
                                 split=True)

    def _mixed_call(self, decode_lanes: list[int], plan: dict[int, int],
                    params, split: bool = False) -> None:
        """One jitted fused call + host fold. ``split=True`` (per-
        generation call) masks the poison carry to this call's own
        lanes and clears only theirs afterwards, so a poison aimed at
        another generation's lane still reaches ITS call."""
        m = self._mirror
        w = _pow2_bucket(max(plan.values(), default=1), self._wcap)
        tokens = np.zeros((self.max_batch, w), np.int32)
        starts = np.zeros(self.max_batch, np.int32)
        q_lens = np.zeros(self.max_batch, np.int32)
        need = 1
        for i in decode_lanes:
            tokens[i, 0] = m["pending"][i]
            starts[i] = m["frontier"][i]
            q_lens[i] = 1
            need = max(need, int(m["frontier"][i]) + 1)
        for i, c in plan.items():
            pos = self._prefilling[i]
            tokens[i, :c] = self.lanes[i].req.prompt[pos:pos + c]
            starts[i] = pos
            q_lens[i] = c
            need = max(need, pos + c)
        covered = set(decode_lanes) | set(plan)
        if plan and any(bool(m["live"][j]) for j in self.active_lanes
                        if j not in covered):
            self.stats["stalled_decode_steps"] += 1
        r = _pow2_bucket(self.pool.slots_for(need), self.max_pages)
        if self._faults is not None:
            self._faults.on_device_step(self._step_idx - 1, self)
        if split:
            pmask = np.zeros(self.max_batch, bool)
            pmask[list(covered)] = True
            poison = np.where(pmask, m["poison"], 0.0)
        else:
            poison = m["poison"]
        t0 = time.monotonic()
        nxt, faulted, self.cache = self._mixed_fn(
            params, self.cache, jnp.asarray(tokens),
            jnp.asarray(starts), jnp.asarray(q_lens),
            jnp.asarray(m["offsets"]), jnp.asarray(m["bt"]),
            read_pages=r, poison=jnp.asarray(poison))
        if split:
            m["poison"] = np.where(pmask, 0.0, m["poison"])
        else:
            m["poison"][:] = 0.0     # one-shot, like the slab's carry
        # the host only needs the token vector when somebody emits a
        # token this call (a decode lane, or a prompt finishing its
        # tail); mid-prompt-only calls stay ASYNC so consecutive chunk
        # dispatches pipeline like the phased prefill loop's — the
        # finite-check verdict is read at the same syncs (a fault in a
        # non-emitting chunk poisons the KV it wrote, so the NEXT
        # emitting call's check still catches that lane)
        fa = None
        if decode_lanes or any(self._prefilling[i] + c
                               >= self.lanes[i].req.prompt_len
                               for i, c in plan.items()):
            nxt = np.asarray(jax.block_until_ready(nxt))
            fa = np.asarray(faulted)
        now = time.monotonic()
        if self.tracer.enabled:
            self.tracer.span_at(
                "mixed.step", t0, now, decode_lanes=len(decode_lanes),
                prefill_lanes=len(plan),
                prefill_tokens=sum(plan.values()),
                uids=[self.lanes[i].req.uid
                      for i in set(decode_lanes) | set(plan)])
        if self.mixed:
            self.stats["mixed_steps"] += 1
        if decode_lanes:
            self.stats["mixed_s"] += now - t0
            self.stats["decode_steps"] += 1
        else:
            # no decode lane rode along (none live, or the phased
            # engine's batched tail prefill): pure prefill time
            self.stats["prefill_s"] += now - t0
        n_tok = len(decode_lanes) + sum(plan.values())
        self.stats["pages_read"] += r * n_tok
        self.stats["pages_read_dense_equiv"] += (
            self.pool.slots_for(self.max_len) * n_tok)
        if plan:
            self.stats["prefill_chunks"] += 1
            self.stats["prefill_tokens"] += sum(plan.values())
        for i in decode_lanes:
            if fa is not None and fa[i]:
                # non-finite logits: freeze the lane (frontier does not
                # advance, the garbage token is never kept) and leave
                # the verdict for _harvest_faults to quarantine
                m["faulted"][i] = True
                m["live"][i] = False
                continue
            t = int(nxt[i])
            self.lanes[i].generated.append(t)
            self.lanes[i].token_times.append(now)
            m["pending"][i] = t
            m["frontier"][i] += 1
            m["remaining"][i] -= 1
            if (m["remaining"][i] <= 0 or m["frontier"][i] >= self.max_len
                    or (self.eos_id is not None and t == self.eos_id)):
                m["live"][i] = False     # same cut as _run_slab's
            self.stats["generated_tokens"] += 1
            self.stats["decode_tokens"] += 1
        for i, c in plan.items():
            pos = self._prefilling[i] + c
            if pos < self.lanes[i].req.prompt_len:
                self._prefilling[i] = pos
                continue
            del self._prefilling[i]      # tail landed: first token out
            if fa is not None and fa[i]:
                m["faulted"][i] = True
                continue
            first = int(nxt[i])
            self.lanes[i].generated.append(first)
            self.lanes[i].token_times.append(now)
            m["pending"][i] = first
            m["live"][i] = True
            self.stats["generated_tokens"] += 1
        self._dirty = True

    def _replay(self, block: np.ndarray, now: float) -> None:
        """Fold a slab's token block into the host mirror using the
        per-lane state the slab returned (downloaded at the same sync —
        the device's stop logic is the single source of truth): lane i
        kept exactly ``new_frontier - old_frontier`` tokens; anything it
        emitted after its stop point is discarded here."""
        new = {k: np.array(v) for k, v in self._dstate.items()}
        for i in self.active_lanes:
            kept = int(new["frontier"][i] - self._mirror["frontier"][i])
            self.lanes[i].generated.extend(
                int(t) for t in block[i, :kept])
            self.lanes[i].token_times.extend([now] * kept)
            self.stats["generated_tokens"] += kept
            self.stats["decode_tokens"] += kept
        self._mirror = new

    # ---------------------------------------------------------------- run
    def run(self) -> dict[int, GenResult]:
        """Drain the queue and all active lanes; {uid: GenResult}."""
        out: dict[int, GenResult] = {}
        while (len(self.scheduler) or self.active_lanes
               or self._preempted or self._pending_results):
            for r in self.step():
                out[r.uid] = r
        self.finalize_stats()
        return out

    def finalize_stats(self) -> dict:
        """Fold the raw counters into derived stats (throughputs, KV
        peaks, latency percentiles). ``run`` calls this at drain;
        callers driving ``step`` themselves (continuous-arrival
        harnesses) call it when their workload ends. Returns stats."""
        # decode throughput (oracle semantics: decode-emitted tokens
        # over decode time — mixed fused-call time included, since
        # those calls carry the decode tokens); e2e adds prefill
        dec_s = self.stats["decode_s"] + self.stats["mixed_s"]
        self.stats["tok_per_s"] = (
            self.stats["decode_tokens"] / dec_s if dec_s > 0 else 0.0)
        total_s = dec_s + self.stats["prefill_s"]
        self.stats["e2e_tok_per_s"] = (
            self.stats["generated_tokens"] / total_s
            if total_s > 0 else 0.0)
        # per-request latency: TTFT (submit -> first token) and
        # inter-token gaps, over the requests FINISHED since the last
        # reset_stats (tokens folded at one host sync share timestamps,
        # so in-slab gaps read 0 and the slab boundary carries the gap)
        for name, vals in (("ttft", self._ttft), ("itl", self._itl)):
            arr = np.asarray(vals, np.float64)
            self.stats[f"{name}_p50_s"] = (
                float(np.percentile(arr, 50)) if arr.size else 0.0)
            self.stats[f"{name}_p95_s"] = (
                float(np.percentile(arr, 95)) if arr.size else 0.0)
        if self.paged:
            self.stats["peak_kv_pages"] = self.pool.peak_in_use
            # pages live lanes pin at once (shared pages count ONCE):
            # the rightsized-pool requirement — cached-idle pages are
            # reclaimable on demand, so they are excluded here while
            # peak_kv_bytes (occupancy watermark) includes them
            self.stats["peak_kv_bytes_referenced"] = (
                self.pool.peak_referenced * self.page_bytes)
        self.stats["peak_kv_bytes"] = self.kv_bytes_peak
        self.stats["kv_bytes_contiguous_equiv"] = \
            self.kv_bytes_contiguous_equiv
        self.stats["admission_rejections"] = getattr(
            self.scheduler, "rejections", 0)
        self.stats["admission_rejected_steps"] = getattr(
            self.scheduler, "rejected_steps", 0)
        if getattr(self, "_offload", None) is not None:
            self.stats["offload_bytes_peak"] = max(
                self.stats["offload_bytes_peak"],
                self._offload.bytes_peak)
            # peak vs the configured byte budget (0 = unbounded): the
            # host-RAM headroom dashboards watch
            self.stats["offload_capacity_bytes"] = (
                self._offload.capacity_bytes or 0)
        if self.pcache is not None:
            self.stats["prefix_hit_rate"] = (
                self.stats["prefill_tokens_skipped"]
                / max(1, self.stats["prompt_tokens"]))
            self.stats["cached_pages"] = self.pool.cached_pages
        return self.stats


def generate(cfg, params, prompts, *, max_new_tokens: int = 32,
             max_len: int | None = None, eos_id: int | None = None,
             prefill_chunk: int = 16, slab_k: int = 8,
             max_batch: int | None = None, dist=None, paged: bool = True,
             page_size: int = 16, n_pages: int | None = None,
             attn_backend: str = "xla", prefix_cache: bool = False,
             mixed: bool = False,
             prefill_token_budget: int | None = None,
             tracer=None):
    """Batch-convenience wrapper: list of ragged 1-D prompts (or a 2-D
    equal-length array) -> (list of per-request token arrays, stats).

    Greedy; equal-length batches are bitwise-identical to
    ``serve_loop.generate`` for every slab size and for both cache
    layouts (tests/test_serving_engine.py, tests/test_paged_kv.py). A
    request that runs out of cache headroom returns fewer than
    ``max_new_tokens`` tokens — ``stats["truncated"]`` counts them (use
    ``Engine`` directly for per-request ``GenResult.truncated``)."""
    prompts = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
    maxp = max(p.size for p in prompts)
    max_len = max_len or (maxp + max_new_tokens)
    eng = Engine(cfg, params, max_batch=max_batch or len(prompts),
                 max_len=max_len, prefill_chunk=prefill_chunk,
                 slab_k=slab_k, eos_id=eos_id, dist=dist, paged=paged,
                 page_size=page_size, n_pages=n_pages,
                 attn_backend=attn_backend, prefix_cache=prefix_cache,
                 mixed=mixed, prefill_token_budget=prefill_token_budget,
                 tracer=tracer)
    uids = [eng.submit(p, max_new_tokens) for p in prompts]
    res = eng.run()
    return [res[u].tokens for u in uids], eng.stats
