"""Zero-downtime weight hot-swap with canary gating and automatic
rollback (DESIGN.md; ISSUE 10 tentpole).

State machine (one swap at a time, driven between engine steps — i.e.
at a slab boundary, the engine's only consistent host-sync point):

    IDLE -> STAGED  artifact validated (bytes + structure layers) and
                    the new params placed on device NEXT TO the serving
                    set — serving never pauses;
         -> CANARY  the sealed golden generations are replayed on the
                    staged weights through the real decode path
                    (artifact.canary_run); a gate failure raises
                    ``ArtifactCanaryError`` with a postmortem and the
                    swap never flips — zero corrupted tokens emitted;
         -> FLIPPED generation counter bumps: NEW admissions decode
                    under the new params, every in-flight lane keeps
                    decoding under its admission-time generation
                    (engine._decode_slab/_run_mixed split per
                    generation — old-gen streams stay bitwise-identical
                    to a no-swap run, zero requests dropped), and the
                    prefix cache is flushed (its pages hold old-gen KV);
         -> COMMITTED after ``monitor_steps`` engine steps with at most
                    ``quarantine_limit`` new-generation lane
                    quarantines; the old params are freed by the
                    engine's generation GC when their last lane
                    retires;
         -> ROLLED_BACK automatically if new-generation quarantines
                    exceed the limit inside the window: ANOTHER
                    generation bump that reuses the previous params
                    object, with a flight-recorder postmortem — lanes
                    admitted under the bad generation keep their
                    weights (their streams are already suspect and get
                    quarantined individually; re-pinning them would
                    corrupt their KV mid-stream).

Obs: ``swap.stage`` / ``swap.canary`` / ``swap.flip`` /
``swap.commit`` / ``swap.rollback`` spans+events on the engine tracer;
``weight_swaps`` / ``swap_canary_failures`` / ``swap_rollbacks`` /
``swap_canary_tokens`` / ``swap_quarantines`` counters and the
``weight_generation`` / ``weight_generations_held`` gauges in the
engine's metrics registry.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from repro.serving import artifact

IDLE = "IDLE"
STAGED = "STAGED"
CANARY = "CANARY"
FLIPPED = "FLIPPED"
COMMITTED = "COMMITTED"
ROLLED_BACK = "ROLLED_BACK"


@dataclasses.dataclass
class SwapReport:
    """Returned by ``swap_weights`` at flip time and MUTATED by the
    monitor when the window closes (COMMITTED) or a quarantine spike
    rolls the swap back — callers keep the reference."""
    state: str
    from_gen: int
    to_gen: int
    fingerprint: str
    canary: dict
    stage_s: float
    canary_s: float
    flip_s: float
    monitor_steps: int
    quarantines: int = 0
    rollback_reason: str | None = None
    rollback_gen: int | None = None


class _SwapMonitor:
    """Post-flip watchdog the engine ticks: ``note_quarantine`` from
    ``_fail_lane`` (only new-generation lane failures count — an old
    lane dying of an unrelated injected fault must not void a good
    swap), ``on_step_end`` from ``step()``. Commit on window end,
    rollback on a quarantine spike."""

    def __init__(self, report: SwapReport, gen: int, prev_params,
                 monitor_steps: int, quarantine_limit: int):
        self.report = report
        self.gen = gen
        self.prev_params = prev_params
        self.remaining = monitor_steps
        self.limit = quarantine_limit

    def note_quarantine(self, gen: int, engine) -> None:
        if gen != self.gen:
            return
        self.report.quarantines += 1
        engine.stats["swap_quarantines"] += 1
        if self.report.quarantines > self.limit:
            _rollback(engine, self, "quarantine_spike")

    def on_step_end(self, engine) -> None:
        self.remaining -= 1
        if self.remaining > 0:
            return
        engine._swap_monitor = None
        self.report.state = COMMITTED
        if engine.tracer.enabled:
            engine.tracer.event("swap.commit", gen=self.gen,
                                quarantines=self.report.quarantines)


def _flip_generation(engine, params) -> int:
    """The shared generation bump (flip AND rollback): new admissions
    route to ``params``, in-flight lanes keep their own generation, the
    prefix cache is flushed (its cached pages hold KV computed under
    another generation's weights — serving them to a new-generation
    admission would mix weights within one stream)."""
    g = engine._gen + 1
    engine._gen = g
    engine._gen_params[g] = params
    engine.params = params
    if engine.pcache is not None:
        engine.pcache.flush()
    engine.stats["weight_generation"] = g
    engine.stats["weight_generations_held"] = len(engine._gen_params)
    return g


def _rollback(engine, mon: _SwapMonitor, reason: str) -> None:
    t0 = time.monotonic()
    engine._swap_monitor = None
    g = _flip_generation(engine, mon.prev_params)
    engine.stats["swap_rollbacks"] += 1
    r = mon.report
    r.state = ROLLED_BACK
    r.rollback_reason = reason
    r.rollback_gen = g
    engine.tracer.span_at("swap.rollback", t0, time.monotonic(),
                          bad_gen=mon.gen, to_gen=g, reason=reason,
                          quarantines=r.quarantines)
    engine.tracer.postmortem(
        "swap.rollback", bad_gen=mon.gen, restored_gen=g, cause=reason,
        quarantines=r.quarantines, fingerprint=r.fingerprint)


def swap_weights(engine, artifact_dir: str, *, monitor_steps: int = 8,
                 quarantine_limit: int = 0, max_token_mismatches: int = 0,
                 max_logit_drift: float = 0.0, dist=None) -> SwapReport:
    """Stage a sealed artifact, canary it, and flip the engine onto it
    generationally. Returns the (live) ``SwapReport`` in state FLIPPED;
    the installed monitor later moves it to COMMITTED or ROLLED_BACK.
    Raises a typed ``ArtifactError`` — WITHOUT touching the serving
    weights — when the artifact fails any validation layer."""
    if engine._swap_monitor is not None:
        raise RuntimeError(
            "previous swap is still in its monitoring window")
    dist = engine.dist if dist is None else dist
    tr = engine.tracer

    # STAGED: bytes + structure layers, then device placement beside
    # the live weights (both generations resident until GC)
    t0 = time.monotonic()
    try:
        params, manifest = artifact.load(artifact_dir, engine.cfg)
    except artifact.ArtifactError as e:
        tr.postmortem("swap.validate_failure", artifact=artifact_dir,
                      error=type(e).__name__, detail=str(e))
        raise
    for leaf in jax.tree_util.tree_leaves(params):
        leaf.block_until_ready()
    t1 = time.monotonic()
    tr.span_at("swap.stage", t0, t1, artifact=artifact_dir,
               fingerprint=manifest["fingerprint"])

    # CANARY: behavioural layer, on the real decode path
    gold = artifact.golden_logits(artifact_dir, manifest)
    try:
        canary = artifact.verify_canaries(
            engine.cfg, params, manifest, gold,
            max_token_mismatches=max_token_mismatches,
            max_logit_drift=max_logit_drift, dist=dist)
    except artifact.ArtifactCanaryError as e:
        engine.stats["swap_canary_failures"] += 1
        tr.span_at("swap.canary", t1, time.monotonic(),
                   artifact=artifact_dir, passed=False)
        tr.postmortem("swap.canary_failure", artifact=artifact_dir,
                      fingerprint=manifest["fingerprint"],
                      detail=str(e))
        raise
    n_tok = sum(len(c["tokens"]) for c in manifest.get("canaries", []))
    engine.stats["swap_canary_tokens"] += n_tok
    t2 = time.monotonic()
    tr.span_at("swap.canary", t1, t2, artifact=artifact_dir,
               passed=True, tokens=n_tok)

    # FLIPPED: generational cutover at the slab boundary
    prev_gen, prev_params = engine._gen, engine.params
    g = _flip_generation(engine, params)
    engine.stats["weight_swaps"] += 1
    t3 = time.monotonic()
    tr.span_at("swap.flip", t2, t3, from_gen=prev_gen, to_gen=g,
               fingerprint=manifest["fingerprint"])
    report = SwapReport(
        state=FLIPPED, from_gen=prev_gen, to_gen=g,
        fingerprint=manifest["fingerprint"], canary=canary,
        stage_s=t1 - t0, canary_s=t2 - t1, flip_s=t3 - t2,
        monitor_steps=monitor_steps)
    engine._swap_monitor = _SwapMonitor(
        report, g, prev_params, monitor_steps, quarantine_limit)
    return report
