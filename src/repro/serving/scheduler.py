"""Admission scheduling for the continuous-batching engine.

The engine keeps ``max_batch`` batch lanes over a shared, time-indexed
KV cache: every active lane decodes at the same cache-slot *frontier*,
and a newly admitted request is prefilled *behind* the frontier — its
prompt right-aligned to end exactly at the frontier slot, with a
per-lane position offset making rope/masking see the true logical
positions (engine.py). That admission rule is what the scheduler
enforces:

  * fresh batch (no active lanes): any queued request whose prompt fits
    the cache may start; the frontier becomes the longest admitted
    prompt length;
  * running batch: a request joins only if its prompt fits behind the
    current frontier (``plen <= frontier``) and the frontier still has
    decode headroom (``frontier < max_len``).

FIFO order — a head-of-line request that cannot yet join simply waits
(it will be admitted at the next fresh batch at the latest).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request (prompt is a 1-D int32 array)."""
    uid: int
    prompt: np.ndarray
    max_new_tokens: int

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)


class FIFOScheduler:
    """FIFO admission with configurable ``max_batch`` / ``max_len``."""

    def __init__(self, max_batch: int, max_len: int):
        assert max_batch >= 1 and max_len >= 2
        self.max_batch = max_batch
        self.max_len = max_len
        self._queue: deque[Request] = deque()

    def __len__(self) -> int:
        return len(self._queue)

    def submit(self, req: Request):
        if req.prompt_len >= self.max_len:
            raise ValueError(
                f"prompt of {req.prompt_len} tokens cannot fit max_len="
                f"{self.max_len} with room to generate")
        self._queue.append(req)

    def admit(self, n_free: int, frontier: int) -> list[Request]:
        """Pop the FIFO prefix that may join now.

        ``n_free``: free lanes; ``frontier``: current shared decode slot
        (0 means the batch is fresh and the admitted group defines it).
        """
        out: list[Request] = []
        fresh = frontier == 0
        limit = self.max_len - 1 if fresh else frontier
        while self._queue and len(out) < n_free:
            head = self._queue[0]
            if head.prompt_len > limit:
                break
            if not fresh and frontier >= self.max_len:
                break
            out.append(self._queue.popleft())
        return out

    def extend(self, reqs: Iterable[Request]):
        for r in reqs:
            self.submit(r)
