"""Admission scheduling for the continuous-batching engine.

The engine keeps ``max_batch`` batch lanes over a shared KV cache with a
PER-LANE cache-slot *frontier*: each lane writes its own next slot, so a
lane freed by a finished sequence resets its frontier to 0 and can take
a new prompt immediately — no waiting for the whole batch to drain
(engine.py). Admission is therefore purely lane-based:

  * any free lane may take the head request (its prompt always fits a
    fresh lane — ``submit`` rejects prompts with no decode headroom);
  * requests admitted together are prefilled as one right-aligned group
    (chunked batched prefill); the group's padding becomes each lane's
    position ``offset``.

FIFO order — requests are popped strictly in submission order, up to the
number of free lanes.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Iterable

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request (prompt is a 1-D int32 array)."""
    uid: int
    prompt: np.ndarray
    max_new_tokens: int

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        self.queued_at = time.monotonic()   # for queued-time observability
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            # prefill always emits one token; a zero budget would also
            # under-pin pages in the paged engine (page cost is
            # width + budget - 1 slots, but prefill writes width slots)
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)


class FIFOScheduler:
    """FIFO admission with configurable ``max_batch`` / ``max_len``.

    ``prefill_token_budget`` caps the TOKENS one mixed engine step may
    spend (engine ``mixed=True``): running decode lanes spend one token
    each first, the remainder is split chunk-granularly across
    admitting lanes (``plan_chunks``) — so a long prompt is prefilled
    incrementally across steps instead of monopolizing one, and decode
    is never stalled by an arriving prompt. ``None`` leaves the budget
    to the engine's default (phased engines ignore it)."""

    def __init__(self, max_batch: int, max_len: int,
                 prefill_token_budget: int | None = None):
        assert max_batch >= 1 and max_len >= 2
        assert prefill_token_budget is None or prefill_token_budget >= 1
        self.max_batch = max_batch
        self.max_len = max_len
        self.prefill_token_budget = prefill_token_budget
        self._queue: deque[Request] = deque()
        self.reset_stats()

    def reset_stats(self):
        # page-gate admission rejections: times the FIFO head had a free
        # lane but the pool (free + reclaimable-cached) couldn't cover
        # the group's effective page cost (engine.reset_stats resets)
        self.rejections = 0

    def __len__(self) -> int:
        return len(self._queue)

    def submit(self, req: Request):
        if req.prompt_len >= self.max_len:
            raise ValueError(
                f"prompt of {req.prompt_len} tokens cannot fit max_len="
                f"{self.max_len} with room to generate")
        self._queue.append(req)

    def admit(self, n_free: int, free_pages: int | None = None,
              page_cost=None) -> list[Request]:
        """Pop the FIFO prefix that may start now: with per-lane
        frontiers every free lane starts at slot 0, so any queued
        request joins as soon as a lane is free.

        Paged engines gate admission on FREE PAGES too: ``page_cost``
        maps a tentative admission group -> total pages it would pin
        (the group is prefilled right-aligned, so adding a long prompt
        widens every member's pad region — the cost must be recomputed
        for the whole group, not summed per request; with the prefix
        cache the engine's cost is the EFFECTIVE one — pages already
        shared from the radix tree cost nothing, and ``free_pages`` is
        free + reclaimable-cached). The prefix stops at the first
        request whose inclusion would overdraw ``free_pages`` — strict
        FIFO, head-of-line blocking by design (the head is admitted as
        soon as enough pages free up). A page-gated stop with lanes
        still free counts as an admission rejection (``rejections``)."""
        out: list[Request] = []
        while self._queue and len(out) < n_free:
            if page_cost is not None:
                trial = out + [self._queue[0]]
                if page_cost(trial) > free_pages:
                    self.rejections += 1
                    break
            out.append(self._queue.popleft())
        return out

    def plan_chunks(self, tails: list[tuple[int, int]], n_decode: int,
                    chunk_cap: int) -> dict[int, int]:
        """Split one mixed step's prefill-token budget across admitting
        lanes. ``tails`` is [(lane, remaining_prompt_tokens), ...] in
        admission order; ``n_decode`` decode tokens are spent FIRST
        (decode never stalls for prefill — the whole point), and the
        remaining ``prefill_token_budget - n_decode`` tokens are handed
        out FIFO, at most ``chunk_cap`` per lane (the mixed step's
        query width). Returns {lane: chunk_len} — empty when decode
        already fills the budget (the prompt waits; the budget frees up
        as lanes finish). A ``None`` budget means chunk-cap-only."""
        left = (max(self.prefill_token_budget - n_decode, 0)
                if self.prefill_token_budget is not None
                else chunk_cap * len(tails))
        plan: dict[int, int] = {}
        for lane, rem in tails:
            c = min(rem, chunk_cap, left)
            if c <= 0:
                break
            plan[lane] = c
            left -= c
        return plan

    def push_front(self, reqs: list[Request]) -> None:
        """Return admitted-but-not-started requests to the queue HEAD in
        their original order (the engine un-admits when a re-checked
        prefix match no longer fits after a concurrent eviction)."""
        for r in reversed(reqs):
            self._queue.appendleft(r)

    def extend(self, reqs: Iterable[Request]):
        for r in reqs:
            self.submit(r)
