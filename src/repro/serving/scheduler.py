"""Admission scheduling for the continuous-batching engine.

The engine keeps ``max_batch`` batch lanes over a shared KV cache with a
PER-LANE cache-slot *frontier*: each lane writes its own next slot, so a
lane freed by a finished sequence resets its frontier to 0 and can take
a new prompt immediately — no waiting for the whole batch to drain
(engine.py). Admission is therefore purely lane-based:

  * any free lane may take the head request (its prompt always fits a
    fresh lane — ``submit`` rejects prompts with no decode headroom);
  * requests admitted together are prefilled as one right-aligned group
    (chunked batched prefill); the group's padding becomes each lane's
    position ``offset``.

Two schedulers share that contract:

  * ``FIFOScheduler`` — strict submission order, the parity baseline;
  * ``SLAScheduler``  — priority classes with deadline/arrival-aware
    ordering inside a class and an anti-starvation aging bound, for
    multi-tenant serving where interactive traffic must never sit
    behind batch jobs (and batch jobs must never starve).

Feasibility is checked ONCE, at ``submit``: the slot gate
(``prompt_len`` must leave decode headroom under ``max_len``) plus an
engine-installed ``feasibility`` hook (the paged engine's page-unit
check) — a request that could never run is rejected synchronously with
a consistent error instead of surfacing later from the queue.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Callable, Iterable

import numpy as np

from repro.obs.trace import NULL_TRACER


@dataclasses.dataclass
class Request:
    """One generation request (prompt is a 1-D int32 array).

    ``priority`` is the SLA class — smaller is more urgent (0 =
    interactive, higher integers = batch tiers); the FIFO scheduler
    ignores it. ``deadline_s`` is an optional target latency relative
    to submission: the SLA scheduler orders WITHIN a class by absolute
    deadline (earliest first; requests without one come after, in
    arrival order)."""
    uid: int
    prompt: np.ndarray
    max_new_tokens: int
    priority: int = 0
    deadline_s: float | None = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        # provisional stamp so a never-submitted Request still carries
        # a timestamp; ``submit`` RE-stamps at enqueue — queued-time
        # stats must measure queue residency, not object lifetime
        self.queued_at = time.monotonic()
        self.deadline_at: float | None = None
        self._seq = -1                     # arrival order, set at submit
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            # prefill always emits one token; a zero budget would also
            # under-pin pages in the paged engine (page cost is
            # width + budget - 1 slots, but prefill writes width slots)
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)


class FIFOScheduler:
    """FIFO admission with configurable ``max_batch`` / ``max_len``.

    ``prefill_token_budget`` caps the TOKENS one mixed engine step may
    spend (engine ``mixed=True``): running decode lanes spend one token
    each first, the remainder is split chunk-granularly across
    admitting lanes (``plan_chunks``) — so a long prompt is prefilled
    incrementally across steps instead of monopolizing one, and decode
    is never stalled by an arriving prompt. ``None`` leaves the budget
    to the engine's default (phased engines ignore it).

    ``clock`` injects the time source for queued-time stamping and
    (in ``SLAScheduler``) aging — tests pass a fake; production uses
    ``time.monotonic``."""

    def __init__(self, max_batch: int, max_len: int,
                 prefill_token_budget: int | None = None,
                 clock: Callable[[], float] = time.monotonic):
        assert max_batch >= 1 and max_len >= 2
        assert prefill_token_budget is None or prefill_token_budget >= 1
        self.max_batch = max_batch
        self.max_len = max_len
        self.prefill_token_budget = prefill_token_budget
        self.clock = clock
        # engine-installed extra submit-time gate (the paged engine's
        # page-unit check): callable(req) raising ValueError — so slot
        # and page infeasibility BOTH reject synchronously at submit
        self.feasibility: Callable[[Request], None] | None = None
        # engine-installed span tracer (obs/trace.py); the default is
        # the shared no-op
        self.tracer = NULL_TRACER
        self._queue: deque[Request] = deque()
        self._seq = 0
        # arrival seqs for crash-relaunched requests: deeply negative
        # but increasing, so they sort BEFORE every fresh arrival of
        # their class (they were already admitted once) while keeping
        # their relative order
        self._reinstate_seq = -(1 << 30)
        self.reset_stats()

    def reset_stats(self):
        # ``rejections``: DISTINCT page-gate blocked-head events — a
        # head request that waits across many engine steps counts once
        # until the head changes (uid-aware). ``rejected_steps``: every
        # step the gate blocked the head (the old per-call semantics —
        # a head waiting N steps adds N here and 1 to ``rejections``).
        # engine.reset_stats resets both.
        self.rejections = 0
        self.rejected_steps = 0
        self._blocked_uid: int | None = None

    def __len__(self) -> int:
        return len(self._queue)

    def head(self) -> Request | None:
        """The request that would be admitted next (None when empty)."""
        return self._queue[0] if self._queue else None

    def submit(self, req: Request):
        if req.prompt_len >= self.max_len:
            raise ValueError(
                f"prompt of {req.prompt_len} tokens cannot fit max_len="
                f"{self.max_len} with room to generate")
        if self.feasibility is not None:
            self.feasibility(req)
        req.queued_at = self.clock()       # stamp at ENQUEUE, not ctor
        req.deadline_at = (req.queued_at + req.deadline_s
                           if req.deadline_s is not None else None)
        req._seq = self._seq
        self._seq += 1
        self._queue.append(req)

    def admit(self, n_free: int, free_pages: int | None = None,
              page_cost=None) -> list[Request]:
        """Pop the FIFO prefix that may start now: with per-lane
        frontiers every free lane starts at slot 0, so any queued
        request joins as soon as a lane is free.

        Paged engines gate admission on FREE PAGES too: ``page_cost``
        maps a tentative admission group -> total pages it would pin
        (the group is prefilled right-aligned, so adding a long prompt
        widens every member's pad region — the cost must be recomputed
        for the whole group, not summed per request; with the prefix
        cache the engine's cost is the EFFECTIVE one — pages already
        shared from the radix tree cost nothing, and ``free_pages`` is
        free + reclaimable-cached). The prefix stops at the first
        request whose inclusion would overdraw ``free_pages`` — strict
        order, head-of-line blocking by design (the head is admitted as
        soon as enough pages free up). A page-gated stop with lanes
        still free counts once per DISTINCT blocked head
        (``rejections``) and once per blocked step
        (``rejected_steps``)."""
        out: list[Request] = []
        while self._queue and len(out) < n_free:
            if page_cost is not None:
                trial = out + [self._queue[0]]
                if page_cost(trial) > free_pages:
                    self.rejected_steps += 1
                    head = self._queue[0]
                    if head.uid != self._blocked_uid:
                        self.rejections += 1
                        self._blocked_uid = head.uid
                        if self.tracer.enabled:
                            # once per DISTINCT blocked head, like the
                            # counter — not once per blocked step
                            self.tracer.event(
                                "admit.blocked", uid=head.uid,
                                free_pages=free_pages,
                                need_pages=page_cost(trial))
                    break
            out.append(self._queue.popleft())
        return out

    def plan_chunks(self, tails: list[tuple[int, int]], n_decode: int,
                    chunk_cap: int) -> dict[int, int]:
        """Split one mixed step's prefill-token budget across admitting
        lanes. ``tails`` is [(lane, remaining_prompt_tokens), ...] in
        admission order; ``n_decode`` decode tokens are spent FIRST
        (decode never stalls for prefill — the whole point), and the
        remaining ``prefill_token_budget - n_decode`` tokens are handed
        out FIFO, at most ``chunk_cap`` per lane (the mixed step's
        query width). Returns {lane: chunk_len} — empty when decode
        already fills the budget (the prompt waits; the budget frees up
        as lanes finish). A ``None`` budget means chunk-cap-only."""
        left = (max(self.prefill_token_budget - n_decode, 0)
                if self.prefill_token_budget is not None
                else chunk_cap * len(tails))
        plan: dict[int, int] = {}
        for lane, rem in tails:
            c = min(rem, chunk_cap, left)
            if c <= 0:
                break
            plan[lane] = c
            left -= c
        return plan

    def push_front(self, reqs: list[Request]) -> None:
        """Return admitted-but-not-started requests to the queue HEAD in
        their original order (the engine un-admits when a re-checked
        prefix match no longer fits after a concurrent eviction)."""
        for r in reversed(reqs):
            self._queue.appendleft(r)

    def remove(self, uid: int) -> Request | None:
        """Pull a still-queued request out (cancellation); None when
        the uid is not queued (already admitted, or unknown)."""
        for r in self._queue:
            if r.uid == uid:
                self._queue.remove(r)
                return r
        return None

    def reinstate(self, reqs: list[Request]) -> None:
        """Re-queue crash-relaunched requests AT THE HEAD, in order,
        bypassing the submit-time feasibility gates: each was feasible
        when first admitted and a relaunch prompt (original prompt +
        emitted tokens) never exceeds the extent already proven to fit.
        Stamps are fresh (``queued_at`` = now — the pre-crash timeline
        died with the engine thread); the caller preserves absolute
        deadlines across the relaunch when it wants them enforced."""
        now = self.clock()
        for r in reqs:
            r.queued_at = now
            if r.deadline_at is None and r.deadline_s is not None:
                r.deadline_at = now + r.deadline_s
            if r._seq < 0:
                r._seq = self._reinstate_seq
                self._reinstate_seq += 1
        for r in reversed(reqs):
            self._queue.appendleft(r)

    def extend(self, reqs: Iterable[Request]):
        for r in reqs:
            self.submit(r)


# conventional SLA classes — any int works; smaller is more urgent
INTERACTIVE = 0
BATCH = 1


class SLAScheduler(FIFOScheduler):
    """Priority-class admission with deadline ordering and aging.

    Ordering at every admission attempt (stable over arrival order):

      1. **effective class** — ``req.priority`` minus one for every
         full ``aging_s`` the request has waited. Promotion is
         unbounded, so a waiting request eventually outranks EVERY
         fresh arrival of every class: the anti-starvation bound — a
         class-``p`` request is never left unadmitted once it has aged
         ``(p + 1) * aging_s`` past the freshest competitor (the
         no-starvation property test pins this down);
      2. **deadline** within a class — earliest absolute deadline
         first (EDF); requests without a deadline come after, so plain
         workloads keep strict arrival order;
      3. **arrival** — submission order breaks every remaining tie
         (strict order within a class).

    The page-gate semantics are inherited unchanged: ``admit`` pops the
    prefix of the ORDERED queue and stops head-of-line at the first
    request the pool cannot cover — so a page-blocked interactive head
    still blocks the batch tier behind it (by design: the head is
    admitted as soon as pages free up; the engine may preempt a
    lower-priority lane to make that happen).

    ``aging_s=None`` disables aging (pure class order — starvable under
    sustained higher-priority pressure; keep the default for
    production)."""

    def __init__(self, max_batch: int, max_len: int,
                 prefill_token_budget: int | None = None,
                 aging_s: float | None = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        assert aging_s is None or aging_s > 0.0
        super().__init__(max_batch, max_len,
                         prefill_token_budget=prefill_token_budget,
                         clock=clock)
        self.aging_s = aging_s

    def effective_priority(self, req: Request,
                           now: float | None = None) -> int:
        """Class after aging: drops one level per full ``aging_s``
        waited, unboundedly (see class docstring)."""
        if self.aging_s is None:
            return req.priority
        now = self.clock() if now is None else now
        waited = max(0.0, now - req.queued_at)
        return req.priority - int(waited // self.aging_s)

    def _order(self) -> None:
        now = self.clock()

        def key(r: Request):
            dl = r.deadline_at if r.deadline_at is not None else math.inf
            return (self.effective_priority(r, now), dl, r._seq)

        ordered = sorted(self._queue, key=key)
        self._queue.clear()
        self._queue.extend(ordered)

    def head(self) -> Request | None:
        self._order()
        return super().head()

    def admit(self, n_free: int, free_pages: int | None = None,
              page_cost=None) -> list[Request]:
        self._order()
        return super().admit(n_free, free_pages, page_cost)
