"""Deterministic fault injection + the serving stack's failure taxonomy.

BLaST's cost story (2.9x cheaper inference) only survives production if
a fault costs one request, not the whole batch: a single non-finite
logit, a hung device step, or an exception on the engine thread must not
kill every in-flight request and drop all KV state into re-prefill.
This module is the TEST SUBSTRATE for that property — a seeded
``FaultPlan`` the engine consults at fixed points so chaos tests are
bitwise-reproducible — plus the structured error types every failure
path raises (one vocabulary across engine, frontend, and tests).

Fault points (all keyed by the ENGINE STEP index — one ``Engine.step``
call; the host syncs at most once per step, so that is the finest
deterministic granularity):

  * ``poison_logits(step, lane)``  — corrupt one lane's logits to
    NaN/Inf at the first in-slab decode step of engine step ``step``
    (the per-lane finite check in serving/step.py must quarantine ONLY
    that lane);
  * ``fail_alloc(step)``           — the next page allocation raises
    (an engine-thread crash the watchdog recovers from);
  * ``crash(step)``                — raise from the step: host-side
    crash (``device_lost=False``, device arrays intact — recovery may
    salvage live KV to the host) or simulated device loss
    (``device_lost=True`` — all on-device KV is gone);
  * ``stall(step, seconds)``       — the jitted step hangs; the
    watchdog's heartbeat deadline must notice and tear the thread down
    (the stall aborts with ``EngineHangError`` once the supervisor
    condemns the engine — the in-process stand-in for killing a wedged
    device call);
  * ``corrupt_offload(nth_save)``  — bit-flip one page of the nth
    record saved to the host offload store AFTER its checksums were
    computed; the restore-side verify must fail only that request.

Every fault that actually fires increments the engine's
``faults_injected`` counter.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


# --------------------------------------------------------------- errors
class ServingFault(Exception):
    """Base class for every structured serving failure."""


class LaneFaultError(ServingFault):
    """One lane's computation produced non-finite logits (or its
    restored KV failed verification): ONLY this request fails — its
    lane is quarantined, its pages freed, and its sequence is never
    donated to the prefix cache."""

    def __init__(self, uid: int, lane: int, reason: str = "non-finite "
                 "logits"):
        self.uid, self.lane, self.reason = uid, lane, reason
        super().__init__(f"request {uid} quarantined on lane {lane}: "
                         f"{reason}")


class EngineCrashError(ServingFault):
    """The engine stepper thread died; ``device_lost`` says whether
    on-device KV survived (host-side crash) or not (device loss)."""

    def __init__(self, msg: str = "engine step crashed",
                 device_lost: bool = False):
        self.device_lost = device_lost
        super().__init__(msg)


class EngineHangError(EngineCrashError):
    """A step overran the watchdog's hung-step deadline and the
    supervisor condemned the engine (device state is not trusted to be
    mid-write consistent, but host arrays survive)."""

    def __init__(self, msg: str = "engine step exceeded the watchdog "
                 "deadline"):
        super().__init__(msg, device_lost=False)


class OffloadCorruptionError(ServingFault):
    """A host-offloaded KV page failed its checksum on restore."""

    def __init__(self, uid: int, logical: list[int]):
        self.uid, self.logical = uid, list(logical)
        super().__init__(
            f"offloaded KV for request {uid} failed checksum on "
            f"logical page(s) {self.logical}")


class OffloadCapacityError(ServingFault):
    """The host offload store's byte budget cannot hold another
    record; the preemption (or crash salvage) that needed it must fall
    back — never silently overrun host RAM."""

    def __init__(self, needed: int, capacity: int, used: int):
        self.needed, self.capacity, self.used = needed, capacity, used
        super().__init__(
            f"host KV store over capacity: record of {needed} bytes "
            f"does not fit ({used} of {capacity} bytes used)")


class BackpressureError(ServingFault):
    """Load shedding: the admission queue is at its bound; retry after
    ``retry_after_s`` (a service-rate estimate, not a promise)."""

    def __init__(self, queue_depth: int, limit: int,
                 retry_after_s: float):
        self.queue_depth, self.limit = queue_depth, limit
        self.retry_after_s = retry_after_s
        super().__init__(
            f"admission queue full ({queue_depth} >= {limit}); "
            f"retry after {retry_after_s:.2f}s")


class RequestCancelledError(ServingFault):
    """The request was cancelled (client cancel or engine shutdown)."""

    def __init__(self, uid: int, reason: str = "cancelled"):
        self.uid, self.reason = uid, reason
        super().__init__(f"request {uid} {reason}")


class DeadlineExceededError(RequestCancelledError):
    """The request's SLA deadline passed while it was still decoding;
    the engine cancelled it at the next host sync."""

    def __init__(self, uid: int):
        super().__init__(uid, "cancelled: SLA deadline exceeded "
                              "mid-decode")


# ------------------------------------------------------------ the plan
@dataclasses.dataclass
class _Poison:
    step: int
    lane: int
    kind: str          # "nan" | "inf"


@dataclasses.dataclass
class _Crash:
    step: int
    device_lost: bool


@dataclasses.dataclass
class _Stall:
    step: int
    seconds: float


class FaultPlan:
    """A seeded, replayable schedule of injected faults.

    Build one, arm faults at chosen engine-step indices, and hand it to
    ``Engine(faults=plan)`` (or ``engine.install_faults(plan)``). The
    plan is consumed as it fires — rerunning the same plan instance
    against a second engine requires a fresh plan (build two from the
    same seed for A/B runs). ``seed`` feeds ``rng`` for tests that want
    randomized-but-reproducible targets (e.g. picking a victim lane);
    the plan itself never draws from it implicitly."""

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self._poisons: list[_Poison] = []
        self._crashes: list[_Crash] = []
        self._stalls: list[_Stall] = []
        self._alloc_steps: set[int] = set()
        self._alloc_armed = False
        self._corrupt_saves: dict[int, int] = {}   # nth save -> bit
        self._n_saves = 0
        self._engine = None            # set by Engine.install_faults
        self.fired: list[str] = []     # audit trail, in firing order

    # ----------------------------------------------------------- arming
    def poison_logits(self, step: int, lane: int,
                      kind: str = "nan") -> "FaultPlan":
        assert kind in ("nan", "inf")
        self._poisons.append(_Poison(step, lane, kind))
        return self

    def fail_alloc(self, step: int) -> "FaultPlan":
        self._alloc_steps.add(step)
        return self

    def crash(self, step: int, device_lost: bool = False) -> "FaultPlan":
        self._crashes.append(_Crash(step, device_lost))
        return self

    def stall(self, step: int, seconds: float) -> "FaultPlan":
        self._stalls.append(_Stall(step, seconds))
        return self

    def corrupt_offload(self, nth_save: int = 0,
                        bit: int = 0) -> "FaultPlan":
        self._corrupt_saves[nth_save] = bit
        return self

    # ------------------------------------------------------ engine hooks
    def on_step(self, idx: int, engine) -> None:
        """Called at the top of ``Engine.step`` (before any mutation):
        arms this step's logit poison into the device-state mirror,
        arms a one-shot page-allocation failure, and raises host-side
        crashes. Device-loss crashes and stalls fire later, at the
        jitted-step call site (``on_device_step``)."""
        for p in [p for p in self._poisons if p.step == idx]:
            self._poisons.remove(p)
            val = np.nan if p.kind == "nan" else np.inf
            engine._mirror["poison"][p.lane] = val
            engine._dirty = True
            engine.stats["faults_injected"] += 1
            self.fired.append(f"poison:{p.kind}@{idx}:lane{p.lane}")
        if idx in self._alloc_steps:
            self._alloc_steps.discard(idx)
            self._alloc_armed = True
            engine.stats["faults_injected"] += 1
            self.fired.append(f"alloc_fail@{idx}")
        for c in [c for c in self._crashes if c.step == idx
                  and not c.device_lost]:
            self._crashes.remove(c)
            engine.stats["faults_injected"] += 1
            self.fired.append(f"crash:host@{idx}")
            raise EngineCrashError(
                f"injected host-side crash at step {idx}",
                device_lost=False)

    def on_device_step(self, idx: int, engine) -> None:
        """Called immediately before a jitted slab/mixed call: simulated
        device loss raises here; a stall sleeps past the watchdog
        deadline, aborting with ``EngineHangError`` the moment the
        supervisor condemns the engine (``engine._condemned``)."""
        for c in [c for c in self._crashes if c.step == idx
                  and c.device_lost]:
            self._crashes.remove(c)
            engine.stats["faults_injected"] += 1
            self.fired.append(f"crash:device@{idx}")
            raise EngineCrashError(
                f"injected device loss at step {idx}", device_lost=True)
        for s in [s for s in self._stalls if s.step == idx]:
            self._stalls.remove(s)
            engine.stats["faults_injected"] += 1
            self.fired.append(f"stall@{idx}:{s.seconds}s")
            deadline = time.monotonic() + s.seconds
            while time.monotonic() < deadline:
                if engine._condemned.is_set():
                    raise EngineHangError()
                time.sleep(min(0.01, s.seconds))

    def on_alloc(self, n: int) -> bool:
        """Page-pool hook (pages.py): True -> this allocation fails."""
        if self._alloc_armed:
            self._alloc_armed = False
            return True
        return False

    def on_artifact(self, d: str, kind: str) -> type:
        """Corrupt the sealed artifact at ``d`` with injector ``kind``
        (see ``ARTIFACT_FAULTS``) and audit it. Returns the typed
        ``ArtifactError`` subclass the corruption must raise at
        validate/canary time."""
        expected = ARTIFACT_FAULTS[kind](d)
        self.fired.append(f"artifact:{kind}")
        return expected

    def on_offload_save(self, rec) -> None:
        """Host-store hook (offload.py), called AFTER checksums were
        computed: bit-flip the first element of the record's first
        page so the restore-side verify must catch it."""
        nth = self._n_saves
        self._n_saves += 1
        if nth not in self._corrupt_saves:
            return
        bit = self._corrupt_saves.pop(nth)
        k = np.array(rec.k, copy=True)          # device downloads are
        flat = k.reshape(-1).view(np.uint8)     # often read-only views
        flat[0] ^= np.uint8(1 << (bit % 8))
        rec.k = k
        if self._engine is not None:
            self._engine.stats["faults_injected"] += 1
        self.fired.append(f"bitflip:save{nth}")


# ----------------------------------------------- artifact corruption
# One injector per corruption class of the sealed-artifact layer
# (serving/artifact.py). Each takes an artifact DIRECTORY, mutates it
# in place, and returns the typed ArtifactError subclass that
# validate()/load(run_canaries=True) must raise — tests sweep the whole
# dict and assert 100% detection before any engine step. The *_signed
# kinds RECOMPUTE the checksum manifest after corrupting (a toolchain
# bug or attacker that re-signs), proving the structural and canary
# layers catch what the byte layer cannot.

def _art_load(d):
    import json
    import os
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        stored = {k: np.array(z[k]) for k in z.files}
    return manifest, stored


def _art_write(d, manifest, stored, resign=False):
    import json
    import os
    if resign:
        from repro.checkpointing.checkpoint import crc32_array
        manifest["checksums"] = {k: crc32_array(v)
                                 for k, v in stored.items()}
    np.savez(os.path.join(d, "arrays.npz"), **stored)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def _art_mutate(stored, manifest, key, fn):
    """Apply ``fn`` (float32 ndarray -> ndarray) to a stored array
    through its TRUE dtype (bf16 leaves live in the npz as uint16
    views)."""
    import jax.numpy as jnp
    arr = stored[key]
    true_dt = manifest["dtypes"][key]
    if str(arr.dtype) != true_dt:        # bf16-as-uint16 view
        v = fn(np.asarray(arr.view(jnp.bfloat16), np.float32))
        stored[key] = np.asarray(v, jnp.bfloat16).view(np.uint16)
    else:
        stored[key] = np.asarray(fn(arr), arr.dtype)


def _first_packed(manifest):
    return sorted(manifest["packed"])[0]


def _bitflip(d, suffix):
    from repro.serving import artifact as art
    manifest, stored = _art_load(d)
    key = f"{_first_packed(manifest)}/{suffix}"
    a = stored[key]
    flat = a.reshape(-1).view(np.uint8)
    flat[len(flat) // 2] ^= np.uint8(1)
    _art_write(d, manifest, stored)
    return art.ArtifactChecksumError


def _fault_idx_bitflip(d):
    return _bitflip(d, "idx")


def _fault_block_bitflip(d):
    return _bitflip(d, "blocks")


def _fault_leaf_truncate(d):
    from repro.serving import artifact as art
    manifest, stored = _art_load(d)
    del stored[f"{_first_packed(manifest)}/blocks"]
    _art_write(d, manifest, stored)
    return art.ArtifactChecksumError


def _fault_config_mismatch(d):
    from repro.serving import artifact as art
    manifest, stored = _art_load(d)
    manifest["fingerprint"] = "0" * 64
    _art_write(d, manifest, stored)
    return art.ArtifactConfigError


def _fault_idx_oob_signed(d):
    from repro.serving import artifact as art
    manifest, stored = _art_load(d)
    path = _first_packed(manifest)
    kb = int(manifest["packed"][path]["kb"])
    idx = stored[f"{path}/idx"]
    idx.reshape(-1)[0] = kb + 7         # gathers past the block-rows
    _art_write(d, manifest, stored, resign=True)
    return art.ArtifactStructureError


def _fault_idx_dup_signed(d):
    from repro.serving import artifact as art
    manifest, stored = _art_load(d)
    path = _first_packed(manifest)
    idx = stored[f"{path}/idx"]
    nnz = idx.shape[-1]
    assert nnz >= 2, "dup fault needs nnz >= 2"
    flat = idx.reshape(-1, nnz)
    flat[0, 1] = flat[0, 0]             # same block-row twice in col 0
    # both duplicate slots must carry data for the double-count hazard
    _art_mutate(stored, manifest, f"{path}/blocks",
                lambda b: np.where(b == 0, np.float32(0.25), b))
    _art_write(d, manifest, stored, resign=True)
    return art.ArtifactStructureError


def _fault_nan_block_signed(d):
    from repro.serving import artifact as art
    manifest, stored = _art_load(d)
    path = _first_packed(manifest)

    def poison(b):
        f = b.reshape(-1)
        f[0] = np.nan
        return b
    _art_mutate(stored, manifest, f"{path}/blocks", poison)
    _art_write(d, manifest, stored, resign=True)
    return art.ArtifactNonFiniteError


def _fault_joint_break_signed(d):
    from repro.serving import artifact as art
    manifest, stored = _art_load(d)
    # claim a joint promise on a gate leaf whose idx table we then skew
    # away from its up partner — the fused-GLU fast path would contract
    # the wrong blocks
    gates = [p for p in manifest["packed"]
             if p.split("/")[-1] in ("w_gate", "ws_gate")]
    assert gates, "joint fault needs a gate leaf"
    path = gates[0]
    up = path.replace("gate", "up")
    manifest["packed"][path]["joint"] = True
    idx = stored[f"{path}/idx"]
    uidx = stored.get(f"{up}/idx")
    if uidx is not None and np.array_equal(idx, uidx):
        kb = int(manifest["packed"][path]["kb"])
        idx.reshape(-1)[0] = (int(idx.reshape(-1)[0]) + 1) % kb
    _art_write(d, manifest, stored, resign=True)
    return art.ArtifactStructureError


def _fault_canary_weights_signed(d):
    from repro.serving import artifact as art
    manifest, stored = _art_load(d)
    path = _first_packed(manifest)
    # structurally sound, finite, correctly signed — only the golden
    # generations can tell these weights are not the sealed ones
    _art_mutate(stored, manifest, f"{path}/blocks", lambda b: b * 1.5)
    _art_write(d, manifest, stored, resign=True)
    return art.ArtifactCanaryError


def _fault_canary_tamper(d):
    from repro.serving import artifact as art
    manifest, stored = _art_load(d)
    manifest["canaries"][0]["tokens"][0] += 1
    _art_write(d, manifest, stored)
    return art.ArtifactChecksumError


ARTIFACT_FAULTS = {
    "idx_bitflip": _fault_idx_bitflip,
    "block_bitflip": _fault_block_bitflip,
    "leaf_truncate": _fault_leaf_truncate,
    "config_mismatch": _fault_config_mismatch,
    "idx_oob_signed": _fault_idx_oob_signed,
    "idx_dup_signed": _fault_idx_dup_signed,
    "nan_block_signed": _fault_nan_block_signed,
    "joint_break_signed": _fault_joint_break_signed,
    "canary_weights_signed": _fault_canary_weights_signed,
    "canary_tamper": _fault_canary_tamper,
}
