"""Sealed serving artifacts: the validated train->serve weight boundary.

BLaST's prune-grow schedule emits a sequence of ever-sparser packed
snapshots (core/prune_grow.py -> export.pack_params); the paper's
deployment story (§5.2, Fig. 7) assumes they reach serving INTACT. A
``PackedBCSC`` is exactly where silent corruption is cheapest to catch
statically — an out-of-range ``idx`` entry gathers garbage blocks and
serves wrong tokens with no crash — so an artifact is sealed with three
nested layers of evidence, verified in order on load:

  1. **bytes**   — per-array crc32 manifest (the same primitive as
     checkpoint restore, ``checkpointing.crc32_array``) plus an exact
     array-set match: bit rot, torn writes and dropped leaves fail here;
  2. **structure** — config fingerprint, and for every packed leaf the
     static invariants (``core/packing.structure_violations``): idx
     dtype/range, block dims vs the registry config, dense extent,
     the duplicate-idx zero rule, finiteness of every float leaf, and
     the ``joint`` gate/up promise (identical idx tables). A RE-SIGNED
     corruption (attacker/toolchain bug recomputes the checksums) still
     fails here;
  3. **behaviour** — golden canary generations: at seal time a handful
     of prompts run greedy decode through ``canary_run`` and the tokens
     + final-step logits are stored. ``verify_canaries`` re-runs the
     SAME function on the loaded weights — an intact artifact reproduces
     the goldens BITWISE (same jitted decode path), so the default gates
     are zero token mismatches and 0.0 logit drift. A corruption that
     preserves structure (a scaled block, re-signed) fails only here —
     which is why the hot-swap (serving/hotswap.py) runs canaries
     against the live engine config before flipping generations.

Every failure raises a typed ``ArtifactError`` BEFORE a single token is
served; serving/faults.py seeds one injector per corruption class and
tests/test_artifact.py proves each is caught at its intended layer.

Layout on disk (atomic: written to ``<dir>.tmp`` then renamed in)::

    <dir>/arrays.npz     params (packed leaves split into <path>/blocks
                         + <path>/idx; bf16 stored as uint16 views) and
                         canary goldens (__canary__/<i>/{tokens,logits})
    <dir>/manifest.json  format, config fingerprint, checksums, packed
                         leaf metadata {kb, joint}, dtypes, pad
                         fractions, canary prompts + a JSON copy of the
                         golden tokens (cross-checked against the npz
                         copy, so editing either one is caught)
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing.checkpoint import crc32_array, flatten_tree
from repro.core import packing, sparse_mlp as sm
from repro.core.packing import PackedBCSC
from repro.models import registry
from repro.serving.faults import ServingFault

FORMAT = "blast-artifact-v1"


# ------------------------------------------------------------- errors
class ArtifactError(ServingFault):
    """Base: a sealed artifact failed verification (or could not be
    read). Raised before any engine step consumes the weights."""


class ArtifactIOError(ArtifactError):
    """Missing/unreadable/unparseable artifact files."""


class ArtifactChecksumError(ArtifactError):
    """Byte-integrity layer: crc32 mismatch, array set drift, or the
    manifest's canary-token copy diverging from the npz copy."""


class ArtifactConfigError(ArtifactError):
    """The artifact was sealed for a different model config."""


class ArtifactStructureError(ArtifactError):
    """A packed leaf violates a static structural invariant (idx range,
    block dims, dense extent, duplicate rule, joint promise)."""


class ArtifactNonFiniteError(ArtifactError):
    """A float leaf contains NaN/Inf."""


class ArtifactCanaryError(ArtifactError):
    """The loaded weights no longer reproduce the golden canary
    generations within the gates (token mismatches / logit drift)."""


# -------------------------------------------------------- fingerprint
def fingerprint(cfg) -> str:
    """Stable digest of the model config an artifact was sealed for."""
    blob = json.dumps(dataclasses.asdict(cfg), sort_keys=True,
                      default=str)
    return hashlib.sha256(blob.encode()).hexdigest()


# ---------------------------------------------------- flatten helpers
def _flatten_params(params):
    """Params tree -> (flat host arrays, packed-leaf metadata). Builds
    on ``checkpointing.flatten_tree`` (which treats a PackedBCSC as an
    opaque leaf) by splitting each packed leaf into ``<path>/blocks`` +
    ``<path>/idx`` and recording its static metadata."""
    arrays, packed = {}, {}
    for k, v in flatten_tree(params).items():
        if isinstance(v, PackedBCSC):
            arrays[f"{k}/blocks"] = np.asarray(jax.device_get(v.blocks))
            arrays[f"{k}/idx"] = np.asarray(jax.device_get(v.idx))
            packed[k] = {"kb": int(v.kb), "joint": bool(v.joint)}
        else:
            arrays[k] = np.asarray(jax.device_get(v))
    return arrays, packed


def _unflatten_params(arrays: dict, packed: dict):
    """Rebuild the nested params dict from flat arrays + packed meta
    (registry params trees are pure nested dicts)."""
    leaves: dict = {}
    for path, meta in packed.items():
        leaves[path] = PackedBCSC(
            blocks=jnp.asarray(arrays[f"{path}/blocks"]),
            idx=jnp.asarray(arrays[f"{path}/idx"]),
            kb=int(meta["kb"]), joint=bool(meta["joint"]))
    for k, v in arrays.items():
        if k.startswith("__canary__/"):
            continue
        base, leaf = k.rsplit("/", 1) if "/" in k else ("", k)
        if leaf in ("blocks", "idx") and base in packed:
            continue
        leaves[k] = jnp.asarray(v)
    tree: dict = {}
    for path, v in leaves.items():
        node = tree
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def _store(arr: np.ndarray):
    """npz-safe encoding: ml_dtypes (bfloat16 etc.) stored as uint16
    views with the true dtype recorded for restore."""
    if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
        return arr.view(np.uint16), str(arr.dtype)
    return arr, str(arr.dtype)


def _restore(arr: np.ndarray, dtype: str):
    if str(arr.dtype) == dtype:
        return arr
    if dtype == "bfloat16":
        return arr.view(jnp.bfloat16)
    return arr.view(np.dtype(dtype))


# -------------------------------------------------------------- canary
def default_canary_prompts(cfg, n_prompts: int = 2,
                           prompt_len: int = 8) -> list[list[int]]:
    """Deterministic pseudo-prompts spread over the vocab (no RNG: the
    same cfg always yields the same canary set)."""
    v = cfg.vocab_size
    return [[(7 * (i + 1) * (j + 3) + 11 * i + 5) % v
             for j in range(prompt_len)] for i in range(n_prompts)]


def canary_run(cfg, params, prompt, n_tokens: int, dist=None):
    """THE canonical canary generation: greedy token-by-token decode of
    one prompt through the repo's oracle serving path (serve_loop's
    prefill + ``make_decode_step``). Called at seal time to produce the
    goldens and again at load/swap time on the candidate weights — the
    same function on intact weights is bitwise-reproducible, so the
    default acceptance gates are exact (0 mismatches, 0.0 drift). The
    engine's slab/mixed paths are bitwise-equal to this path (the
    parity suite), so golden tokens also predict served tokens.

    Returns (tokens (n_tokens,) int32, last-step logits (V,) f32)."""
    from repro.serving.serve_loop import prefill_with_decode
    from repro.serving.step import make_decode_step
    prompts = jnp.asarray(np.asarray(prompt, np.int32)[None, :])
    plen = prompts.shape[1]
    last, cache = prefill_with_decode(cfg, params, prompts,
                                      plen + n_tokens, dist)
    decode = jax.jit(make_decode_step(cfg, dist=dist))
    nxt = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    toks = [int(nxt[0, 0])]
    rng = jax.random.PRNGKey(0)
    for i in range(n_tokens - 1):
        nxt, cache, last, rng = decode(params, cache, nxt,
                                       jnp.int32(plen + i), rng)
        toks.append(int(nxt[0, 0]))
    return (np.asarray(toks, np.int32),
            np.asarray(jax.device_get(last), np.float32)[0])


def verify_canaries(cfg, params, manifest: dict, golden_logits: dict,
                    *, max_token_mismatches: int = 0,
                    max_logit_drift: float = 0.0, dist=None) -> dict:
    """Re-run every canary on ``params`` and gate against the goldens.

    ``golden_logits`` maps canary index -> stored (V,) f32 final-step
    logits (from the artifact npz). Returns a report dict; raises
    ``ArtifactCanaryError`` when any canary exceeds the gates. The
    defaults are EXACT gates — see ``canary_run``."""
    report = {"canaries": [], "token_mismatches": 0, "logit_drift": 0.0}
    for i, c in enumerate(manifest["canaries"]):
        toks, logits = canary_run(cfg, params, c["prompt"],
                                  len(c["tokens"]), dist=dist)
        mism = int(np.sum(toks != np.asarray(c["tokens"], np.int32)))
        drift = float(np.max(np.abs(logits - golden_logits[i]))) \
            if i in golden_logits else 0.0
        report["canaries"].append(
            {"i": i, "token_mismatches": mism, "logit_drift": drift,
             "tokens": toks.tolist()})
        report["token_mismatches"] += mism
        report["logit_drift"] = max(report["logit_drift"], drift)
    if (report["token_mismatches"] > max_token_mismatches
            or report["logit_drift"] > max_logit_drift):
        raise ArtifactCanaryError(
            f"canary gate failed: {report['token_mismatches']} token "
            f"mismatch(es) (gate {max_token_mismatches}), max logit "
            f"drift {report['logit_drift']:.3e} (gate "
            f"{max_logit_drift:.3e}) — weights do not reproduce the "
            "sealed goldens")
    return report


# ---------------------------------------------------------------- seal
def seal(cfg, params, out_dir: str, *, canary_prompts=None,
         canary_tokens: int = 8, pad: dict | None = None,
         dist=None) -> dict:
    """Seal packed serving params (``export.pack_params`` output) into
    a validated artifact directory. Computes the config fingerprint,
    per-array crc32s, and the golden canary generations on the EXACT
    weights being sealed. ``pad`` is export's per-path pad-fraction
    report (unbalanced masks), recorded for the memory accounting.
    Returns the manifest. Atomic: assembled in ``<dir>.tmp`` and
    renamed into place."""
    arrays, packed = _flatten_params(params)
    if canary_prompts is None:
        canary_prompts = default_canary_prompts(cfg)
    canaries = []
    for i, prompt in enumerate(canary_prompts):
        toks, logits = canary_run(cfg, params, prompt, canary_tokens,
                                  dist=dist)
        arrays[f"__canary__/{i}/tokens"] = toks
        arrays[f"__canary__/{i}/logits"] = logits
        canaries.append({"prompt": [int(t) for t in prompt],
                         "tokens": toks.tolist()})
    stored, dtypes = {}, {}
    for k, v in arrays.items():
        stored[k], dtypes[k] = _store(v)
    manifest = {
        "format": FORMAT,
        "fingerprint": fingerprint(cfg),
        "checksums": {k: crc32_array(v) for k, v in stored.items()},
        "packed": packed,
        "dtypes": dtypes,
        "pad": {k: float(v) for k, v in (pad or {}).items()},
        "canaries": canaries,
    }
    tmp, final = out_dir + ".tmp", out_dir
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "arrays.npz"), **stored)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return manifest


# ------------------------------------------------------------ validate
def _read(d: str):
    mpath = os.path.join(d, "manifest.json")
    apath = os.path.join(d, "arrays.npz")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except FileNotFoundError:
        raise ArtifactIOError(f"no manifest.json in {d}") from None
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ArtifactIOError(f"manifest.json unreadable: {e}") from None
    if manifest.get("format") != FORMAT:
        raise ArtifactIOError(
            f"unknown artifact format {manifest.get('format')!r} "
            f"(expected {FORMAT!r})")
    try:
        with np.load(apath) as z:
            stored = {k: z[k] for k in z.files}
    except FileNotFoundError:
        raise ArtifactIOError(f"no arrays.npz in {d}") from None
    except Exception as e:
        raise ArtifactIOError(f"arrays.npz unreadable: {e}") from None
    return manifest, stored


def validate(d: str, cfg=None) -> dict:
    """Verify an artifact directory layer by layer (bytes, then
    structure/behavioural metadata) WITHOUT instantiating engine state.
    With ``cfg``, also checks the config fingerprint and the packed
    leaves' shapes against the registry. Raises a typed
    ``ArtifactError``; returns the manifest on success."""
    manifest, stored = _read(d)

    # layer 1: bytes — exact array set, then per-array crc32
    cks = manifest.get("checksums", {})
    missing = sorted(set(cks) - set(stored))
    extra = sorted(set(stored) - set(cks))
    if missing or extra:
        raise ArtifactChecksumError(
            f"array set drift: missing {missing[:4]}, "
            f"unmanifested {extra[:4]}")
    for k in sorted(stored):
        if crc32_array(stored[k]) != cks[k]:
            raise ArtifactChecksumError(
                f"crc32 mismatch on {k!r}: artifact bytes corrupt")

    # canary cross-check: the manifest's JSON token copy vs the npz copy
    for i, c in enumerate(manifest.get("canaries", [])):
        npz_toks = stored.get(f"__canary__/{i}/tokens")
        if npz_toks is None or not np.array_equal(
                np.asarray(c["tokens"], np.int32), npz_toks):
            raise ArtifactChecksumError(
                f"canary {i} golden tokens diverge between manifest "
                "and arrays (tampered goldens)")

    # layer 2a: config fingerprint
    if cfg is not None and manifest.get("fingerprint") != fingerprint(cfg):
        raise ArtifactConfigError(
            "artifact was sealed for a different config "
            f"(fingerprint {manifest.get('fingerprint', '')[:12]}… != "
            f"{fingerprint(cfg)[:12]}…)")

    # decode true dtypes for the structural + finiteness layers
    arrays = {k: _restore(v, manifest["dtypes"][k])
              for k, v in stored.items()}

    # layer 2b: structural invariants of every packed leaf
    abs_tmpl = registry.abstract_params(cfg) if cfg is not None else None
    for path, meta in manifest.get("packed", {}).items():
        p = PackedBCSC(blocks=arrays[f"{path}/blocks"],
                       idx=arrays[f"{path}/idx"],
                       kb=int(meta["kb"]), joint=bool(meta["joint"]))
        bi = bo = dense = None
        if cfg is not None:
            bi, bo = sm.block_dims_for(cfg.blast, path)
            dense = sm.get_path(abs_tmpl, path).shape
        bad = packing.structure_violations(p, bi, bo, dense)
        if bad:
            raise ArtifactStructureError(
                f"packed leaf {path!r}: " + "; ".join(bad))
        if meta.get("joint"):
            leaf = path.split("/")[-1]
            partner = path[:-len(leaf)] + (
                leaf.replace("gate", "up") if "gate" in leaf
                else leaf.replace("up", "gate"))
            pidx = arrays.get(f"{partner}/idx")
            if pidx is None or not np.array_equal(
                    np.asarray(p.idx), np.asarray(pidx)):
                raise ArtifactStructureError(
                    f"joint promise broken: {path!r} marked joint but "
                    f"its idx table differs from {partner!r} — the "
                    "fused GLU kernel would contract the wrong blocks")

    # layer 2c: finiteness of every float leaf (incl. canary logits)
    for k, v in arrays.items():
        if v.dtype.kind == "f" or str(v.dtype) == "bfloat16":
            if not bool(np.isfinite(np.asarray(v, np.float32)).all()):
                raise ArtifactNonFiniteError(
                    f"non-finite values in {k!r}")
    return manifest


def load(d: str, cfg=None, *, run_canaries: bool = False, dist=None,
         max_token_mismatches: int = 0, max_logit_drift: float = 0.0):
    """Validate and load an artifact. Returns ``(params, manifest)``.
    With ``run_canaries`` (requires ``cfg``), also replays the golden
    generations on the loaded weights — the behavioural layer — before
    returning."""
    manifest = validate(d, cfg)
    _, stored = _read(d)
    arrays = {k: _restore(v, manifest["dtypes"][k])
              for k, v in stored.items()}
    params = _unflatten_params(arrays, manifest.get("packed", {}))
    if run_canaries:
        assert cfg is not None, "run_canaries needs the model config"
        goldens = {i: np.asarray(stored[f"__canary__/{i}/logits"],
                                 np.float32)
                   for i in range(len(manifest.get("canaries", [])))}
        verify_canaries(cfg, params, manifest, goldens,
                        max_token_mismatches=max_token_mismatches,
                        max_logit_drift=max_logit_drift, dist=dist)
    return params, manifest


def golden_logits(d: str, manifest: dict | None = None) -> dict:
    """The stored final-step canary logits, keyed by canary index (for
    ``verify_canaries`` callers that already hold loaded params)."""
    manifest = manifest if manifest is not None else _read(d)[0]
    _, stored = _read(d)
    return {i: np.asarray(stored[f"__canary__/{i}/logits"], np.float32)
            for i in range(len(manifest.get("canaries", [])))}
