"""Serving steps: prefill (build cache + last-token logits) and decode
(one token with cache). Weights arrive already PRUNED (zeros in pruned
blocks) or PACKED (balanced BCSC — the paper's inference memory win;
``export.py``). Greedy sampling by default; temperature optional at the
loop level.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import registry


def make_prefill_step(cfg, dist=None):
    """prefill(params, tokens, **frontend) -> (last_logits, kv-seed).

    For the KV-cache families the prefill writes the cache via the
    training forward's returned K/V; here (dry-run + CPU serving) we
    lower the forward and re-run decode from scratch caches, which is
    the same compute cost — the cache-write variant is a serving-loop
    detail (serve_loop.py seeds caches token-by-token for exactness)."""
    def prefill_step(params, tokens, **kw):
        logits, _ = registry.forward(cfg, params, tokens, masks=None,
                                     dist=dist, **kw)
        return logits[:, -1]
    return prefill_step


def make_decode_step(cfg, dist=None, temperature: float = 0.0):
    def decode_step(params, cache, tokens, pos, rng):
        logits, cache = registry.decode_step(cfg, params, cache, tokens,
                                             pos, masks=None, dist=dist)
        last = logits[:, -1]
        if temperature > 0.0:
            rng, sub = jax.random.split(rng)
            nxt = jax.random.categorical(sub, last / temperature)
        else:
            nxt = jnp.argmax(last, axis=-1)
        return nxt[:, None].astype(jnp.int32), cache, last, rng
    return decode_step
