"""Serving steps: prefill (build cache + last-token logits) and decode
(one token with cache). Weights arrive already PRUNED (zeros in pruned
blocks) or PACKED (balanced BCSC — the paper's inference memory win;
``export.py``). Greedy sampling by default; temperature optional at the
loop level.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import registry


def make_prefill_step(cfg, dist=None):
    """prefill(params, tokens, **frontend) -> (last_logits, kv-seed).

    For the KV-cache families the prefill writes the cache via the
    training forward's returned K/V; here (dry-run + CPU serving) we
    lower the forward and re-run decode from scratch caches, which is
    the same compute cost — the cache-write variant is a serving-loop
    detail (serve_loop.py seeds caches token-by-token for exactness)."""
    def prefill_step(params, tokens, **kw):
        logits, _ = registry.forward(cfg, params, tokens, masks=None,
                                     dist=dist, **kw)
        return logits[:, -1]
    return prefill_step


def make_prefill_chunk_step(cfg, dist=None):
    """Jittable chunked batched prefill (engine.py): one call runs a
    whole (B, C) chunk of right-aligned prompt tokens through the model
    and seeds the KV cache — the per-token Python prefill loop collapses
    to ceil(plen / C) jitted calls.

    prefill(params, cache, tokens, slot, offsets, lane_mask)
        -> (last_logits (B, V) f32, new_cache)
    """
    def prefill_step(params, cache, tokens, slot, offsets, lane_mask):
        logits, cache = registry.prefill_chunk(
            cfg, params, cache, tokens, slot, offsets, masks=None,
            dist=dist, lane_mask=lane_mask)
        return logits[:, -1], cache
    return prefill_step


def _run_slab(k_steps, max_len, eos_id, cache, state, park, step_fn):
    """The decode-slab scan body shared by the contiguous and paged
    twins — they differ ONLY in where a dead lane parks (``park``: a
    slot the cache write drops) and how one step touches the cache
    (``step_fn(cache, tokens (B,1), write_pos (B,)) -> (logits,
    new_cache)``), so the stop logic can never drift between them (the
    paged-vs-contiguous bitwise-parity guarantee leans on that).

    A lane dies mid-slab when it emits ``eos_id``, exhausts its budget,
    or runs out of cache (``frontier`` reaching ``max_len``); a dead
    lane's frontier/remaining freeze and its emitted tokens after the
    stop point are garbage the host discards — so greedy decode stays
    bitwise-identical to the per-token path.

    Fault containment rides the same carry: each step's last-row logits
    pass a per-lane finite check, and a lane whose logits go NaN/Inf is
    marked ``faulted`` and dies WITHOUT advancing its frontier — its
    request fails structurally (engine quarantine) while every other
    lane's argmax stream is untouched. ``state["poison"]`` (f32 (B,),
    normally all zero) is the injection port: it is added to the first
    in-slab step's logits and then zeroed, so a seeded FaultPlan can
    corrupt exactly one lane at exactly one step — adding 0.0 to every
    healthy lane's logits is exact in f32, so the check costs no
    parity."""
    def body(carry, _):
        cache, pending, frontier, remaining, live, poison, faulted = carry
        write_pos = jnp.where(live, frontier, park)
        logits, cache = step_fn(cache, pending[:, None], write_pos)
        last = logits[:, -1] + poison[:, None]
        poison = jnp.zeros_like(poison)
        nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        bad = live & ~jnp.isfinite(last).all(axis=-1)
        faulted = faulted | bad
        ok = live & ~bad
        frontier = jnp.where(ok, frontier + 1, frontier)
        remaining = jnp.where(ok, remaining - 1, remaining)
        died = (remaining <= 0) | (frontier >= max_len) | bad
        if eos_id is not None:
            died |= nxt == eos_id
        live = live & ~died
        pending = jnp.where(live, nxt, pending)
        return (cache, pending, frontier, remaining, live, poison,
                faulted), nxt

    carry = (cache, state["pending"], state["frontier"],
             state["remaining"], state["live"], state["poison"],
             state["faulted"])
    (cache, pending, frontier, remaining, live, poison,
     faulted), toks = jax.lax.scan(body, carry, None, length=k_steps)
    state = dict(state, pending=pending, frontier=frontier,
                 remaining=remaining, live=live, poison=poison,
                 faulted=faulted)
    return toks.T, state, cache


def make_decode_slab_step(cfg, k_steps: int, max_len: int,
                          eos_id: int | None = None, dist=None):
    """Jitted decode SLAB: one ``lax.scan`` over ``k_steps`` greedy
    decode steps, the whole token loop on-device — the host syncs once
    per slab instead of once per token (engine.py).

    The carried per-lane state (all (B,) vectors, persistent on-device
    between slabs) is a dict:

      ``pending``   int32  next token to feed each lane
      ``frontier``  int32  cache slot the lane writes next
      ``offsets``   int32  left-pad of the lane's prompt (rope/masking)
      ``remaining`` int32  decode tokens the lane may still emit
      ``live``      bool   lane still decoding

    Dead lanes park at write slot ``max_len`` (the scatter drops it) —
    see ``_run_slab`` for the shared stop logic.

    slab(params, cache, state) -> (tokens (B, k_steps) int32,
                                   new_state, new_cache)
    """
    def slab(params, cache, state):
        offsets = state["offsets"]

        def step_fn(cache, tokens, write_pos):
            return registry.decode_step(
                cfg, params, cache, tokens, write_pos, masks=None,
                dist=dist, offsets=offsets)

        return _run_slab(k_steps, max_len, eos_id, cache, state,
                         jnp.int32(max_len), step_fn)
    return slab


def make_paged_prefill_chunk_step(cfg, dist=None):
    """Paged twin of ``make_prefill_chunk_step``: the chunk's K/V routes
    through per-lane block tables into the shared page pool.
    ``read_pages`` must be jit-STATIC (the engine buckets it to a power
    of two, so the jit cache stays O(log max_pages)).

    prefill(params, cache, tokens, slot, offsets, lane_mask,
            block_tables, read_pages) -> (last_logits (B, V), new_cache)
    """
    def prefill_step(params, cache, tokens, slot, offsets, lane_mask,
                     block_tables, read_pages):
        logits, cache = registry.paged_prefill_chunk(
            cfg, params, cache, tokens, slot, offsets, block_tables,
            read_pages=read_pages, masks=None, dist=dist,
            lane_mask=lane_mask)
        return logits[:, -1], cache
    return prefill_step


def make_paged_decode_slab_step(cfg, k_steps: int, max_len: int,
                                page_size: int, eos_id: int | None = None,
                                dist=None, attn_backend: str = "xla"):
    """Paged twin of ``make_decode_slab_step``: the scan carries the same
    per-lane state dict plus ``bt`` — each lane's (max_pages,) block
    table, constant THROUGH a slab (the engine grows allocations only at
    slab boundaries, where the host syncs anyway). A dead lane parks at
    logical slot ``max_pages * page_size``: past the table end, so the
    paged write DROPS instead of clamping onto pool page 0 (which may
    belong to another lane). ``read_pages`` is jit-static; the engine
    guarantees ``read_pages * page_size >= min(max frontier + k_steps,
    max_len)`` so every in-slab query sees its whole live context.
    Stop logic is the shared ``_run_slab``.

    slab(params, cache, state, read_pages) -> (tokens (B, k_steps),
                                               new_state, new_cache)
    """
    def slab(params, cache, state, read_pages):
        offsets = state["offsets"]
        bt = state["bt"]

        def step_fn(cache, tokens, write_pos):
            return registry.paged_decode_step(
                cfg, params, cache, tokens, write_pos, bt,
                read_pages=read_pages, masks=None, dist=dist,
                offsets=offsets, attn_backend=attn_backend)

        return _run_slab(k_steps, max_len, eos_id, cache, state,
                         jnp.int32(bt.shape[1] * page_size), step_fn)
    return slab


def make_mixed_step(cfg, dist=None):
    """Jitted MIXED decode+prefill step (engine ``mixed=True``): one
    pass of the transformer stack over a (B, W) token batch with
    per-lane variable query lengths — running lanes contribute ONE
    decode token each (q_len 1 at start = their frontier), admitting
    lanes contribute a prefill chunk (q_len = chunk at start = their
    prefill position), idle lanes ride along masked out (q_len 0).
    Decode throughput is never zeroed by an arriving prompt, and the
    uncovered tails of several prefix-cached admissions coalesce into
    this one call instead of per-lane prefill loops.

    Each lane's next token is the argmax of its LAST valid row — for a
    decode lane that is its next decode token, for a lane finishing its
    prompt this step it is the request's first generated token, and for
    a mid-prompt or idle lane it is garbage the host ignores. Only the
    (B,) token vector crosses to the host.

    ``read_pages`` must be jit-STATIC and cover every lane's
    ``start + q_len`` (the engine buckets it to a power of two); W is
    baked into the trace, so the engine buckets the width too.

    ``poison`` (f32 (B,), normally zeros) is the same fault-injection
    port as the slab's: added to each lane's last valid row before the
    argmax, with a per-lane finite check returned as ``faulted`` so the
    engine can quarantine a corrupted lane without touching the others
    (idle lanes' garbage rows may be anything — the engine masks
    ``faulted`` by lane activity before acting on it).

    mixed(params, cache, tokens (B,W), starts (B,), q_lens (B,),
          offsets (B,), block_tables, read_pages, poison (B,))
        -> (next_tokens (B,) int32, faulted (B,) bool, new_cache)
    """
    def mixed_step(params, cache, tokens, starts, q_lens, offsets,
                   block_tables, read_pages, poison):
        logits, cache = registry.paged_prefill_chunk(
            cfg, params, cache, tokens, starts, offsets, block_tables,
            read_pages=read_pages, masks=None, dist=dist, q_lens=q_lens)
        last = jnp.take_along_axis(
            logits, jnp.maximum(q_lens.astype(jnp.int32) - 1,
                                0)[:, None, None], axis=1)[:, 0]
        last = last + poison[:, None]
        faulted = ~jnp.isfinite(last).all(axis=-1)
        return (jnp.argmax(last, -1).astype(jnp.int32), faulted, cache)
    return mixed_step


def make_copy_pages_step():
    """Jittable copy-on-write page copy over the paged pool
    (engine.py + serving/prefix_cache.py): duplicate pool pages ``src``
    into ``dst`` across every layer, K and V, in one fused scatter per
    array. The whole page is copied — the rows past the shared boundary
    are stale garbage the causal mask hides until the lane overwrites
    them, exactly like a recycled free page.

    copy(cache, src (n,) int32, dst (n,) int32) -> new_cache
    """
    def copy_pages(cache, src, dst):
        out = dict(cache)
        for name in ("k", "v"):
            out[name] = cache[name].at[:, dst].set(cache[name][:, src])
        return out
    return copy_pages


def make_gather_pages_step():
    """Jittable page DOWNLOAD gather for preemption (engine.py +
    serving/offload.py): pull pool pages ``pages`` out of the device
    cache across every layer, K and V — the (layers, n, page_size, KV,
    hd) results are what the host offload store keeps while the pages
    themselves are released for reuse.

    gather(cache, pages (n,) int32) -> (k, v)
    """
    def gather_pages(cache, pages):
        return cache["k"][:, pages], cache["v"][:, pages]
    return gather_pages


def make_scatter_pages_step():
    """Jittable page UPLOAD scatter, the restore half of preemption:
    write host-held page data ``k``/``v`` (layers, n, page_size, KV, hd)
    into freshly allocated pool pages ``dst``. Duplicate indices in
    ``dst`` (the engine's power-of-two padding repeats the first page
    with its own data) write identical values, so the pad is a no-op.

    scatter(cache, dst (n,) int32, k, v) -> new_cache
    """
    def scatter_pages(cache, dst, k, v):
        out = dict(cache)
        out["k"] = cache["k"].at[:, dst].set(k)
        out["v"] = cache["v"].at[:, dst].set(v)
        return out
    return scatter_pages


def make_decode_step(cfg, dist=None, temperature: float = 0.0):
    def decode_step(params, cache, tokens, pos, rng):
        logits, cache = registry.decode_step(cfg, params, cache, tokens,
                                             pos, masks=None, dist=dist)
        last = logits[:, -1]
        if temperature > 0.0:
            rng, sub = jax.random.split(rng)
            nxt = jax.random.categorical(sub, last / temperature)
        else:
            nxt = jnp.argmax(last, axis=-1)
        return nxt[:, None].astype(jnp.int32), cache, last, rng
    return decode_step
