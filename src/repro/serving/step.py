"""Serving steps: prefill (build cache + last-token logits) and decode
(one token with cache). Weights arrive already PRUNED (zeros in pruned
blocks) or PACKED (balanced BCSC — the paper's inference memory win;
``export.py``). Greedy sampling by default; temperature optional at the
loop level.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import registry


def make_prefill_step(cfg, dist=None):
    """prefill(params, tokens, **frontend) -> (last_logits, kv-seed).

    For the KV-cache families the prefill writes the cache via the
    training forward's returned K/V; here (dry-run + CPU serving) we
    lower the forward and re-run decode from scratch caches, which is
    the same compute cost — the cache-write variant is a serving-loop
    detail (serve_loop.py seeds caches token-by-token for exactness)."""
    def prefill_step(params, tokens, **kw):
        logits, _ = registry.forward(cfg, params, tokens, masks=None,
                                     dist=dist, **kw)
        return logits[:, -1]
    return prefill_step


def make_prefill_chunk_step(cfg, dist=None):
    """Jittable chunked batched prefill (engine.py): one call runs a
    whole (B, C) chunk of right-aligned prompt tokens through the model
    and seeds the KV cache — the per-token Python prefill loop collapses
    to ceil(plen / C) jitted calls.

    prefill(params, cache, tokens, slot, offsets, lane_mask)
        -> (last_logits (B, V) f32, new_cache)
    """
    def prefill_step(params, cache, tokens, slot, offsets, lane_mask):
        logits, cache = registry.prefill_chunk(
            cfg, params, cache, tokens, slot, offsets, masks=None,
            dist=dist, lane_mask=lane_mask)
        return logits[:, -1], cache
    return prefill_step


def make_engine_decode_step(cfg, dist=None):
    """Greedy decode step for the continuous-batching engine: shared
    scalar cache slot, per-lane position offsets (ragged batch).

    decode(params, cache, tokens, pos, offsets)
        -> (next (B,1) int32, new_cache, last_logits (B,V) f32)
    """
    def decode_step(params, cache, tokens, pos, offsets):
        logits, cache = registry.decode_step(cfg, params, cache, tokens,
                                             pos, masks=None, dist=dist,
                                             offsets=offsets)
        last = logits[:, -1]
        nxt = jnp.argmax(last, axis=-1)
        return nxt[:, None].astype(jnp.int32), cache, last
    return decode_step


def make_decode_step(cfg, dist=None, temperature: float = 0.0):
    def decode_step(params, cache, tokens, pos, rng):
        logits, cache = registry.decode_step(cfg, params, cache, tokens,
                                             pos, masks=None, dist=dist)
        last = logits[:, -1]
        if temperature > 0.0:
            rng, sub = jax.random.split(rng)
            nxt = jax.random.categorical(sub, last / temperature)
        else:
            nxt = jnp.argmax(last, axis=-1)
        return nxt[:, None].astype(jnp.int32), cache, last, rng
    return decode_step
