"""Export a trained BLaST model for serving (paper §5.2 / Fig. 7):

  * ``prune_params``  — bake masks into weights (zeros in pruned blocks),
    cast to bf16: the baseline serving layout;
  * ``pack_params``   — replace every sparse weight with its balanced-
    BCSC ``PackedBCSC`` (blocks + int32 index table): the 1/(1-s) memory
    reduction and the input the BSpMM kernels consume.

``memory_report`` quantifies the Fig. 7 claim (bytes & #accelerators).
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing, sparse_mlp as sm, topk
from repro.models import registry


class UnbalancedMaskWarning(UserWarning):
    """A mask handed to ``pack_params`` is not balanced: some block-
    columns keep fewer blocks than the max, so the pack zero-pads them
    up to the static ``nnz`` — numerically exact, but the advertised
    1/(1-s) memory reduction silently degrades by the pad fraction."""


def prune_params(cfg, params, masks, dtype=jnp.bfloat16):
    out = params
    for path, m in masks.items():
        w = sm.get_path(params, path)
        bi, bo = sm.block_dims_for(cfg.blast, path)
        out = sm.set_path(out, path,
                          topk.apply_block_mask(w, m, bi, bo))
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if x.dtype == jnp.float32 else x, out)


def pack_params(cfg, params, masks, dtype=jnp.bfloat16,
                unbalanced: str = "warn",
                pad_report: dict | None = None):
    """Sparse leaves -> PackedBCSC (static nnz = max kept per column,
    uniform under balanced selection).

    Gate/up pairs whose masks coincide (joint pruning) are marked
    ``joint`` so the fused GLU kernels stream each X tile once
    (``packing.mark_joint``).

    An UNBALANCED mask no longer packs silently: ``unbalanced`` is
    ``"warn"`` (``UnbalancedMaskWarning`` with the pad fraction),
    ``"raise"`` (``ValueError``), or ``"ignore"``. A caller-supplied
    ``pad_report`` dict is filled ``path -> pad fraction`` for every
    padded path — ``artifact.seal`` records it in the manifest."""
    if unbalanced not in ("warn", "raise", "ignore"):
        raise ValueError(f"unbalanced={unbalanced!r}: expected "
                         "'warn', 'raise' or 'ignore'")
    pruned = prune_params(cfg, params, masks, dtype)
    out = pruned
    for path, m in masks.items():
        w = sm.get_path(pruned, path)
        bi, bo = sm.block_dims_for(cfg.blast, path)
        counts = np.asarray(jax.device_get(m)).sum(axis=-2)
        nnz = int(counts.max())
        frac = packing.pad_fraction(m, nnz)
        if frac > 0.0:
            if pad_report is not None:
                pad_report[path] = frac
            msg = (f"mask for {path!r} is unbalanced: {frac:.1%} of "
                   f"packed block slots are zero padding (nnz={nnz}, "
                   f"min per-column count {int(counts.min())})")
            if unbalanced == "raise":
                raise ValueError(msg)
            if unbalanced == "warn":
                warnings.warn(msg, UnbalancedMaskWarning, stacklevel=2)
        p = packing.pack_stacked(w, m, bi, bo, nnz)
        out = sm.set_path(out, path, p)
    for gpath in masks:
        leaf = gpath.split("/")[-1]
        if leaf not in ("w_gate", "ws_gate"):
            continue
        upath = gpath[:-len(leaf)] + leaf.replace("gate", "up")
        if upath not in masks:
            continue
        pg, pu = packing.mark_joint(sm.get_path(out, gpath),
                                    sm.get_path(out, upath))
        out = sm.set_path(out, gpath, pg)
        out = sm.set_path(out, upath, pu)
    return out


def abstract_packed_params(cfg, sparsity: float, mesh=None):
    """ShapeDtypeStruct serving params with sparse leaves replaced by
    abstract PackedBCSC at ``sparsity`` (dry-run: the compiled serve
    step carries the true sparse FLOPs and packed memory footprint).

    Returns (abstract_params, shardings | None)."""
    import math

    from repro.core.packing import PackedBCSC
    from repro.distributed import sharding as shd

    abs_p = registry.abstract_params(cfg)
    abs_p = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
        abs_p)
    shards = shd.param_sharding_tree(registry.param_specs(cfg), mesh) \
        if mesh is not None else None
    axes = registry.axes_tree(cfg)
    tp = 1
    if mesh is not None:
        tp = dict(zip(mesh.axis_names,
                      mesh.devices.shape)).get("model", 1)
    for path in registry.sparse_paths(cfg):
        w = sm.get_path(abs_p, path)
        bi, bo = sm.block_dims_for(cfg.blast, path)
        kb, nb = w.shape[-2] // bi, w.shape[-1] // bo
        nnz = max(1, math.ceil((1.0 - sparsity) * kb))
        swapped = path.split("/")[-1] in sm._SWAPPED_LEAVES
        if swapped and nnz >= tp:
            # down-projections: column-blocks = d_model (often not
            # tp-divisible) — shard the nnz CONTRACTION dim instead
            # (zero-block padded; partial sums psum exactly)
            nnz = math.ceil(nnz / tp) * tp
        lead = w.shape[:-2]
        packed = PackedBCSC(
            blocks=jax.ShapeDtypeStruct(lead + (nb, nnz, bi, bo),
                                        jnp.bfloat16),
            idx=jax.ShapeDtypeStruct(lead + (nb, nnz), jnp.int32),
            kb=kb)
        abs_p = sm.set_path(abs_p, path, packed)
        if shards is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            waxes = sm.get_path(axes, path)
            nlead = len(lead)
            lead_parts = [shd.spec_for((w.shape[i],), (waxes[i],),
                                       mesh)[0] for i in range(nlead)]
            if swapped and nnz % tp == 0:
                bspec = P(*lead_parts, None, "model", None, None)
                ispec = P(*lead_parts, None, "model")
            elif not swapped and nb % tp == 0:
                bspec = P(*lead_parts, "model", None, None, None)
                ispec = P(*lead_parts, "model", None)
            else:
                bspec = P(*lead_parts, None, None, None, None)
                ispec = P(*lead_parts, None, None)
            shards = sm.set_path(
                shards, path,
                PackedBCSC(blocks=NamedSharding(mesh, bspec),
                           idx=NamedSharding(mesh, ispec), kb=kb))
    return abs_p, shards


def memory_report(cfg, params_or_packed) -> dict:
    """Bytes of the serving weights + #accelerators at a given HBM size
    (paper Fig. 7 uses 96 GB GH200; TPU v5e is 16 GB)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params_or_packed,
            is_leaf=lambda x: isinstance(x, packing.PackedBCSC)):
        if isinstance(leaf, packing.PackedBCSC):
            total += packing.storage_bytes(leaf)
        else:
            total += leaf.size * leaf.dtype.itemsize
    return {
        "bytes": int(total),
        "GiB": total / 2**30,
        "chips_v5e_16GB": int(np.ceil(total / (16 * 2**30))),
        "gpus_96GB": int(np.ceil(total / (96 * 2**30))),
    }
