"""Deterministic fault injection for PRETRAINING + the training stack's
failure taxonomy (the training counterpart of ``serving/faults.py``).

BLaST is an inference *and pretraining* method, and a prune-grow
schedule makes divergent steps more likely exactly when the sparsifier
just zeroed whole weight blocks — a lost step or a torn checkpoint at
that moment costs a restart, and restart cost dominates training
economics at scale. This module is the TEST SUBSTRATE for the training
loop's recovery guarantees: a seeded ``TrainFaultPlan`` consumed at
fixed step indices so chaos tests are bitwise-reproducible, plus the
structured error types the checkpoint/guard paths raise.

Fault points (all keyed by the HOST step index ``i`` of the train
loop — one ``step_fn`` call):

  * ``nan_grads(step)``      — multiply the loss by ``(1 + NaN/Inf)``
    inside the jitted step, poisoning EVERY gradient; the in-step
    anomaly guard must skip the update (identity state transition);
    the 0.0 no-fault value is a bitwise-exact identity (x * (1+0));
  * ``loss_spike(step, m)``  — add ``m`` to the REPORTED loss only
    (gradients untouched): the host-side EMA/z-score detector must
    flag it while the device-side finite check stays green;
  * ``force_skip(step)``     — force the skip path with healthy
    gradients: the parity oracle's control arm ("a run that never
    applies step k's update");
  * ``hard_kill(step)``      — SIGKILL our own process at the top of
    the step: the subprocess chaos harness's crash; resume must be
    bitwise-identical to an uninterrupted run;
  * ``slow_step(step, s)``   — sleep inside the timed region: the
    straggler watchdog must emit structured telemetry;
  * ``corrupt_checkpoint(nth_save)`` — bit-flip the nth checkpoint's
    array file AFTER it lands on disk (post-rename, post-checksum):
    restore must detect the mismatch and fall back to the newest
    intact checkpoint.

The module also hosts the subprocess chaos child
(``python -m repro.training.faults spec.json``): a self-contained
training run built from a JSON spec that tests and the chaos benchmark
SIGKILL, resume, and compare bitwise against uninterrupted runs.
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import time

import numpy as np


# --------------------------------------------------------------- errors
class TrainingFault(Exception):
    """Base class for every structured training-stack failure."""


class CheckpointCorruptionError(TrainingFault):
    """A checkpoint failed integrity verification (crc32 manifest
    mismatch, torn directory, unreadable arrays)."""

    def __init__(self, step: int | None, directory: str,
                 reason: str = "checksum mismatch"):
        self.step, self.directory, self.reason = step, directory, reason
        super().__init__(
            f"checkpoint step {step} in {directory} failed integrity "
            f"verification: {reason}")


class TrainingDivergedError(TrainingFault):
    """K consecutive anomalous steps and the rewind budget is spent (or
    no intact checkpoint exists to rewind to): the run is diverging
    deterministically — replaying will not help, a human must look."""

    def __init__(self, step: int, consecutive: int, rewinds: int):
        self.step, self.consecutive, self.rewinds = (step, consecutive,
                                                     rewinds)
        super().__init__(
            f"training diverged at step {step}: {consecutive} "
            f"consecutive anomalous steps after {rewinds} rewind(s)")


# ------------------------------------------------------------- the plan
class TrainFaultPlan:
    """A seeded, replayable schedule of injected training faults.

    Build one, arm faults at chosen step indices, and hand it to
    ``train_loop.train(..., faults=plan)``. The plan is consumed as it
    fires — a rewind replays the faulted steps CLEANLY (transient
    hardware faults do not recur on replay), and rerunning the same
    plan instance needs a fresh plan. ``seed`` feeds ``rng`` for tests
    that want randomized-but-reproducible fault placement; the plan
    never draws from it implicitly. ``fired`` is the audit trail."""

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self._nan: dict[int, str] = {}          # step -> "nan" | "inf"
        self._spikes: dict[int, float] = {}     # step -> magnitude
        self._skips: set[int] = set()
        self._kills: set[int] = set()
        self._slow: dict[int, float] = {}       # step -> seconds
        self._corrupt_saves: dict[int, int] = {}  # nth save -> bit
        self._n_saves = 0
        self.fired: list[str] = []

    # ----------------------------------------------------------- arming
    def nan_grads(self, step: int, kind: str = "nan") -> "TrainFaultPlan":
        assert kind in ("nan", "inf")
        self._nan[step] = kind
        return self

    def loss_spike(self, step: int,
                   magnitude: float = 1e3) -> "TrainFaultPlan":
        self._spikes[step] = float(magnitude)
        return self

    def force_skip(self, step: int) -> "TrainFaultPlan":
        self._skips.add(step)
        return self

    def hard_kill(self, step: int) -> "TrainFaultPlan":
        self._kills.add(step)
        return self

    def slow_step(self, step: int, seconds: float) -> "TrainFaultPlan":
        self._slow[step] = float(seconds)
        return self

    def corrupt_checkpoint(self, nth_save: int = 0,
                           bit: int = 0) -> "TrainFaultPlan":
        self._corrupt_saves[nth_save] = bit
        return self

    # ------------------------------------------------------- loop hooks
    def step_scalars(self, idx: int) -> dict:
        """Per-step injection scalars riding the batch into the jitted
        step. Always returns all three keys (stable batch pytree
        structure across steps); the no-fault values are bitwise-exact
        identities inside the step."""
        gp = 0.0
        if idx in self._nan:
            kind = self._nan.pop(idx)
            gp = np.nan if kind == "nan" else np.inf
            self.fired.append(f"nan_grads:{kind}@{idx}")
        lp = 0.0
        if idx in self._spikes:
            lp = self._spikes.pop(idx)
            self.fired.append(f"loss_spike@{idx}:{lp:g}")
        fs = 0.0
        if idx in self._skips:
            self._skips.discard(idx)
            fs = 1.0
            self.fired.append(f"force_skip@{idx}")
        return {"grad_poison": np.float32(gp),
                "loss_poison": np.float32(lp),
                "force_skip": np.float32(fs)}

    def on_host_step(self, idx: int) -> None:
        """Top of the host loop iteration: hard process kill (the
        subprocess chaos harness's crash point — nothing after this
        line runs, including any in-flight async checkpoint write)."""
        if idx in self._kills:
            self._kills.discard(idx)
            os.kill(os.getpid(), signal.SIGKILL)

    def on_timed_step(self, idx: int) -> None:
        """Inside the timed region, before the jitted call: a slow step
        the straggler watchdog must notice."""
        s = self._slow.pop(idx, None)
        if s:
            self.fired.append(f"slow@{idx}:{s:g}s")
            time.sleep(s)

    def on_ckpt_saved(self, path: str, step: int) -> None:
        """Checkpointer hook, called AFTER the directory was renamed
        into place (checksums already computed): bit-flip one byte in
        the middle of the array file — host-RAM/disk rot the restore
        verify must catch."""
        nth = self._n_saves
        self._n_saves += 1
        bit = self._corrupt_saves.pop(nth, None)
        if bit is None:
            return
        f = os.path.join(path, "arrays.npz")
        with open(f, "r+b") as fh:
            fh.seek(0, os.SEEK_END)
            off = fh.tell() // 2
            fh.seek(off)
            b = fh.read(1)
            fh.seek(off)
            fh.write(bytes([b[0] ^ (1 << (bit % 8))]))
        self.fired.append(f"ckpt_bitflip:save{nth}@step{step}")


# ----------------------------------------------- subprocess chaos child
def _src_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def default_chaos_spec(workdir: str, **overrides) -> dict:
    """The tiny CPU-runnable training spec the chaos harness kills and
    resumes. ``step_size=5`` with ``kill_at=11`` / ``ckpt_every=4``
    puts the resume replay ACROSS a prune-grow refresh (restore step 8,
    refresh fires at step 10), so masks and params must rewind
    consistently for the bitwise oracle to pass."""
    spec = {
        "model": dict(name="chaos-tiny", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2,
                      head_dim=16, d_ff=64, vocab_size=64,
                      mlp_kind="glu", mlp_act="silu",
                      norm_kind="rmsnorm", remat=False,
                      compute_dtype="float32", chunk_size=8),
        "blast": dict(enabled=True, b_in=16, b_out=16, s_max=0.75,
                      total_steps=20, step_size=5, dense_last=1),
        "steps": 16, "seq_len": 32, "batch": 8, "data_seed": 3,
        "opt": dict(peak_lr=2e-2, warmup_steps=5, total_steps=60,
                    weight_decay=0.0),
        "ckpt_dir": None, "ckpt_every": 4, "keep": 3,
        "kill_at": None, "nan_at": [],
        "out": os.path.join(workdir, "final.npz"),
        "meta_out": os.path.join(workdir, "meta.json"),
    }
    spec.update(overrides)
    return spec


def run_child(spec: dict, spec_path: str,
              timeout: float = 600) -> subprocess.CompletedProcess:
    """Write ``spec`` to ``spec_path`` and run the chaos child on it in
    a subprocess (so a ``hard_kill`` SIGKILLs the child, not the
    caller). Returns the CompletedProcess; a killed child has
    ``returncode == -SIGKILL``."""
    with open(spec_path, "w") as f:
        json.dump(spec, f)
    env = dict(os.environ)
    env["PYTHONPATH"] = (_src_root() + os.pathsep
                         + env.get("PYTHONPATH", ""))
    return subprocess.run(
        [sys.executable, "-m", "repro.training.faults", spec_path],
        capture_output=True, text=True, env=env, timeout=timeout)


def chaos_child_main(argv: list[str]) -> None:
    """Entry point of the subprocess chaos child: build the spec'd
    model, train (resuming from any intact checkpoint in ckpt_dir),
    then dump the final TrainState to ``out`` and run metadata to
    ``meta_out`` for the parent's bitwise comparison."""
    with open(argv[0]) as f:
        spec = json.load(f)
    import jax

    from repro.checkpointing.checkpoint import Checkpointer, _flatten
    from repro.configs.base import ModelConfig
    from repro.core.prune_grow import BlastSpec
    from repro.data.pipeline import SyntheticLM
    from repro.optim import adamw
    from repro.training import train_loop

    cfg = ModelConfig(**spec["model"], blast=BlastSpec(**spec["blast"]))
    src = SyntheticLM(cfg.vocab_size, spec["seq_len"], spec["batch"],
                      seed=spec["data_seed"])
    opt = adamw.AdamWConfig(**spec["opt"])
    plan = TrainFaultPlan()
    if spec.get("kill_at") is not None:
        plan.hard_kill(spec["kill_at"])
    for s in spec.get("nan_at", []):
        plan.nan_grads(s)
    resumed_from = None
    restore_s = 0.0
    if spec.get("ckpt_dir"):
        t0 = time.monotonic()
        resumed_from = Checkpointer(spec["ckpt_dir"],
                                    keep=spec["keep"]).latest_intact_step()
        restore_s = time.monotonic() - t0
    loop = train_loop.TrainLoopConfig(
        total_steps=spec["steps"], ckpt_dir=spec.get("ckpt_dir"),
        ckpt_every=spec["ckpt_every"], keep=spec["keep"],
        log_every=10 ** 9)
    t0 = time.monotonic()
    state, hist = train_loop.train(cfg, opt, src, loop, faults=plan,
                                   log_fn=lambda m: None)
    wall = time.monotonic() - t0
    flat = _flatten({"step": state.step, "params": state.params,
                     "opt_state": state.opt_state, "masks": state.masks,
                     "rng": state.rng})
    np.savez(spec["out"],
             **{k: np.asarray(jax.device_get(v)) for k, v in flat.items()})
    counters = {k: hist[-1].get(k) for k in
                ("anomaly_steps", "skipped_steps", "rewinds",
                 "ckpt_fallbacks")} if hist else {}
    with open(spec["meta_out"], "w") as f:
        json.dump({"resumed_from": resumed_from, "wall_s": wall,
                   "verify_latency_s": restore_s, "fired": plan.fired,
                   "counters": counters}, f)


if __name__ == "__main__":
    chaos_child_main(sys.argv[1:])
