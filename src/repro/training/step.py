"""The jitted train step: forward/backward (STE dense grads), in-step
blocked prune-and-grow (paper Listing 1 — the mask refresh happens INSIDE
the compiled step under lax.cond, so the whole sparsity schedule runs
with zero recompiles), masked AdamW update with regrown-moment reset.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import distill, sparse_mlp as sm
from repro.models import registry
from repro.optim import adamw


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    step: jax.Array
    params: Any
    opt_state: Any
    masks: Any
    rng: jax.Array


def init_state(cfg, rng) -> TrainState:
    params = registry.init_params(cfg, rng)
    masks = registry.init_masks(cfg, params)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt_state=adamw.init(params), masks=masks, rng=rng)


def abstract_state(cfg) -> TrainState:
    """ShapeDtypeStruct TrainState (dry-run: no allocation)."""
    params = registry.abstract_params(cfg)
    sds = lambda t: jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    # masks shapes derived from weight shapes
    masks = {}
    if cfg.blast.enabled:
        for path in registry.sparse_paths(cfg):
            w = sm.get_path(params, path)
            bi, bo = sm.block_dims_for(cfg.blast, path)
            masks[path] = jax.ShapeDtypeStruct(
                w.shape[:-2] + (w.shape[-2] // bi, w.shape[-1] // bo),
                jnp.bool_)
    opt = {"m": sds(params), "v": sds(params)}
    return TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32), params=params,
        opt_state=opt, masks=masks,
        rng=jax.ShapeDtypeStruct((2,), jnp.uint32))


def loss_fn(cfg, params, masks, batch, teacher_logits=None,
            kd_alpha=1.0, kd_beta=0.0, dist=None):
    kw = {}
    if cfg.family == "audio":
        kw["frames"] = batch["frames"]
    if cfg.family == "vlm":
        kw["patch_embeds"] = batch["patch_embeds"]
    logits, aux = registry.forward(cfg, params, batch["tokens"],
                                   masks=masks, dist=dist, **kw)
    loss = distill.distill_loss(logits, batch["labels"],
                                teacher_logits, alpha=kd_alpha,
                                beta=kd_beta)
    if cfg.is_moe:
        loss = loss + 0.01 * aux
    return loss, (logits, aux)


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, dist=None,
                    kd_alpha=1.0, kd_beta=0.0, teacher_cfg=None,
                    teacher_params_static=None, microbatches: int = 1,
                    guard: bool = True,
                    grad_norm_limit: float | None = None):
    """Build the jittable train_step(state, batch) -> (state, metrics).

    ``microbatches`` > 1: gradient accumulation via lax.scan over batch
    slices — bounds the activation working set to 1/N (gemma2-27B
    train_4k needs N>=4 to fit v5e HBM — EXPERIMENTS.md §Perf).

    Knowledge distillation (paper §5.2): when ``teacher_cfg`` is given,
    the batch must carry 'teacher_logits' (precomputed) OR
    ``teacher_params_static`` is closed over for an in-step dense
    teacher forward.

    Anomaly guard (``guard=True``): the step computes an ``anomaly``
    flag — non-finite loss, non-finite gradient norm, or gradient norm
    over ``grad_norm_limit`` — and applies SKIP-UPDATE semantics under
    ``lax.cond``: an anomalous step is an identity update on
    params/opt-state/masks (only ``step`` advances), so a run that
    hits NaN grads at step k is bitwise-identical to a run that never
    applies step k's update. The flag rides the metrics dict: zero
    extra host syncs.

    Fault-injection scalars (training/faults.py) may ride the batch:
    ``grad_poison`` multiplies the loss by ``(1 + poison)`` BEFORE the
    backward (NaN/Inf poisons every gradient; the 0.0 no-fault value is
    a bitwise-exact identity), ``loss_poison`` is added to the REPORTED
    loss only (host-visible spike, gradients untouched), and
    ``force_skip`` forces the skip path with healthy gradients (the
    parity oracle's control arm)."""
    spec = cfg.blast
    dense_flags = registry.dense_layer_flags(cfg) if spec.enabled else None

    def train_step(state: TrainState, batch):
        batch = dict(batch)
        grad_poison = batch.pop("grad_poison", None)
        loss_poison = batch.pop("loss_poison", None)
        force_skip = batch.pop("force_skip", None)
        teacher_logits = batch.get("teacher_logits")
        if teacher_params_static is not None:
            teacher_logits, _ = registry.forward(
                teacher_cfg or cfg, teacher_params_static,
                batch["tokens"])
            teacher_logits = jax.lax.stop_gradient(teacher_logits)

        def grads_of(b, tl):
            def poisoned_loss(p):
                loss, aux2 = loss_fn(cfg, p, state.masks, b, tl,
                                     kd_alpha, kd_beta, dist)
                if grad_poison is not None:
                    loss = loss * (1.0 + grad_poison)
                return loss, aux2
            return jax.value_and_grad(
                poisoned_loss, has_aux=True)(state.params)

        if microbatches <= 1:
            (loss, (_, aux)), dense_grads = grads_of(batch,
                                                     teacher_logits)
        else:
            n = microbatches
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]),
                batch)
            tlm = None if teacher_logits is None else \
                teacher_logits.reshape(n, -1, *teacher_logits.shape[1:])

            def acc(carry, xs):
                g_acc, loss_acc, aux_acc = carry
                b_i = xs if tlm is None else xs[0]
                tl_i = None if tlm is None else xs[1]
                (loss_i, (_, aux_i)), g_i = grads_of(b_i, tl_i)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g_i)
                return (g_acc, loss_acc + loss_i, aux_acc + aux_i), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            xs = mb if tlm is None else (mb, tlm)
            (dense_grads, loss, aux), _ = jax.lax.scan(
                acc, (zeros, 0.0, 0.0), xs)
            dense_grads = jax.tree_util.tree_map(
                lambda g: g / n, dense_grads)
            loss, aux = loss / n, aux / n

        gnorm = adamw.global_norm(dense_grads)
        if guard:
            ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
            if grad_norm_limit is not None:
                ok &= gnorm <= grad_norm_limit
            anomaly = ~ok
        else:
            anomaly = jnp.zeros((), bool)
        if force_skip is not None:
            anomaly = anomaly | (force_skip > 0)

        def apply_update(_):
            if spec.enabled:
                masks, params, _grown = sm.maybe_refresh(
                    spec, state.params, dense_grads, state.masks,
                    state.step, dense_flags)
                grads = sm.mask_grads(masks, dense_grads, spec)
                opt_state = adamw.mask_moments(state.opt_state, masks,
                                               spec)
            else:
                masks, params = state.masks, state.params
                grads, opt_state = dense_grads, state.opt_state
            params, opt_state, _om = adamw.update(
                opt_cfg, grads, opt_state, params, state.step)
            return params, opt_state, masks

        def skip_update(_):
            return state.params, state.opt_state, state.masks

        params, opt_state, masks = jax.lax.cond(
            anomaly, skip_update, apply_update, None)

        loss_out = loss if loss_poison is None else loss + loss_poison
        metrics = {"loss": loss_out, "aux": aux,
                   "sparsity": (sm.tree_sparsity(masks)
                                if spec.enabled else 0.0),
                   "grad_norm": gnorm,
                   "lr": adamw.lr_at(opt_cfg, state.step),
                   "anomaly": anomaly.astype(jnp.int32)}
        new_state = TrainState(step=state.step + 1, params=params,
                               opt_state=opt_state, masks=masks,
                               rng=state.rng)
        return new_state, metrics

    return train_step
