"""Deferred (and optionally int8-EF-compressed) data-parallel gradient
reduction — the production fix identified by the gemma2-27b hillclimb
(EXPERIMENTS.md §Perf cell 2):

GSPMD's implicit gradient psum fires once PER MICROBATCH (measured: 8
microbatches doubled the collective term). Here the train step runs
under a PARTIAL-MANUAL shard_map — manual over the data axes, Auto over
the model axis (TP/SP/GSPMD untouched inside) — so per-shard gradients
accumulate UNREDUCED across microbatches and cross the DP fabric exactly
once, optionally as int8 (4x fewer bytes; error feedback keeps it
unbiased: optim/compress.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import sparse_mlp as sm
from repro.distributed.context import (HAS_PARTIAL_MANUAL, DistContext,
                                       shard_map)
from repro.models import registry
from repro.optim import adamw, compress
from repro.training.step import TrainState, loss_fn


def make_train_step_deferred(cfg, opt_cfg: adamw.AdamWConfig, mesh,
                             microbatches: int = 1,
                             compress_grads: bool = True):
    """train_step(state, batch) with ONE (compressed) DP reduction.

    opt_state grows an 'ef' tree (error-feedback residuals) when
    compression is on — init via ``init_opt_state``."""
    if not HAS_PARTIAL_MANUAL:
        raise NotImplementedError(
            "deferred reduction needs partial-manual shard_map "
            "(axis_names), unsupported by this jax version")
    spec = cfg.blast
    dense_flags = registry.dense_layer_flags(cfg) if spec.enabled else None
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # inside the manual-data region, sharding constraints may reference
    # only the Auto axes -> batch dim unconstrained, model-axis SP kept
    dist = DistContext(mesh=mesh, manual_data=True)

    def body(state: TrainState, batch):
        def grads_of(b):
            return jax.value_and_grad(
                lambda p: loss_fn(cfg, p, state.masks, b, None,
                                  1.0, 0.0, dist),
                has_aux=True)(state.params)

        n = microbatches
        if n <= 1:
            (loss, (_, aux)), g = grads_of(batch)
        else:
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]),
                batch)

            def acc(carry, b_i):
                g_acc, l_acc, a_acc = carry
                (l_i, (_, a_i)), g_i = grads_of(b_i)
                return (jax.tree_util.tree_map(jnp.add, g_acc, g_i),
                        l_acc + l_i, a_acc + a_i), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (g, loss, aux), _ = jax.lax.scan(acc, (zeros, 0.0, 0.0), mb)
            g = jax.tree_util.tree_map(lambda x: x / n, g)
            loss, aux = loss / n, aux / n

        # THE deferred reduction: one pass over the DP fabric
        if compress_grads:
            flat_g, tdef = jax.tree_util.tree_flatten(g)
            flat_e = tdef.flatten_up_to(state.opt_state["ef"])
            red = [compress.reduce_leaf_int8(gi, ei, data_axes)
                   for gi, ei in zip(flat_g, flat_e)]
            dense_grads = tdef.unflatten([r[0] for r in red])
            new_ef = tdef.unflatten([r[1] for r in red])
        else:
            dense_grads = jax.tree_util.tree_map(
                lambda x: jax.lax.pmean(x, data_axes), g)
            new_ef = state.opt_state.get("ef", {})
        loss = jax.lax.pmean(loss, data_axes)

        if spec.enabled:
            masks, params, _ = sm.maybe_refresh(
                spec, state.params, dense_grads, state.masks,
                state.step, dense_flags)
            grads = sm.mask_grads(masks, dense_grads, spec)
            opt_state = adamw.mask_moments(state.opt_state, masks, spec)
        else:
            masks, params, grads = state.masks, state.params, dense_grads
            opt_state = state.opt_state

        params, mv, om = adamw.update(
            opt_cfg, grads, {"m": opt_state["m"], "v": opt_state["v"]},
            params, state.step)
        opt_state = {"m": mv["m"], "v": mv["v"], "ef": new_ef}
        metrics = {"loss": loss, "aux": aux,
                   "sparsity": (sm.tree_sparsity(masks)
                                if spec.enabled else 0.0), **om}
        return (TrainState(step=state.step + 1, params=params,
                           opt_state=opt_state, masks=masks,
                           rng=state.rng), metrics)

    # manual over data; params/opt/masks ride along on the Auto model
    # axis (specs must not mention Auto axes)
    rep = P()
    state_spec = TrainState(
        step=rep,
        params=jax.tree_util.tree_map(lambda _: rep,
                                      registry.abstract_params(cfg)),
        opt_state=None, masks=None, rng=rep)
    # build full spec trees lazily inside the wrapper instead:

    def train_step(state: TrainState, batch):
        st_spec = jax.tree_util.tree_map(lambda _: rep, state)
        b_first = tuple(data_axes) if len(data_axes) > 1 else \
            (data_axes[0] if data_axes else None)
        b_spec = jax.tree_util.tree_map(
            lambda x: P(*([b_first] + [None] * (x.ndim - 1))), batch)
        out_spec = (jax.tree_util.tree_map(lambda _: rep, state),
                    {"loss": rep, "aux": rep, "sparsity": rep,
                     "grad_norm": rep, "lr": rep})
        f = shard_map(body, mesh=mesh, in_specs=(st_spec, b_spec),
                      out_specs=out_spec, check_vma=False,
                      axis_names=set(data_axes))
        return f(state, batch)

    del state_spec
    return train_step


def init_opt_state(cfg, params, compress_grads: bool = True):
    st = adamw.init(params)
    st["ef"] = (compress.init_error_feedback(params)
                if compress_grads else {})
    return st
