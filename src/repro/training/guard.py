"""Host-side anomaly detection + rewind policy for the training loop.

Two detection tiers guard each train step (ISSUE 8):

  * DEVICE tier (``training/step.py``): an all-finite + grad-norm check
    folded into the jitted step. Anomalous steps are SKIPPED on device
    (identity update on params/opt-state/masks under ``lax.cond``) and
    the ``anomaly`` flag rides the metrics dict — zero extra host
    syncs, and the skip is deterministic: a run that hits NaN grads at
    step k is bitwise-identical to a run that never applies step k's
    update.
  * HOST tier (this module): EMA/z-score loss-spike detection. A spike
    has finite gradients, so its update was already applied and cannot
    be skipped after the fact — spikes instead count toward the same
    K-consecutive-anomalies budget as device skips, and hitting K
    triggers an automatic REWIND: restore the newest intact checkpoint
    and replay. The stateless data pipeline (batch = f(seed, step)) and
    in-state RNG make the replay bitwise-exact.

The spike threshold is SCHEDULE-AWARE: right after a scheduled
prune-grow refresh (``core/schedule.py`` cadence) the loss legitimately
jumps — the sparsifier just zeroed whole weight blocks — so for
``refresh_window`` steps after each refresh the z-threshold is widened
by ``refresh_relax`` instead of tripping the guard on the schedule's
own dynamics.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.schedule import steps_since_refresh
from repro.obs.trace import NULL_TRACER


@dataclasses.dataclass
class GuardConfig:
    """Knobs for both guard tiers. ``grad_norm_limit`` is compiled into
    the jitted step (device tier); the rest drive the host tier."""
    enabled: bool = True
    z_threshold: float = 10.0      # spike = z-score above this
    ema_beta: float = 0.9          # EMA decay for loss mean/variance
    warmup_steps: int = 10         # healthy observations before arming
    min_std_frac: float = 0.2      # std floor as a fraction of |mean|
    max_consecutive: int = 3       # K anomalies in a row -> rewind
    max_rewinds: int = 2           # rewind budget, then diverged
    refresh_window: int = 5        # widened steps after a prune refresh
    refresh_relax: float = 4.0     # threshold multiplier in the window
    grad_norm_limit: float | None = None  # device-tier norm anomaly


class AnomalyGuard:
    """Per-run detector state + counters. ``observe`` returns a verdict:

      * ``"ok"``     — healthy step, EMA updated;
      * ``"skip"``   — the device tier already skipped the update;
      * ``"spike"``  — host-tier loss spike (update was applied);
      * ``"rewind"`` — K consecutive anomalies: the loop should restore
        the newest intact checkpoint and replay (or raise
        ``TrainingDivergedError`` if it cannot).
    """

    def __init__(self, cfg: GuardConfig, step_size: int = 0):
        self.cfg = cfg
        self.step_size = int(step_size or 0)
        self._mean: float | None = None
        self._var = 0.0
        self._n = 0                    # healthy observations seen
        self.consecutive = 0
        self.tracer = NULL_TRACER  # obs/trace.py; train_loop installs
        self.counters = {"anomaly_steps": 0, "skipped_steps": 0,
                         "spike_steps": 0, "rewinds": 0,
                         "steps_replayed": 0}

    # -------------------------------------------------------- detection
    def threshold_at(self, step: int) -> float:
        thr = self.cfg.z_threshold
        if (self.step_size
                and steps_since_refresh(step, self.step_size)
                < self.cfg.refresh_window):
            thr *= self.cfg.refresh_relax
        return thr

    def zscore(self, loss: float) -> float:
        """Deviation of ``loss`` from the EMA in floored-std units; 0
        until the detector has a mean."""
        if self._mean is None:
            return 0.0
        std = math.sqrt(max(self._var, 0.0))
        floor = abs(self._mean) * self.cfg.min_std_frac + 1e-8
        return (loss - self._mean) / max(std, floor)

    def observe(self, step: int, loss: float,
                device_anomaly: bool) -> str:
        c = self.cfg
        verdict = "ok"
        if device_anomaly:
            self.counters["skipped_steps"] += 1
            verdict = "skip"
        elif not np.isfinite(loss):
            # host sees a non-finite loss the device tier did not skip
            # (guard compiled out): treat as a spike-tier anomaly
            self.counters["spike_steps"] += 1
            verdict = "spike"
        elif (self._n >= c.warmup_steps
                and self.zscore(loss) > self.threshold_at(step)):
            self.counters["spike_steps"] += 1
            verdict = "spike"

        if verdict != "ok":
            self.counters["anomaly_steps"] += 1
            self.consecutive += 1
            if self.tracer.enabled:
                self.tracer.event("train.anomaly", step=step,
                                  verdict=verdict, loss=loss,
                                  consecutive=self.consecutive)
            if self.consecutive >= c.max_consecutive:
                return "rewind"
            return verdict

        self.consecutive = 0
        if self._mean is None:
            self._mean = float(loss)
        else:
            d = float(loss) - self._mean
            self._mean += (1.0 - c.ema_beta) * d
            self._var = c.ema_beta * (self._var
                                      + (1.0 - c.ema_beta) * d * d)
        self._n += 1
        return "ok"

    # ----------------------------------------------------------- rewind
    def note_rewind(self, from_step: int, to_step: int) -> None:
        """Record a performed rewind and restart the detector — the
        replayed region is judged fresh (the faults that tripped the
        guard were transient; deterministic recurrence exhausts
        ``max_rewinds`` and surfaces as TrainingDivergedError)."""
        self.counters["rewinds"] += 1
        self.counters["steps_replayed"] += max(from_step - to_step, 0)
        self.reset()
        self._mean, self._var, self._n = None, 0.0, 0

    def reset(self) -> None:
        """Clear the consecutive-anomaly streak (rewind unavailable)."""
        self.consecutive = 0
