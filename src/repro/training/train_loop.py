"""Training loop: pjit'd step + BLaST pruning (inside the step) +
checkpoint/restart + preemption handling + straggler watchdog.

Fault tolerance model (DESIGN.md §4):
  * auto-resume from the latest checkpoint in ``ckpt_dir`` at startup;
  * periodic async checkpoints (keep-k, atomic);
  * SIGTERM/SIGINT triggers one final blocking checkpoint, then a clean
    exit — a preempted worker loses at most the in-flight step;
  * the data pipeline is stateless-resumable (batch = f(seed, step));
  * a wall-time watchdog logs steps slower than ``straggler_factor`` x
    the running median (on real multi-pod deployments this feeds the
    controller that re-shards around slow hosts; here it logs).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpointing.checkpoint import Checkpointer
from repro.optim import adamw
from repro.training import step as step_mod


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    log_every: int = 10
    keep: int = 3
    straggler_factor: float = 3.0


def train(cfg, opt_cfg: adamw.AdamWConfig, source, loop: TrainLoopConfig,
          dist=None, state=None, jit_kwargs: dict | None = None,
          log_fn: Callable[[dict], None] | None = None,
          teacher_params=None, teacher_cfg=None, kd_beta: float = 0.0):
    """Returns (final_state, history list of metric dicts)."""
    train_step = step_mod.make_train_step(
        cfg, opt_cfg, dist=dist, kd_beta=kd_beta,
        teacher_cfg=teacher_cfg, teacher_params_static=teacher_params)
    step_fn = jax.jit(train_step, donate_argnums=(0,),
                      **(jit_kwargs or {}))

    if state is None:
        state = step_mod.init_state(cfg, jax.random.PRNGKey(0))

    ckpt = Checkpointer(loop.ckpt_dir, keep=loop.keep) \
        if loop.ckpt_dir else None
    start = 0
    if ckpt and ckpt.latest_step() is not None:
        state = ckpt.restore_state(state)
        start = int(np.asarray(state.step))
        print(f"[resume] restored step {start} from {loop.ckpt_dir}")

    stop = {"flag": False}

    def handler(signum, frame):  # noqa: ARG001
        stop["flag"] = True

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            old_handlers[sig] = signal.signal(sig, handler)
        except ValueError:   # not main thread (tests)
            pass

    history: list[dict] = []
    durations: list[float] = []
    try:
        for i in range(start, loop.total_steps):
            batch = {k: jax.numpy.asarray(v)
                     for k, v in source.batch(i).items()}
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            durations.append(dt)
            med = float(np.median(durations[-50:]))
            if len(durations) > 5 and dt > loop.straggler_factor * med:
                print(f"[straggler] step {i}: {dt:.3f}s "
                      f"(median {med:.3f}s)")
            if i % loop.log_every == 0 or i == loop.total_steps - 1:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m.update(step=i, sec_per_step=dt)
                history.append(m)
                if log_fn:
                    log_fn(m)
                else:
                    print(f"step {i:5d} loss {m['loss']:.4f} "
                          f"sparsity {m['sparsity']:.3f} {dt:.2f}s")
            if ckpt and ((i + 1) % loop.ckpt_every == 0):
                ckpt.save(i + 1, state)
            if stop["flag"]:
                print(f"[preempt] signal at step {i}; checkpointing")
                if ckpt:
                    ckpt.save(i + 1, state, blocking=True)
                break
    finally:
        if ckpt:
            ckpt.wait()
        for sig, h in old_handlers.items():
            signal.signal(sig, h)
    return state, history
