"""Training loop: pjit'd step + BLaST pruning (inside the step) +
anomaly guard + checkpoint/restart + automatic rewind + preemption
handling + straggler watchdog.

Fault tolerance model (DESIGN.md §4, hardened per ISSUE 8):
  * auto-resume from the latest INTACT checkpoint in ``ckpt_dir`` at
    startup (torn/corrupt checkpoints are skipped via the crc32
    manifest);
  * periodic async checkpoints (keep-k, atomic, non-destructive swap);
    a failed background write surfaces on ``wait()``/the next save;
  * every jitted step carries an all-finite + grad-norm check and
    SKIPS anomalous updates on device (``training/step.py``); the host
    runs EMA/z-score loss-spike detection (``training/guard.py``),
    schedule-aware around prune-grow refreshes;
  * K consecutive anomalies trigger an automatic REWIND: restore the
    newest intact checkpoint and replay — bitwise-exact because the
    data pipeline is stateless (batch = f(seed, step)) and the RNG
    lives in the TrainState. A spent rewind budget raises
    ``TrainingDivergedError``;
  * SIGTERM/SIGINT triggers one final blocking checkpoint, then a clean
    exit — a preempted worker loses at most the in-flight step;
  * a wall-time watchdog emits structured straggler events (step,
    duration, running median) through the same log_fn/history channel
    as metrics, plus a ``straggler_steps`` counter (on real multi-pod
    deployments this feeds the controller that re-shards around slow
    hosts).
"""
from __future__ import annotations

import dataclasses
import signal
import sys
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpointing.checkpoint import Checkpointer
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.optim import adamw
from repro.training import step as step_mod
from repro.training.faults import TrainingDivergedError
from repro.training.guard import AnomalyGuard, GuardConfig


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    log_every: int = 10
    keep: int = 3
    straggler_factor: float = 3.0
    guard: GuardConfig | None = dataclasses.field(
        default_factory=GuardConfig)


def train(cfg, opt_cfg: adamw.AdamWConfig, source, loop: TrainLoopConfig,
          dist=None, state=None, jit_kwargs: dict | None = None,
          log_fn: Callable[[dict], None] | None = None,
          teacher_params=None, teacher_cfg=None, kd_beta: float = 0.0,
          faults=None, tracer=None, metrics=None):
    """Returns (final_state, history list of metric dicts).

    ``faults`` is an optional ``training/faults.py`` TrainFaultPlan —
    the chaos-test injection port. History entries are either step
    metrics (every ``log_every`` steps and the final step — the LAST
    entry is always the final step's metrics) or structured events
    (``{"event": "straggler" | "rewind" | ...}``).

    ``tracer`` (obs/trace.py) records ``train.step`` spans at the
    step's EXISTING host sync and routes every structured event
    through the same schema serving uses; the checkpoint/rewind paths
    dump flight-recorder postmortems through it. ``metrics`` injects a
    ``MetricsRegistry`` so a caller can scrape the loop's counters
    (Prometheus/snapshot); by default a private one backs ``counters``
    — either way reset/snapshot derive from the registry, never from a
    hand-kept list."""
    tr = NULL_TRACER if tracer is None else tracer
    reg = metrics if metrics is not None else MetricsRegistry(
        namespace="blast_train")
    gcfg = loop.guard if (loop.guard and loop.guard.enabled) else None
    train_step = step_mod.make_train_step(
        cfg, opt_cfg, dist=dist, kd_beta=kd_beta,
        teacher_cfg=teacher_cfg, teacher_params_static=teacher_params,
        guard=gcfg is not None,
        grad_norm_limit=gcfg.grad_norm_limit if gcfg else None)
    step_fn = jax.jit(train_step, donate_argnums=(0,),
                      **(jit_kwargs or {}))

    if state is None:
        state = step_mod.init_state(cfg, jax.random.PRNGKey(0))

    ckpt = Checkpointer(loop.ckpt_dir, keep=loop.keep) \
        if loop.ckpt_dir else None
    if ckpt is not None:
        ckpt.tracer = tr
        if faults is not None:
            ckpt.fault_hook = faults.on_ckpt_saved
    start = 0
    if ckpt and ckpt.latest_intact_step() is not None:
        state = ckpt.restore_state(state)
        start = int(np.asarray(state.step))
        print(f"[resume] restored step {start} from {loop.ckpt_dir}")

    guard = AnomalyGuard(
        gcfg, step_size=(cfg.blast.step_size if cfg.blast.enabled
                         else 0)) if gcfg else None
    if guard is not None:
        guard.tracer = tr
    for name, help_ in (
            ("straggler_steps", "steps slower than factor x median"),
            ("ckpt_fallbacks", "corrupt/torn checkpoints skipped"),
            ("anomaly_steps", "steps with any anomaly verdict"),
            ("skipped_steps", "device-skipped (non-finite/grad) steps"),
            ("spike_steps", "host loss-spike verdicts"),
            ("rewinds", "automatic checkpoint rewinds"),
            ("steps_replayed", "steps re-run after rewinds")):
        reg.counter(name, help_)
    counters = reg.view()

    stop = {"flag": False}

    def handler(signum, frame):  # noqa: ARG001
        stop["flag"] = True

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            old_handlers[sig] = signal.signal(sig, handler)
        except ValueError:   # not main thread (tests)
            pass

    history: list[dict] = []
    durations: list[float] = []

    def emit(event: dict):
        history.append(event)
        if tr.enabled:
            # one schema for log_fn/history AND the tracer: the span
            # stream carries the same straggler/rewind/anomaly events
            # the structured log does, namespaced under train.*
            tr.event("train." + event["event"],
                     **{k: v for k, v in event.items() if k != "event"})
        if log_fn:
            log_fn(event)
        else:
            print(f"[{event['event']}] {event}")

    try:
        i = start
        while i < loop.total_steps:
            if faults is not None:
                faults.on_host_step(i)
            batch = {k: jax.numpy.asarray(v)
                     for k, v in source.batch(i).items()}
            if faults is not None:
                batch.update({k: jax.numpy.asarray(v) for k, v
                              in faults.step_scalars(i).items()})
            t0 = time.monotonic()
            if faults is not None:
                faults.on_timed_step(i)
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            durations.append(dt)
            med = float(np.median(durations[-50:]))
            if len(durations) > 5 and dt > loop.straggler_factor * med:
                counters["straggler_steps"] += 1
                emit({"event": "straggler", "step": i,
                      "sec_per_step": dt, "median_s": med})

            loss = float(np.asarray(metrics["loss"]))
            device_anomaly = bool(np.asarray(metrics["anomaly"]))
            if tr.enabled:
                # attached at the step's EXISTING host sync (the
                # block_until_ready above) — no extra device round-trip
                tr.span_at("train.step", t0, t0 + dt, step=i,
                           loss=loss, anomaly=device_anomaly)
            if guard is not None:
                verdict = guard.observe(i, loss, device_anomaly)
                counters.update(guard.counters)
                if verdict == "rewind":
                    target = ckpt.latest_intact_step() if ckpt else None
                    if (target is not None
                            and guard.counters["rewinds"]
                            < gcfg.max_rewinds):
                        # freeze the flight recorder FIRST: the rewind
                        # restores older state, so the recent-span ring
                        # is the only record of the anomalous run-up
                        tr.postmortem("train_rewind", step=i,
                                      consecutive=guard.consecutive,
                                      rewinds=guard.counters["rewinds"])
                        state = ckpt.restore_state(state)
                        counters["ckpt_fallbacks"] = ckpt.fallbacks
                        new_i = int(np.asarray(state.step))
                        guard.note_rewind(i, new_i)
                        counters.update(guard.counters)
                        emit({"event": "rewind", "step": i,
                              "to_step": new_i,
                              "consecutive": gcfg.max_consecutive})
                        i = new_i
                        continue
                    if ckpt is not None:
                        tr.postmortem(
                            "training_diverged", step=i,
                            consecutive=guard.consecutive,
                            rewinds=guard.counters["rewinds"])
                        raise TrainingDivergedError(
                            i, guard.consecutive,
                            guard.counters["rewinds"])
                    # no checkpointing: log and push on
                    guard.reset()
                    emit({"event": "rewind_unavailable", "step": i})

            if i % loop.log_every == 0 or i == loop.total_steps - 1:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                if ckpt:
                    counters["ckpt_fallbacks"] = ckpt.fallbacks
                m.update(step=i, sec_per_step=dt, **counters)
                history.append(m)
                if log_fn:
                    log_fn(m)
                else:
                    print(f"step {i:5d} loss {m['loss']:.4f} "
                          f"sparsity {m['sparsity']:.3f} {dt:.2f}s")
            if ckpt and ((i + 1) % loop.ckpt_every == 0):
                ckpt.save(i + 1, state)
            if stop["flag"]:
                print(f"[preempt] signal at step {i}; checkpointing")
                if ckpt:
                    ckpt.save(i + 1, state, blocking=True)
                break
            i += 1
    finally:
        propagating = sys.exc_info()[1] is not None
        if ckpt:
            if propagating:
                try:          # don't mask the in-flight exception
                    ckpt.wait()
                except Exception:
                    pass
            else:
                ckpt.wait()
        for sig, h in old_handlers.items():
            signal.signal(sig, h)
    return state, history
