"""AdamW from scratch (no optax in the container), sparse-aware:

  * global-norm gradient clipping;
  * decoupled weight decay (skipped for 1-D params: norms/biases);
  * BLaST integration — gradients are pre-masked by the caller, and the
    first/second moments of REGROWN blocks are reset to zero (RigL
    semantics; keeps stale momentum from instantly re-inflating blocks
    the sparsifier just zero-initialised).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    end_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_at(c: AdamWConfig, step) -> jax.Array:
    """Linear warmup + cosine decay to end_lr_frac * peak."""
    step = jnp.asarray(step, jnp.float32)
    warm = c.peak_lr * step / max(c.warmup_steps, 1)
    frac = jnp.clip((step - c.warmup_steps)
                    / max(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = c.peak_lr * (c.end_lr_frac + (1 - c.end_lr_frac)
                       * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < c.warmup_steps, warm, cos)


def init(params) -> dict:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def update(c: AdamWConfig, grads, opt_state, params, step):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, c.grad_clip)
    lr = lr_at(c, step)
    t = jnp.asarray(step, jnp.float32) + 1.0
    bc1 = 1.0 - c.b1 ** t
    bc2 = 1.0 - c.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = c.b1 * m + (1 - c.b1) * g
        v = c.b2 * v + (1 - c.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + c.eps)
        if p.ndim >= 2:   # decoupled wd, matrices only
            delta = delta + c.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}


def mask_moments(opt_state, masks: dict, spec):
    """Zero the Adam moments of every PRUNED block (RigL semantics).

    Without this, the moment history of a freshly-pruned block keeps
    pushing its (zeroed) weight off zero at the next update even though
    the masked gradient is zero — found by the train-system invariant
    test. Grown blocks are covered too: they were pruned before, so
    their moments are already zero."""
    from repro.core import sparse_mlp as sm
    from repro.core import topk
    new = opt_state
    for which in ("m", "v"):
        tree = new[which]
        for path, mask in masks.items():
            leaf = sm.get_path(tree, path)
            bi, bo = sm.block_dims_for(spec, path)
            keep = topk.expand_mask(mask, bi, bo).astype(jnp.float32)
            tree = sm.set_path(tree, path, leaf * keep)
        new = dict(new, **{which: tree})
    return new
