"""Gradient compression for the data-parallel all-reduce (beyond-paper
distributed optimization, DESIGN.md §4).

Two composable mechanisms:

  * **int8 error-feedback quantization** — per-leaf scale = max|g|/127;
    the quantization residual is carried in an error-feedback buffer so
    the compression is unbiased over time (SGD-EF). Wire traffic of the
    DP gradient reduction drops 4x (f32) / 2x (bf16).
  * **BLaST-sparse reduction** — gradients of block-sparse weights are
    already masked; with balanced masks the kept blocks are a static
    (1-s) fraction, so the DP reduce moves only packed kept blocks:
    traffic x(1-s) on the MLP gradients (the paper's sparsity becoming a
    COMMUNICATION win, not just compute/memory).

The compressed reduction is expressed with shard_map over the data axes
(psum of the quantized payload), so the dry-run HLO shows the real
collective bytes for the roofline's collective term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.context import shard_map


def quantize_int8(g: jax.Array, err: jax.Array):
    """-> (q int8, scale f32 scalar, new_err). g+err is quantized."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.abs(gf).max() / 127.0, 1e-12)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(grads):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def reduce_leaf_int8(g, e, axes: tuple[str, ...]):
    """One leaf's compressed mean-reduction, for use INSIDE an existing
    shard_map region (manual over ``axes``). int8 payload accumulated in
    int32, scales pmax'd — 4x less wire traffic than f32."""
    q, s, ne = quantize_int8(g, e)
    total = jax.lax.psum(q.astype(jnp.int32), axes)
    smax = jax.lax.pmax(s, axes)
    n = 1
    for a in axes:
        n *= jax.lax.axis_size(a)
    return (total.astype(jnp.float32) * smax / n), ne


def compressed_psum(grads, err, mesh, axes: tuple[str, ...]):
    """All-reduce ``grads`` over the data axes with int8 EF compression.

    Returns (mean_grads f32, new_err). Standalone wrapper (creates its
    own shard_map); inside an existing manual region use
    ``reduce_leaf_int8`` directly."""
    def body(g, e):
        return reduce_leaf_int8(g, e, axes)

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(err)

    def mapped(*leaves):
        gs = leaves[:len(flat_g)]
        es = leaves[len(flat_g):]
        outs = [body(g, e) for g, e in zip(gs, es)]
        return (tuple(o[0] for o in outs), tuple(o[1] for o in outs))

    specs = tuple(P() for _ in flat_g + flat_e)
    out_specs = (tuple(P() for _ in flat_g), tuple(P() for _ in flat_g))
    f = shard_map(mapped, mesh=mesh, in_specs=specs,
                  out_specs=out_specs, check_vma=False)
    red, new_e = f(*flat_g, *flat_e)
    return (tdef.unflatten(list(red)), tdef.unflatten(list(new_e)))


def traffic_report(grads, masks=None, spec=None, sparsity: float = 0.0
                   ) -> dict:
    """Bytes over the DP fabric per step: f32 vs int8 vs int8+sparse."""
    total = sum(g.size for g in jax.tree_util.tree_leaves(grads))
    sparse_frac = 1.0
    if masks:
        from repro.core import sparse_mlp as sm
        sparse_elems = 0
        kept = 0
        for path, m in masks.items():
            g = sm.get_path(grads, path)
            sparse_elems += g.size
            kept += float(m.mean()) * g.size
        sparse_frac = (total - sparse_elems + kept) / total
    return {
        "f32_bytes": 4 * total,
        "int8_bytes": total,
        "int8_sparse_bytes": int(total * sparse_frac),
        "reduction_vs_f32": 4 / sparse_frac,
    }
