"""Sparsity schedule (paper Eq. 2, Zhu & Gupta cubic ramp).

``s_i = s_max + (s_init - s_max) * (1 - i / (m - d))^3``

clamped so that sparsity is ``s_init`` at step 0 and reaches ``s_max`` at
step ``m - d`` (the decay term ``d`` pulls the saturation point earlier,
activating the sparse kernels sooner — paper §5.4.3).
"""
from __future__ import annotations

import jax.numpy as jnp


def sparsity_at(step, *, s_init: float, s_max: float, total_steps: int,
                decay: int = 0):
    """Scheduled sparsity at ``step`` (jit-safe; ``step`` may be traced).

    Returns a float32 scalar in [s_init, s_max].
    """
    horizon = max(int(total_steps) - int(decay), 1)
    frac = jnp.clip(step / horizon, 0.0, 1.0)
    s = s_max + (s_init - s_max) * (1.0 - frac) ** 3
    return jnp.asarray(s, jnp.float32)


def keep_count(sparsity, n_blocks: int, minimum: int = 1):
    """Number of blocks to KEEP at ``sparsity`` out of ``n_blocks``.

    ceil((1 - s) * n), clamped to [minimum, n_blocks]. jit-safe.
    """
    kept = jnp.ceil((1.0 - sparsity) * n_blocks).astype(jnp.int32)
    return jnp.clip(kept, minimum, n_blocks)


def is_refresh_step(step, step_size: int) -> bool:
    """True when the prune-grow mask refresh fires at ``step`` — the
    cadence of ``sparse_mlp.maybe_refresh`` (host-side helper for
    schedule-aware consumers like the training anomaly guard)."""
    return step_size > 0 and int(step) % int(step_size) == 0


def steps_since_refresh(step, step_size: int) -> int:
    """Steps elapsed since the most recent scheduled mask refresh at or
    before ``step`` (0 on a refresh step itself). With no refresh
    cadence (``step_size <= 0``) returns ``step``."""
    if step_size <= 0:
        return int(step)
    return int(step) % int(step_size)
