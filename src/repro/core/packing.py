"""Packed balanced-BCSC representation for serving (DESIGN.md §2).

After training, each sparse weight W (K, N) with a *balanced* block mask
(the same number ``nnz`` of kept blocks in every block-column) is packed
into:

    blocks : (Nb, nnz, b_in, b_out)   kept block values, column-major
    idx    : (Nb, nnz) int32          block-row index of each kept block

which is the static-shape TPU analogue of the paper's BCSC format. The
Pallas kernel and the XLA scan formulation both consume this layout. For
*unbalanced* (global top-k) masks, columns are padded with zero blocks up
to the max per-column count (idx points at block-row 0; the zero values
make the contribution exact).

Pure-jnp, differentiable where it matters (pack is gather; unpack is
scatter) — but serving treats packed weights as constants.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PackedBCSC:
    blocks: jax.Array   # (..., Nb, nnz, b_in, b_out)
    idx: jax.Array      # (..., Nb, nnz) int32
    kb: int             # number of block-rows (STATIC pytree metadata)
    # STATIC pack-time promise: this operand's idx table is identical to
    # its fused-GLU partner's (joint gate/up pruning), so the fused
    # kernel may stream each X tile ONCE for both contractions. Being
    # pytree metadata it survives jit tracing — set it via mark_joint().
    joint: bool = False

    @property
    def nnz(self) -> int:
        return self.idx.shape[-1]

    @property
    def nb(self) -> int:
        return self.idx.shape[-2]

    @property
    def b_in(self) -> int:
        return self.blocks.shape[-2]

    @property
    def b_out(self) -> int:
        return self.blocks.shape[-1]

    def dense_shape(self):
        return (self.kb * self.b_in, self.nb * self.b_out)


jax.tree_util.register_dataclass(
    PackedBCSC, data_fields=["blocks", "idx"], meta_fields=["kb", "joint"])


def mark_joint(p_gate: PackedBCSC, p_up: PackedBCSC
               ) -> tuple[PackedBCSC, PackedBCSC]:
    """Verify (on concrete arrays) that two fused-GLU operands share one
    idx table and, if so, mark both ``joint`` — enabling the single-X
    fast path of ``kernels.fused_glu``. No-op when the structures differ."""
    import numpy as np
    ig, iu = jax.device_get(p_gate.idx), jax.device_get(p_up.idx)
    if ig.shape == iu.shape and bool(np.array_equal(ig, iu)):
        return (dataclasses.replace(p_gate, joint=True),
                dataclasses.replace(p_up, joint=True))
    return p_gate, p_up


def max_nnz_per_col(block_mask: jax.Array) -> int:
    """Static upper bound used to size the pack (requires concrete mask)."""
    counts = jnp.asarray(block_mask).sum(axis=-2)
    return int(counts.max())


def pack(w: jax.Array, block_mask: jax.Array, b_in: int, b_out: int,
         nnz: int | None = None) -> PackedBCSC:
    """Pack masked weight into balanced BCSC.

    w: (K, N); block_mask: (Kb, Nb) bool. ``nnz`` defaults to the max
    per-column count (must be >= it). Leading batch dims are supported
    via vmap by callers; this function handles a single matrix.
    """
    k, n = w.shape
    kb, nb = k // b_in, n // b_out
    assert block_mask.shape == (kb, nb)
    if nnz is None:
        nnz = max_nnz_per_col(block_mask)
    # order rows of each column: kept blocks first (stable), then padding
    keyed = jnp.where(block_mask, 0, 1)                    # kept -> 0
    order = jnp.argsort(keyed, axis=0, stable=True)        # (Kb, Nb)
    sel = order[:nnz].T.astype(jnp.int32)                  # (Nb, nnz)
    valid = jnp.take_along_axis(block_mask.T, sel, axis=1) # (Nb, nnz)
    idx = jnp.where(valid, sel, 0)
    wb = w.reshape(kb, b_in, nb, b_out).transpose(2, 0, 1, 3)  # (Nb,Kb,bi,bo)
    blocks = jnp.take_along_axis(
        wb, idx[:, :, None, None], axis=1)                 # (Nb,nnz,bi,bo)
    blocks = jnp.where(valid[:, :, None, None], blocks, 0.0).astype(w.dtype)
    return PackedBCSC(blocks=blocks, idx=idx, kb=kb)


def unpack(p: PackedBCSC) -> jax.Array:
    """Packed -> dense (K, N). Padding blocks are zero so scatter-add is
    exact even with duplicate idx 0 entries."""
    nb, nnz, b_in, b_out = p.blocks.shape
    dense_blocks = jnp.zeros((nb, p.kb, b_in, b_out), p.blocks.dtype)
    dense_blocks = dense_blocks.at[
        jnp.arange(nb)[:, None], p.idx].add(p.blocks)
    # (Nb, Kb, bi, bo) -> (K, N)
    return dense_blocks.transpose(1, 2, 0, 3).reshape(
        p.kb * b_in, nb * b_out)


def pack_stacked(w: jax.Array, block_mask: jax.Array, b_in: int, b_out: int,
                 nnz: int) -> PackedBCSC:
    """vmap ``pack`` over arbitrary leading dims (layers, experts)."""
    lead = w.shape[:-2]
    if not lead:
        return pack(w, block_mask, b_in, b_out, nnz)
    fn = lambda wi, mi: pack(wi, mi, b_in, b_out, nnz)
    for _ in lead:
        fn = jax.vmap(fn)
    p = fn(w, block_mask)
    return PackedBCSC(blocks=p.blocks, idx=p.idx,
                      kb=w.shape[-2] // b_in)


def pad_nnz(p: PackedBCSC, nnz: int) -> PackedBCSC:
    """Pad per-column block count with zero blocks (idx 0 — exact, the
    zero values contribute nothing). Used to align two operands of the
    fused kernel."""
    cur = p.idx.shape[-1]
    if cur == nnz:
        return p
    assert nnz > cur, (nnz, cur)
    pad_b = [(0, 0)] * (p.blocks.ndim - 3) + [(0, nnz - cur), (0, 0),
                                              (0, 0)]
    pad_i = [(0, 0)] * (p.idx.ndim - 1) + [(0, nnz - cur)]
    # padding edits the idx table, voiding any joint-structure promise
    return PackedBCSC(blocks=jnp.pad(p.blocks, pad_b),
                      idx=jnp.pad(p.idx, pad_i), kb=p.kb)


def pad_fraction(block_mask, nnz: int | None = None) -> float:
    """Fraction of packed block slots that are zero padding under an
    UNBALANCED mask: columns with fewer kept blocks than the max are
    padded up to ``nnz`` (idx 0, zero values). 0.0 for a balanced mask.
    The padding is numerically exact but inflates ``storage_bytes`` /
    ``memory_report`` — export warns on it and the artifact manifest
    records it (serving/artifact.py)."""
    import numpy as np
    m = np.asarray(jax.device_get(block_mask))
    counts = m.sum(axis=-2)
    if nnz is None:
        nnz = int(counts.max())
    total = nnz * counts.size
    return float((total - counts.sum()) / total) if total else 0.0


def structure_violations(p: PackedBCSC, b_in: int | None = None,
                         b_out: int | None = None,
                         dense_shape: tuple | None = None) -> list[str]:
    """Static structural invariants of a PackedBCSC, checked on host
    arrays; returns human-readable violation strings (empty = sound).
    The artifact layer (serving/artifact.py) maps these onto typed
    errors BEFORE a single token is served:

      * shape consistency between ``blocks`` and ``idx`` (and, when
        given, against the registry's expected block dims and dense
        leaf shape);
      * every ``idx`` entry in ``[0, kb)`` — an out-of-range entry
        makes the BSpMM gather garbage blocks silently;
      * per-column duplicate ``idx`` entries may only carry ZERO blocks
        (the zero-padding convention): a duplicate with data would
        double-count that block-row in the contraction.
    """
    import numpy as np
    out: list[str] = []
    blocks = np.asarray(jax.device_get(p.blocks))
    idx = np.asarray(jax.device_get(p.idx))
    if idx.dtype != np.int32:
        out.append(f"idx dtype {idx.dtype}, expected int32")
    if blocks.ndim != idx.ndim + 2 or blocks.shape[:-2] != idx.shape:
        return out + [f"blocks shape {blocks.shape} inconsistent with "
                      f"idx shape {idx.shape}"]
    if b_in is not None and (p.b_in, p.b_out) != (b_in, b_out):
        out.append(f"block dims ({p.b_in}, {p.b_out}) != configured "
                   f"({b_in}, {b_out})")
    if dense_shape is not None:
        got = blocks.shape[:-4] + p.dense_shape()
        if tuple(got) != tuple(dense_shape):
            out.append(f"dense extent {got} != expected "
                       f"{tuple(dense_shape)}")
    if idx.size and (idx.min() < 0 or idx.max() >= p.kb):
        out.append(f"idx out of range [0, {p.kb}): "
                   f"min {int(idx.min())}, max {int(idx.max())}")
        return out       # duplicate analysis is meaningless past this
    nnz = idx.shape[-1]
    cols_i = idx.reshape(-1, nnz)
    cols_b = blocks.reshape(-1, nnz, p.b_in * p.b_out)
    nz = np.any(cols_b != 0, axis=-1)                    # (C, nnz)
    order = np.argsort(cols_i, axis=1, kind="stable")
    si = np.take_along_axis(cols_i, order, axis=1)
    sz = np.take_along_axis(nz, order, axis=1)
    dup = si[:, 1:] == si[:, :-1]
    bad = dup & sz[:, 1:] & sz[:, :-1]
    if bad.any():
        c = int(np.argwhere(bad.any(axis=1))[0, 0])
        out.append(f"duplicate idx entries with nonzero blocks in "
                   f"{int(bad.any(axis=1).sum())} column(s) "
                   f"(first: flat column {c}) — block-rows would be "
                   "double-counted")
    return out


def storage_bytes(p: PackedBCSC) -> int:
    """HBM bytes of the packed representation (paper Fig. 7 analogue)."""
    return (p.blocks.size * p.blocks.dtype.itemsize
            + p.idx.size * p.idx.dtype.itemsize)
