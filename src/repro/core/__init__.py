"""BLaST core: blocked prune-and-grow sparsification (paper §3)."""
from repro.core.prune_grow import BlastSpec, generate_mask, prune_weight
from repro.core.schedule import keep_count, sparsity_at
from repro.core.sparse_mlp import (apply_mask_ste, glu_mlp, init_masks,
                                   mask_grads, maybe_mask, maybe_refresh,
                                   mlp2, refresh_masks, tree_sparsity)
from repro.core.packing import PackedBCSC, pack, pack_stacked, unpack
from repro.core.distill import cross_entropy, distill_loss, kl_to_teacher

__all__ = [
    "BlastSpec", "generate_mask", "prune_weight", "keep_count",
    "sparsity_at", "apply_mask_ste", "glu_mlp", "init_masks", "mask_grads",
    "maybe_mask", "maybe_refresh", "mlp2", "refresh_masks", "tree_sparsity",
    "PackedBCSC", "pack", "pack_stacked", "unpack", "cross_entropy",
    "distill_loss", "kl_to_teacher",
]
