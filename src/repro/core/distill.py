"""Knowledge-distillation loss (paper §5.2): alpha * CE + beta * KL.

Used in the post-training-compression setting: the dense pretrained model
is the teacher, the BLaST-sparsified model is the student. KL is computed
between student and teacher logits (temperature-scaled, standard
Hinton-style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_index: int = -100) -> jax.Array:
    """Mean token CE. logits (..., V) f32-upcast; labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    valid = (labels != ignore_index).astype(jnp.float32)
    return (nll * valid).sum() / jnp.maximum(valid.sum(), 1.0)


def kl_to_teacher(student_logits: jax.Array, teacher_logits: jax.Array,
                  temperature: float = 1.0) -> jax.Array:
    """KL(teacher || student), mean over tokens (paper: L_KL between BLaST
    logits and the dense pretrained model's logits)."""
    t = temperature
    sp = jax.nn.log_softmax(student_logits.astype(jnp.float32) / t, axis=-1)
    tp = jax.nn.log_softmax(teacher_logits.astype(jnp.float32) / t, axis=-1)
    kl = (jnp.exp(tp) * (tp - sp)).sum(axis=-1)
    return (t * t) * kl.mean()


def distill_loss(student_logits, labels, teacher_logits=None, *,
                 alpha: float = 1.0, beta: float = 0.0,
                 temperature: float = 1.0, ignore_index: int = -100):
    """alpha * L_CE + beta * L_KL. With beta=0 (or no teacher) this is the
    plain LM loss used in pretraining."""
    loss = alpha * cross_entropy(student_logits, labels, ignore_index)
    if teacher_logits is not None and beta != 0.0:
        loss = loss + beta * kl_to_teacher(
            student_logits, teacher_logits, temperature)
    return loss
