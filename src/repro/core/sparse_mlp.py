"""Block-sparse MLP forward + mask-tree management (the paper's §3 glue).

Training path ("masked dense", DESIGN.md §2): the forward multiplies the
weight by its expanded block mask. A custom VJP makes the *backward*
return the FULL dense gradient (the paper keeps "the dense weight and
gradient matrices intact" — the dense gradient is what drives the grow
step), while the optimizer applies the mask to updates so pruned blocks
never move (RigL semantics). One backward pass yields both the training
gradient (dense·mask) and the grow-scoring gradient (dense).

Mask trees: model params are nested dicts with stacked layer leading
dims; each model family declares its sparse-weight paths. The helpers
here init/refresh masks for all declared paths, honouring the
``dense_last`` L layers (paper §5.4.4) via per-layer dense flags.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import topk
from repro.core.prune_grow import BlastSpec, generate_mask, prune_weight

Params = dict
MaskTree = dict  # path_str -> bool block mask, stacked like the weight


# ---------------------------------------------------------------- STE mask
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def apply_mask_ste(w: jax.Array, block_mask: jax.Array,
                   b_in: int, b_out: int) -> jax.Array:
    """w * expand(mask); backward passes the dense (unmasked) gradient."""
    return topk.apply_block_mask(w, block_mask, b_in, b_out)


def _ste_fwd(w, block_mask, b_in, b_out):
    return topk.apply_block_mask(w, block_mask, b_in, b_out), block_mask


def _ste_bwd(b_in, b_out, block_mask, g):
    # dense gradient to the weight; mask is boolean (no cotangent)
    return g, jnp.zeros_like(block_mask)


apply_mask_ste.defvjp(_ste_fwd, _ste_bwd)


# Convention: BlastSpec.b_in tiles the d_model side, b_out tiles the
# d_ff side, for EVERY matrix. Up-projections (D, F) use (b_in, b_out);
# down-projections (F, D) use the swapped (b_out, b_in). A weight's
# orientation is derived from its leaf name.
_SWAPPED_LEAVES = ("w_down", "w_out", "ws_down")


def block_dims_for(spec: BlastSpec, path: str) -> tuple[int, int]:
    leaf = path.split("/")[-1]
    if leaf in _SWAPPED_LEAVES:
        return spec.b_out, spec.b_in
    return spec.b_in, spec.b_out


def maybe_mask(w: jax.Array, mask: jax.Array | None,
               spec: BlastSpec | None, swapped: bool = False) -> jax.Array:
    if mask is None or spec is None or not spec.enabled:
        return w
    bi, bo = (spec.b_out, spec.b_in) if swapped else (spec.b_in, spec.b_out)
    return apply_mask_ste(w, mask, bi, bo)


# ------------------------------------------------------------ MLP forwards
def act_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    return {
        "silu": jax.nn.silu,
        "gelu": functools.partial(jax.nn.gelu, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def _is_packed(w) -> bool:
    from repro.core.packing import PackedBCSC
    return isinstance(w, PackedBCSC)


def glu_mlp(x, w_gate, w_up, w_down, *, act="silu",
            masks=None, spec: BlastSpec | None = None):
    """Gated MLP: (act(x W_g) * (x W_u)) W_d — paper Eq. (1) for silu.

    masks: optional dict with keys 'w_gate','w_up','w_down'. Weights may
    be ``PackedBCSC`` (serving): dispatches to the fused BSpMM path."""
    if _is_packed(w_gate):
        from repro.kernels import ops
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y = ops.sparse_mlp_apply(x2, w_gate, w_up, w_down, act=act)
        return y.reshape(*lead, y.shape[-1])
    m = masks or {}
    dt = x.dtype
    wg = maybe_mask(w_gate, m.get("w_gate"), spec).astype(dt)
    wu = maybe_mask(w_up, m.get("w_up"), spec).astype(dt)
    wd = maybe_mask(w_down, m.get("w_down"), spec, swapped=True).astype(dt)
    h = act_fn(act)(x @ wg) * (x @ wu)
    return h @ wd


def mlp2(x, w_in, w_out, b_in_=None, b_out_=None, *, act="gelu",
         masks=None, spec: BlastSpec | None = None, square: bool = False):
    """Two-matrix MLP (GPT-2 / ViT / whisper): act(x W1 + b1) W2 + b2.

    ``square``: rwkv6 channel-mix squares the activation."""
    if _is_packed(w_in):
        from repro.kernels import ops
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        h = ops.bspmm(x2, w_in)
        if b_in_ is not None:
            h = h + b_in_.astype(h.dtype)
        h = act_fn(act)(h)
        if square:
            h = h * h
        y = ops.bspmm(h, w_out)
        if b_out_ is not None:
            y = y + b_out_.astype(y.dtype)
        return y.reshape(*lead, y.shape[-1])
    m = masks or {}
    dt = x.dtype
    w1 = maybe_mask(w_in, m.get("w_in"), spec).astype(dt)
    w2 = maybe_mask(w_out, m.get("w_out"), spec, swapped=True).astype(dt)
    h = x @ w1
    if b_in_ is not None:
        h = h + b_in_.astype(dt)
    h = act_fn(act)(h)
    if square:
        h = h * h
    y = h @ w2
    if b_out_ is not None:
        y = y + b_out_.astype(dt)
    return y


# ------------------------------------------------------- mask-tree helpers
def get_path(tree: Params, path: str):
    node = tree
    for k in path.split("/"):
        node = node[k]
    return node


def set_path(tree: Params, path: str, value) -> Params:
    """Functional set (copies dicts along the path)."""
    keys = path.split("/")
    def rec(node, i):
        node = dict(node)
        if i == len(keys) - 1:
            node[keys[i]] = value
        else:
            node[keys[i]] = rec(node[keys[i]], i + 1)
        return node
    return rec(tree, 0)


def _dense_flag_mask(new_mask: jax.Array, dense_flags, path: str = ""):
    """Force all-kept mask on layers whose dense flag is set.

    new_mask: (L, ..., Kb, Nb); dense_flags: (L,) bool, or a dict keyed
    by stack prefix (whisper has encoder/decoder stacks of different
    depth), or None."""
    if isinstance(dense_flags, dict):
        dense_flags = dense_flags.get(path.split("/")[0])
    if dense_flags is None:
        return new_mask
    shape = (-1,) + (1,) * (new_mask.ndim - 1)
    return jnp.where(dense_flags.reshape(shape), True, new_mask)


def init_masks(spec: BlastSpec, params: Params, sparse_paths: list[str],
               dense_flags: jax.Array | None = None) -> MaskTree:
    """All-kept initial masks (s_init=0) for every declared sparse weight."""
    masks: MaskTree = {}
    for path in sparse_paths:
        w = get_path(params, path)
        bi, bo = block_dims_for(spec, path)
        kb, nb = w.shape[-2] // bi, w.shape[-1] // bo
        masks[path] = jnp.ones(w.shape[:-2] + (kb, nb), bool)
    return masks


def refresh_masks(spec: BlastSpec, params: Params, dense_grads: Params,
                  masks: MaskTree, step,
                  dense_flags: jax.Array | None = None
                  ) -> tuple[MaskTree, Params, MaskTree]:
    """generate_masks() + prune_weights() of paper Listing 1 over the whole
    mask tree. Returns (new_masks, pruned_params, grown_masks).

    ``dense_grads`` is the full (unmasked) gradient pytree from the STE
    backward. Stacked leading dims (layers, experts) are vmapped."""
    import dataclasses as _dc
    new_masks: MaskTree = {}
    grown: MaskTree = {}
    new_params = params
    for path, old in masks.items():
        w = get_path(params, path)
        g = get_path(dense_grads, path)
        bi, bo = block_dims_for(spec, path)
        pspec = _dc.replace(spec, b_in=bi, b_out=bo)
        gen = lambda wi, gi: generate_mask(pspec, wi, gi, step)
        for _ in range(w.ndim - 2):
            gen = jax.vmap(gen)
        nm = _dense_flag_mask(gen(w, g), dense_flags, path)
        gr = nm & ~old
        w_new = prune_weight(pspec, w, nm)
        w_new = jnp.where(
            topk.expand_mask(gr, bi, bo), 0.0, w_new).astype(w.dtype)
        new_masks[path] = nm
        grown[path] = gr
        new_params = set_path(new_params, path, w_new)
    return new_masks, new_params, grown


def maybe_refresh(spec: BlastSpec, params, dense_grads, masks, step,
                  dense_flags=None):
    """Refresh every ``spec.step_size`` steps, inside jit via lax.cond.

    Returns (masks, params, grown_or_zeros)."""
    if not spec.enabled:
        zeros = {p: jnp.zeros_like(m) for p, m in masks.items()}
        return masks, params, zeros

    def do(_):
        return refresh_masks(spec, params, dense_grads, masks, step,
                             dense_flags)

    def skip(_):
        zeros = {p: jnp.zeros_like(m) for p, m in masks.items()}
        return masks, params, zeros

    return jax.lax.cond(step % spec.step_size == 0, do, skip, operand=None)


def mask_grads(masks: MaskTree, grads: Params, spec: BlastSpec) -> Params:
    """Apply masks to the dense gradients before the optimizer step."""
    out = grads
    for path, m in masks.items():
        g = get_path(grads, path)
        bi, bo = block_dims_for(spec, path)
        out = set_path(out, path, topk.apply_block_mask(g, m, bi, bo))
    return out


def tree_sparsity(masks: MaskTree) -> jax.Array:
    """Overall fraction of pruned blocks across the mask tree."""
    tot = sum(m.size for m in masks.values())
    kept = sum(m.sum() for m in masks.values())
    return 1.0 - kept / tot
