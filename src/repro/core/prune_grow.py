"""Blocked prune-and-grow (paper §3.2, Fig. 2), fully jit-safe.

Per sparse weight matrix W (with gradient G), at every mask refresh:

  1. score = Frobenius norm per (b_in, b_out) block of W and of G;
  2. keep the top ``kept - grow`` blocks by |W| (the pruning function S);
  3. *grow* ``grow`` blocks by |G| that are not already kept (RigL-style
     difference step — the red blocks in paper Fig. 2);
  4. newly grown blocks are zero-initialised (their weights were pruned
     to zero earlier and the mask only re-enables their training), and
     their optimizer moments are reset.

The paper's variant regrows the *set difference* S(G) \\ S(W) on top of
S(W) (transiently exceeding the budget); we use the fixed-budget RigL
formulation so the kept-count exactly tracks the schedule — DESIGN.md §8
records this deviation. ``grow_frac`` cosine-decays as in RigL.

Everything here operates on one weight leaf; `sparse_mlp.py` maps it over
the model's sparse-weight pytree.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import topk
from repro.core.schedule import keep_count, sparsity_at


@dataclasses.dataclass(frozen=True)
class BlastSpec:
    """Static sparsification hyper-parameters for one model (paper Table 2)."""
    enabled: bool = True
    b_in: int = 128            # block rows (K / d_model side)
    b_out: int = 128           # block cols (N / d_ff side) == paper's b
    s_init: float = 0.0
    s_max: float = 0.8
    step_size: int = 100       # mask refresh interval (paper §5.4.2)
    decay: int = 0             # d in Eq. 2 (paper §5.4.3)
    total_steps: int = 10_000  # m in Eq. 2
    dense_last: int = 2        # L rightmost MLP blocks stay dense (§5.4.4)
    selection: Literal["balanced", "global"] = "balanced"
    grow_frac: float = 0.3     # fraction of kept budget regrown by |G|
    grow_frac_end: float = 0.0 # cosine-decayed to this by total_steps

    def block_grid(self, k: int, n: int) -> tuple[int, int]:
        assert k % self.b_in == 0 and n % self.b_out == 0, (
            f"weight {(k, n)} not tiled by block ({self.b_in},{self.b_out})")
        return k // self.b_in, n // self.b_out


def grow_count(spec: BlastSpec, step, kept):
    """Number of blocks regrown by gradient at this refresh (cosine decay)."""
    frac = jnp.clip(step / max(spec.total_steps, 1), 0.0, 1.0)
    g = spec.grow_frac_end + 0.5 * (spec.grow_frac - spec.grow_frac_end) * (
        1.0 + jnp.cos(jnp.pi * frac))
    # never grow more than kept-1 (at least one block chosen by |W|)
    return jnp.minimum((g * kept).astype(jnp.int32),
                       jnp.maximum(kept - 1, 0))


def _select(spec: BlastSpec, scores: jax.Array, k) -> jax.Array:
    if spec.selection == "balanced":
        return topk.topk_mask_per_col(scores, k)
    return topk.topk_mask_global(scores, k * scores.shape[-1])


def generate_mask(spec: BlastSpec, w: jax.Array, g: jax.Array,
                  step) -> jax.Array:
    """One prune-and-grow mask refresh for one weight. Returns bool block
    mask of shape (..., Kb, Nb).

    ``step`` may be traced. For ``balanced`` selection the keep/grow
    budgets are per block-column; for ``global`` they are scaled by Nb.
    """
    wn = topk.block_norms(w, spec.b_in, spec.b_out)
    gn = topk.block_norms(g, spec.b_in, spec.b_out)
    kb = wn.shape[-2]
    s = sparsity_at(step, s_init=spec.s_init, s_max=spec.s_max,
                    total_steps=spec.total_steps, decay=spec.decay)
    kept = keep_count(s, kb)                       # per-column budget
    grow = grow_count(spec, step, kept)

    keep_mask = _select(spec, wn, kept - grow)
    # difference step: gradient-selected blocks not already kept
    gn_masked = jnp.where(keep_mask, -jnp.inf, gn)
    grow_mask = _select(spec, gn_masked, grow)
    return keep_mask | grow_mask


def prune_weight(spec: BlastSpec, w: jax.Array,
                 block_mask: jax.Array) -> jax.Array:
    """prune_weights() of Listing 1: zero out pruned blocks."""
    return topk.apply_block_mask(w, block_mask, spec.b_in, spec.b_out)


def refresh_mask_and_weight(spec: BlastSpec, w, g, old_mask, step):
    """Full refresh: new mask, pruned weight, and the set of newly-grown
    blocks (for optimizer moment reset). Regrown weights are zeroed —
    they were already zero (pruned) but we enforce it (paper: 'initially
    set to zero')."""
    new_mask = generate_mask(spec, w, g, step)
    grown = new_mask & ~old_mask
    w_new = prune_weight(spec, w, new_mask)
    # enforce zero-init of regrown blocks
    w_new = jnp.where(
        topk.expand_mask(grown, spec.b_in, spec.b_out), 0.0, w_new
    ).astype(w.dtype)
    return new_mask, w_new, grown


def initial_mask(spec: BlastSpec, w: jax.Array) -> jax.Array:
    """All-ones mask at s_init=0 (or scheduled-at-0 sparsity by |W|)."""
    kb, nb = (w.shape[-2] // spec.b_in, w.shape[-1] // spec.b_out)
    lead = w.shape[:-2]
    if spec.s_init <= 0.0:
        return jnp.ones(lead + (kb, nb), bool)
    wn = topk.block_norms(w, spec.b_in, spec.b_out)
    kept = keep_count(jnp.float32(spec.s_init), kb)
    return _select(spec, wn, kept)
