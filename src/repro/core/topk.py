"""Block-granularity scoring and top-k mask selection (paper §3.2, S()).

A weight matrix ``W`` of shape (K, N) is viewed as a grid of
``(K/b_in) x (N/b_out)`` blocks. ``S()`` scores each block by its
Frobenius norm and keeps the top blocks at the scheduled sparsity.

Two selection modes:
  * ``global``   — paper-faithful: top-k over the whole block grid.
  * ``balanced`` — TPU adaptation: top-k *per block-column*, so every
    block-column keeps the same number of blocks. This makes the packed
    BCSC representation static-shaped and perfectly load-balanced across
    TP shards (DESIGN.md §2).

All functions are jit-safe with *dynamic* keep counts (rank-threshold
trick: rank = argsort(argsort(-scores)); mask = rank < k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_norms(w: jax.Array, b_in: int, b_out: int) -> jax.Array:
    """Frobenius norm of each (b_in, b_out) block.

    w: (..., K, N) -> (..., K//b_in, N//b_out)  float32.
    Leading dims (e.g. experts) are preserved.
    """
    *lead, k, n = w.shape
    assert k % b_in == 0 and n % b_out == 0, (
        f"block ({b_in},{b_out}) does not tile weight {(k, n)}")
    kb, nb = k // b_in, n // b_out
    w2 = (w.astype(jnp.float32) ** 2).reshape(*lead, kb, b_in, nb, b_out)
    return jnp.sqrt(w2.sum(axis=(-3, -1)))


def _ranks_desc(s: jax.Array) -> jax.Array:
    """rank[i] = position of s[i] in a descending sort of the last axis.

    Deterministic (stable ties by index)."""
    order = jnp.argsort(-s, axis=-1, stable=True)
    return jnp.argsort(order, axis=-1)


def topk_mask_global(scores: jax.Array, k) -> jax.Array:
    """Bool mask keeping the ``k`` largest entries over the last TWO axes
    (the block grid); leading dims (e.g. experts) select independently.

    ``k`` may be a traced int32 scalar (dynamic)."""
    *lead, kb, nb = scores.shape
    ranks = _ranks_desc(scores.reshape(*lead, kb * nb))
    return (ranks < k).reshape(scores.shape)


def topk_mask_per_col(scores: jax.Array, k) -> jax.Array:
    """Bool mask keeping the ``k`` largest entries of every block-column.

    scores: (..., Kb, Nb); selection over the Kb axis independently per
    column. ``k`` may be traced."""
    s = jnp.swapaxes(scores, -2, -1)       # (..., Nb, Kb)
    mask = _ranks_desc(s) < k
    return jnp.swapaxes(mask, -1, -2)


def expand_mask(block_mask: jax.Array, b_in: int, b_out: int) -> jax.Array:
    """(..., Kb, Nb) bool -> (..., Kb*b_in, Nb*b_out) elementwise mask."""
    m = jnp.repeat(block_mask, b_in, axis=-2)
    return jnp.repeat(m, b_out, axis=-1)


def apply_block_mask(w: jax.Array, block_mask: jax.Array,
                     b_in: int, b_out: int) -> jax.Array:
    """Zero out pruned blocks of ``w`` (mask may have leading dims)."""
    return w * expand_mask(block_mask, b_in, b_out).astype(w.dtype)


def mask_sparsity(block_mask: jax.Array) -> jax.Array:
    """Fraction of pruned blocks (float32 scalar)."""
    return 1.0 - block_mask.astype(jnp.float32).mean()
