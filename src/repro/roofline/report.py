"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the per-cell
dry-run JSONs.

    PYTHONPATH=src python -m repro.roofline.report results/dryrun
"""
from __future__ import annotations

import json
import os
import sys


def load(out_dir: str) -> list[dict]:
    rows = []
    for name in sorted(os.listdir(out_dir)):
        if name.endswith(".json"):
            with open(os.path.join(out_dir, name)) as f:
                rows.append(json.load(f))
    return rows


def fmt_bytes(b) -> str:
    b = float(b or 0)
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PiB"


def dryrun_table(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | kind | compile_s | args/dev | "
           "temp/dev | HLO GFLOP/dev | coll MB/dev | #coll |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        mem = r.get("memory", {})
        coll = r["collectives"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} | "
            f"{r['compile_s']} | "
            f"{fmt_bytes(mem.get('argument_size_in_bytes'))} | "
            f"{fmt_bytes(mem.get('temp_size_in_bytes'))} | "
            f"{r['flops_per_device'] / 1e9:.1f} | "
            f"{coll['total_bytes'] / 1e6:.1f} | "
            f"{sum(coll['count'].values())} |")
    return "\n".join(out)


def roofline_table(rows: list[dict], mesh: str = "16x16") -> str:
    out = ["| arch | shape | compute_s | memory_s | collective_s | "
           "dominant | MODEL_TF | useful_ratio | MFU_bound |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4f} | "
            f"{rl['memory_s']:.4f} | {rl['collective_s']:.4f} | "
            f"**{rl['dominant'].replace('_s','')}** | "
            f"{rl.get('model_flops', 0) / 1e12:.1f} | "
            f"{rl.get('useful_flops_ratio', 0):.3f} | "
            f"{rl.get('mfu_upper_bound', 0):.3f} |")
    return "\n".join(out)


def pick_hillclimb(rows: list[dict]) -> list[tuple[str, str, str]]:
    """worst MFU bound / most collective-bound / most paper-representative."""
    single = [r for r in rows if r["mesh"] == "16x16"
              and r["kind"] == "train"]
    worst = min(single, key=lambda r: r["roofline"].get(
        "mfu_upper_bound", 1))
    collb = max(rows, key=lambda r: (
        r["roofline"]["collective_s"]
        / max(r["roofline"]["step_time_lower_bound_s"], 1e-12)
        if r["mesh"] == "16x16" else 0))
    return [(worst["arch"], worst["shape"], "worst MFU bound"),
            (collb["arch"], collb["shape"], "most collective-bound"),
            ("qwen2-7b", "decode_32k",
             "paper-representative: sparse-MLP-dominated decode")]


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    rows = load(out_dir)
    print(f"## Dry-run ({len(rows)} cells)\n")
    print(dryrun_table(rows))
    print("\n## Roofline (single-pod 16x16, per-device terms)\n")
    print(roofline_table(rows, "16x16"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(rows, "2x16x16"))
    print("\n## Hillclimb picks\n")
    for arch, shape, why in pick_hillclimb(rows):
        print(f"* {arch} x {shape} — {why}")


if __name__ == "__main__":
    main()
