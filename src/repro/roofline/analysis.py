"""Roofline term extraction from the compiled dry-run artifact
(ROOFLINE ANALYSIS section of the task).

  compute_s    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory_s     = HLO_bytes_per_device / HBM_BW
  collective_s = collective_bytes_per_device / LINK_BW

cost_analysis() provides flops/bytes (per-device SPMD module);
collective bytes are parsed from the compiled HLO text — we sum the
RESULT-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (a per-device proxy of link traffic).
Hardware constants: TPU v5e-like (197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI).
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e5m2": 1, "f8e4m3fn": 1,
}

_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")

# e.g.  %all-gather.5 = bf16[16,4096,512]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes per collective kind. '-done' ops are skipped
    (the '-start' carries the shape; avoids double counting)."""
    out = {k: 0 for k in _COLL}
    count = {k: 0 for k in _COLL}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.groups()
        out[kind] += _shape_bytes(dtype, dims)
        count[kind] += 1
    return {"bytes": out, "count": count,
            "total_bytes": sum(out.values())}


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float, chips: int,
                   model_flops: float | None = None) -> dict:
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_bytes / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    out = dict(terms, dominant=dom, chips=chips,
               step_time_lower_bound_s=bound)
    if model_flops:
        out["model_flops"] = model_flops
        out["hlo_flops_global"] = flops * chips
        out["useful_flops_ratio"] = model_flops / max(flops * chips, 1.0)
        out["mfu_upper_bound"] = (model_flops / chips / PEAK_FLOPS
                                  / max(bound, 1e-12))
    return out


def analyze_compiled(compiled, chips: int, model_flops=None) -> dict:
    """Loop-weighted HLO cost (hlo_cost.py) is the primary source —
    XLA's cost_analysis() counts while-loop bodies once and under-counts
    scanned models by the trip count (EXPERIMENTS.md §Perf notes). The
    raw XLA numbers are kept for reference."""
    from repro.roofline import hlo_cost
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    text = compiled.as_text()
    weighted = hlo_cost.analyze_text(text)
    flops = float(weighted["flops"])
    bts = float(weighted["bytes_accessed"])
    coll = {"bytes": weighted["collectives"]["bytes"],
            "count": weighted["collectives"]["count"],
            "total_bytes": float(weighted["collective_bytes"])}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            mem[k] = getattr(ma, k, None)
    except Exception:
        pass
    return {
        "flops_per_device": flops,
        "bytes_per_device": bts,
        "collectives": coll,
        "xla_raw": {"flops": float(cost.get("flops", 0.0)),
                    "bytes_accessed": float(cost.get("bytes accessed",
                                                     0.0))},
        "loop_weights": weighted["weights_nontrivial"],
        "memory": mem,
        "roofline": roofline_terms(flops, bts, coll["total_bytes"],
                                   chips, model_flops),
    }
