"""Loop-weighted HLO cost model (the §Roofline measurement backbone).

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — for a
scan-over-layers model that under-counts FLOPs/bytes/collective-bytes by
the trip count (94x for qwen3!; verified empirically, see EXPERIMENTS.md
§Perf notes). This module re-derives the three roofline inputs from the
optimized HLO text with per-computation execution weights:

  * computations are segmented from the text; ``while`` ops link their
    body/condition; the trip count is read from the loop condition's
    comparison constant;
  * weight(ENTRY)=1; weight(while body) += weight(caller) x trips;
    ``conditional`` branches inherit the caller weight (both branches
    counted — the prune-refresh branch is cheap sorts, noted);
  * FLOPs: dot ops contribute 2 x |result| x |contracting dims|
    (elementwise flops are ignored — matmuls dominate; convolutions are
    not used by these models);
  * bytes: every op in a weighted computation contributes result +
    operand bytes, EXCEPT no-traffic ops (parameter/constant/tuple/gte/
    bitcast) and fusion-internal ops (a fusion's interior values never
    touch HBM — only the fusion call site's operands/result count, which
    is MORE faithful to real traffic than XLA's own metric);
  * collectives: result bytes of all-gather/all-reduce/reduce-scatter/
    all-to-all/collective-permute, loop-weighted.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8,
    "u64": 8, "f64": 8, "c64": 8, "c128": 16, "f8e5m2": 1,
    "f8e4m3fn": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+|[\w\.\-]+)\s*=\s*(.+)$")
_OPCODE_RE = re.compile(r"^\(?\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]"
                        r"(?:\{[0-9,]*\})?)\s*\)?\s*([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s*(?:\(.*)?\{")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=(%?[\w\.\-]+).*?body=(%?[\w\.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=(%?[\w\.\-]+)")
_COND_BRANCHES_RE = re.compile(
    r"conditional\(.*?\).*?branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_FUSION_RE = re.compile(r"fusion\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")
_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "iota"}


def _shape_bytes(text: str) -> int:
    """Sum of all array shapes in a type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _result_type(rhs: str) -> str:
    """Type portion of an op definition rhs (before the opcode)."""
    m = re.match(r"^\(?((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]"
                 r"(?:\{[0-9,]*\})?)(?:,\s*[a-z0-9]+\[[0-9,]*\]"
                 r"(?:\{[0-9,]*\})?)*)\)?\s*[\w\-]+\(", rhs)
    return m.group(1) if m else rhs.split(" ")[0]


@dataclass
class Op:
    name: str
    opcode: str
    rhs: str
    result_bytes: int
    result_dims: list[int]
    dtype_bytes: int


@dataclass
class Computation:
    name: str
    ops: list[Op] = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # op name -> bytes
    is_fusion_interior: bool = False


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = None
    for line in text.splitlines():
        if cur is None:
            stripped = line.rstrip()
            if not stripped.endswith("{"):
                continue
            if " -> " not in stripped and not stripped.startswith("ENTRY"):
                continue   # metadata blocks (FileLocations etc.)
            m = _COMP_HDR_RE.match(line)
            if m:
                name = m.group(1).lstrip("%")
                cur = Computation(name=name)
                if line.startswith("ENTRY"):
                    entry_name = name
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1).lstrip("%"), m.group(2)
        om = _OPCODE_RE.match(rhs)
        opcode = om.group(1) if om else rhs.split("(")[0].split()[-1]
        rtype = _result_type(rhs)
        rb = _shape_bytes(rtype)
        sm_ = _SHAPE_RE.search(rtype)
        dims = [int(d) for d in sm_.group(2).split(",") if d] if sm_ else []
        dtb = _DTYPE_BYTES.get(sm_.group(1), 4) if sm_ else 4
        cur.ops.append(Op(name, opcode, rhs, rb, dims, dtb))
        cur.shapes[name] = rb
    if cur is not None:
        comps[cur.name] = cur
    comps["__entry__"] = comps.get(entry_name, Computation("none"))
    return comps


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition (the bound)."""
    best = 1
    for op in cond.ops:
        for c in _CONST_RE.findall(op.rhs):
            best = max(best, int(c))
    return best


def computation_weights(comps: dict[str, Computation]) -> dict[str, float]:
    entry = comps["__entry__"].name
    weights = {name: 0.0 for name in comps}
    weights[entry] = 1.0
    # iterate to fixpoint (nesting depth is small)
    for _ in range(12):
        new = {name: 0.0 for name in comps}
        new[entry] = 1.0
        for cname, comp in comps.items():
            if cname == "__entry__":
                continue
            w = weights.get(cname, 0.0)
            if w == 0.0:
                continue
            for op in comp.ops:
                wm = _WHILE_RE.search(op.rhs)
                if wm:
                    cond = wm.group(1).lstrip("%")
                    body = wm.group(2).lstrip("%")
                    trips = _trip_count(comps[cond]) if cond in comps \
                        else 1
                    new[body] = new.get(body, 0.0) + w * trips
                    new[cond] = new.get(cond, 0.0) + w * (trips + 1)
                    continue
                bm = _COND_BRANCHES_RE.search(op.rhs)
                if bm:
                    for b in bm.group(1).split(","):
                        b = b.strip().lstrip("%")
                        if b in comps:
                            new[b] = new.get(b, 0.0) + w
                    continue
                if op.opcode in ("call", "fusion"):
                    # fusion targets inherit weight so interior DOTs are
                    # flop-counted; byte accounting still treats their
                    # interiors as HBM-free (is_fusion_interior).
                    cm = _CALL_RE.search(op.rhs)
                    if cm:
                        t = cm.group(1).lstrip("%")
                        if t in comps:
                            new[t] = new.get(t, 0.0) + w
        if all(abs(new[k] - weights.get(k, 0.0)) < 1e-9 for k in new):
            weights = new
            break
        weights = new
    return weights


def _mark_fusion_interiors(comps: dict[str, Computation]):
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                cm = _CALL_RE.search(op.rhs)
                if cm:
                    t = cm.group(1).lstrip("%")
                    if t in comps:
                        comps[t].is_fusion_interior = True


_OPERAND_RE = re.compile(r"\(([^)]*)\)")
_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")


_TRANSPARENT = ("convert", "bitcast", "reshape", "copy", "transpose")


def _fusion_effective_bytes(fusion_op: Op, target: Computation) -> int:
    """Physical HBM traffic of one fusion execution.

    Interior values never hit HBM; inputs consumed ONLY through
    slice/gather ops charge the slice result (a scan body fused with its
    per-trip dynamic-slice reads one step's slice, not the whole stacked
    array); outputs written through a root dynamic-update-slice charge
    the update region (in-place carry update), not the full buffer.

    Convert/bitcast/reshape chains are TRANSPARENT: the XLA CPU
    backend's float-normalization pass wraps bf16 loop carries in
    full-tensor f32 round-trips that a TPU build never materialises
    (found on the qwen2 decode cell — EXPERIMENTS.md §Perf P3)."""
    ops_by_name = {op.name: op for op in target.ops}
    consumers: dict[str, list[Op]] = {}
    params: dict[str, Op] = {}
    for op in target.ops:
        if op.opcode == "parameter":
            params[op.name] = op
        for o in _operand_names(op.rhs):
            consumers.setdefault(o, []).append(op)

    def resolve_consumers(name: str, depth=0) -> list:
        """Effective (orig_name, consumer) pairs, skipping through
        transparent ops."""
        out = []
        if depth > 8:
            return out
        for c in consumers.get(name, []):
            if c.opcode in _TRANSPARENT:
                nxt = resolve_consumers(c.name, depth + 1)
                out.extend((name, cc) for _, cc in nxt) if nxt else \
                    out.append((name, c))
            else:
                out.append((name, c))
        return out

    def _resolve_back(name: str) -> str:
        """Follow transparent defs backwards to the producer name."""
        seen = set()
        while name in ops_by_name and \
                ops_by_name[name].opcode in _TRANSPARENT and \
                name not in seen:
            seen.add(name)
            srcs = _operand_names(ops_by_name[name].rhs)
            if not srcs:
                break
            name = srcs[0]
        return name

    def _windowed_read(p: str, c: Op):
        if c.opcode in ("dynamic-slice", "slice", "gather"):
            return c.result_bytes
        if c.opcode == "dynamic-update-slice":
            ops_ = _operand_names(c.rhs)
            if ops_ and _resolve_back(ops_[0]) == p:
                return 0          # in-place destination: no read
            return None           # update operand: full (small) read
        return None

    eff_in = 0
    for pname, pop in params.items():
        cons = resolve_consumers(pname)
        if not cons:
            continue
        reads = [_windowed_read(orig, c) for orig, c in cons]
        if all(r is not None for r in reads):
            eff_in += sum(reads)
        else:
            eff_in += pop.result_bytes

    def _out_bytes_for(name: str) -> int:
        defop = ops_by_name.get(_resolve_back(name))
        if defop is None:
            return target.shapes.get(name, 0)
        if defop.opcode == "dynamic-update-slice":
            oo = _operand_names(defop.rhs)
            return 2 * (target.shapes.get(oo[1], 0) if len(oo) > 1
                        else 0)
        if defop.opcode == "parameter":
            return 0              # pass-through output: no new write
        return defop.result_bytes

    root = target.ops[-1] if target.ops else None
    if root is None:
        eff_out = fusion_op.result_bytes
    elif root.opcode == "tuple":
        eff_out = sum(_out_bytes_for(o)
                      for o in _operand_names(root.rhs))
    else:
        eff_out = _out_bytes_for(root.name)
    return eff_in + eff_out


def _operand_names(rhs: str) -> list[str]:
    m = _OPERAND_RE.search(rhs[rhs.index("("):] if "(" in rhs else rhs)
    if not m:
        return []
    out = []
    for tok in m.group(1).split(","):
        # newer XLA prints typed operands: "f32[64,32]{1,0} %Arg_0.1"
        tok = tok.strip().split()[-1] if tok.strip() else ""
        if tok.startswith("%"):
            out.append(tok.lstrip("%"))
        elif re.fullmatch(r"[\w\.\-]+", tok):
            out.append(tok)
    return out


def _dot_flops(op: Op, shapes_dims: dict[str, list[int]]) -> int:
    """2 x |result| x prod(contracting dim sizes)."""
    cm = _CONTRACT_RE.search(op.rhs)
    if not cm:
        return 0
    lhs = _operand_names(op.rhs)
    lhs_dims = shapes_dims.get(lhs[0], []) if lhs else []
    contract = 1
    for d in cm.group(1).split(","):
        if d and int(d) < len(lhs_dims):
            contract *= lhs_dims[int(d)]
    out = math.prod(op.result_dims) if op.result_dims else 1
    return 2 * out * contract


def analyze_text(text: str) -> dict:
    comps = parse_hlo(text)
    _mark_fusion_interiors(comps)
    weights = computation_weights(comps)
    # symbol dims table per computation for dot lhs lookup
    flops = 0.0
    bytes_accessed = 0.0
    coll_bytes = {k: 0.0 for k in _COLL}
    coll_count = {k: 0 for k in _COLL}
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        w = weights.get(cname, 0.0)
        if w == 0.0:
            continue
        dims_tbl = {op.name: op.result_dims for op in comp.ops}
        in_fusion = comp.is_fusion_interior
        for op in comp.ops:
            if op.opcode in ("dot", "dot-general"):
                flops += w * _dot_flops(op, dims_tbl)
            if in_fusion:
                continue   # interior values never touch HBM
            kind = next((k for k in _COLL if op.opcode.startswith(k)), None)
            if kind and not op.opcode.endswith("-done"):
                coll_bytes[kind] += w * op.result_bytes
                coll_count[kind] += int(w)
            if op.opcode in _NO_TRAFFIC or op.opcode in ("while",
                                                         "conditional"):
                continue
            if op.opcode == "fusion":
                cm = _CALL_RE.search(op.rhs)
                tgt = comps.get(cm.group(1).lstrip("%")) if cm else None
                if tgt is not None:
                    bytes_accessed += w * _fusion_effective_bytes(op, tgt)
                    continue
            # Sliced access patterns must NOT charge the full operand:
            # a scan trip dynamic-slices its per-step inputs out of the
            # stacked array — physical traffic is the slice, not the
            # stack (found when zamba2 showed a 295 s memory term).
            if op.opcode in ("dynamic-slice", "slice"):
                bytes_accessed += w * 2 * op.result_bytes
                continue
            if op.opcode == "dynamic-update-slice":
                ops_ = _operand_names(op.rhs)
                upd = comp.shapes.get(ops_[1], 0) if len(ops_) > 1 else 0
                bytes_accessed += w * 2 * upd
                continue
            if op.opcode == "gather":
                bytes_accessed += w * 2 * op.result_bytes
                continue
            if op.opcode in ("scatter", "select-and-scatter"):
                ops_ = _operand_names(op.rhs)
                upd = comp.shapes.get(ops_[-1], 0) if ops_ else 0
                bytes_accessed += w * (2 * upd + op.result_bytes)
                continue
            operands = sum(comp.shapes.get(o, 0)
                           for o in _operand_names(op.rhs))
            bytes_accessed += w * (op.result_bytes + operands)
    return {
        "flops": flops,
        "bytes_accessed": bytes_accessed,
        "collective_bytes": sum(coll_bytes.values()),
        "collectives": {"bytes": coll_bytes, "count": coll_count},
        "weights_nontrivial": {k: v for k, v in weights.items()
                               if v > 1.5},
    }
