"""Baseline vs optimized roofline comparison table.

    PYTHONPATH=src python -m repro.roofline.compare \
        results/dryrun results/dryrun_opt
"""
from __future__ import annotations

import json
import os
import sys


def load(d):
    out = {}
    for n in sorted(os.listdir(d)):
        if n.endswith(".json"):
            r = json.load(open(os.path.join(d, n)))
            out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def main():
    base = load(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    opt = load(sys.argv[2] if len(sys.argv) > 2 else
               "results/dryrun_opt")
    print("| arch | shape | term | baseline_s | optimized_s | delta |")
    print("|---|---|---|---|---|---|")
    total_b = total_o = 0.0
    for key in sorted(base):
        if key[2] != "16x16" or key not in opt:
            continue
        rb, ro = base[key]["roofline"], opt[key]["roofline"]
        bb = rb["step_time_lower_bound_s"]
        oo = ro["step_time_lower_bound_s"]
        total_b += bb
        total_o += oo
        if abs(bb - oo) / max(bb, 1e-12) < 0.01:
            continue
        print(f"| {key[0]} | {key[1]} | {rb['dominant'].replace('_s','')}"
              f" | {bb:.4f} | {oo:.4f} | "
              f"{(oo - bb) / bb * 100:+.1f}% |")
    print(f"\nSum of dominant-term lower bounds over all cells: "
          f"baseline {total_b:.2f}s -> optimized {total_o:.2f}s "
          f"({(total_o - total_b) / total_b * 100:+.1f}%)")


if __name__ == "__main__":
    main()
