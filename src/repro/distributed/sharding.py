"""Logical-axis -> mesh-axis sharding rules (DESIGN.md §4).

Every parameter declares logical axis names (ParamSpec.axes); the rules
below map them to mesh axes. A rule is dropped automatically when the
dimension is not divisible by the mesh-axis size (e.g. 4 kv heads on a
16-way model axis -> replicated), so one rule table serves all ten
architectures.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> candidate mesh axes (in order; all that fit are used)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "ff": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "ssm_proj": ("model",),
    "ssm_conv": ("model",),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "kv_seq": ("model",),     # decode-cache sequence dim (DESIGN.md §5)
    # replicated: embed, embed2, head_dim, layers, seq, None
}


def _axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(shape: tuple[int, ...], axes: tuple[Any, ...], mesh: Mesh,
             rules: dict | None = None) -> P:
    """PartitionSpec for one array, honouring divisibility."""
    rules = rules or DEFAULT_RULES
    sizes = _axis_sizes(mesh)
    parts = []
    used: set[str] = set()
    for dim, ax in zip(shape, axes):
        cand = rules.get(ax) if ax is not None else None
        if not cand:
            parts.append(None)
            continue
        chosen = []
        rem = dim
        for name in cand:
            if name in sizes and name not in used \
                    and rem % sizes[name] == 0:
                chosen.append(name)
                used.add(name)
                rem //= sizes[name]
        if not chosen:
            parts.append(None)
        elif len(chosen) == 1:
            parts.append(chosen[0])
        else:
            parts.append(tuple(chosen))
    return P(*parts)


def param_sharding_tree(param_specs: dict, mesh: Mesh,
                        rules: dict | None = None):
    """NamedSharding tree parallel to a ParamSpec tree."""
    from repro.models.params import ParamSpec
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, spec_for(s.shape, s.axes, mesh,
                                               rules)),
        param_specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def mask_sharding_tree(masks_abstract: dict, weight_axes: dict,
                       sparse_paths: list[str], mesh: Mesh,
                       rules: dict | None = None):
    """Masks shard like their weights (block dims inherit the weight's
    logical axes; divisibility is re-checked against block counts)."""
    from repro.core.sparse_mlp import get_path
    out = {}
    for path in sparse_paths:
        axes = get_path(weight_axes, path)
        arr = masks_abstract[path]
        out[path] = NamedSharding(
            mesh, spec_for(arr.shape, axes, mesh, rules))
    return out


def batch_pspec(mesh: Mesh) -> P:
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    return P(tuple(axes) if len(axes) > 1 else axes[0])


def batch_sharding(mesh: Mesh, ndim: int,
                   batch_dim: int | None = None) -> NamedSharding:
    """Batch over the data axes; with ``batch_dim`` given, axes that do
    not divide it are dropped (long_500k has global_batch=1)."""
    sizes = _axis_sizes(mesh)
    axes = [a for a in ("pod", "data") if a in sizes]
    if batch_dim is not None:
        chosen, got = [], 1
        for a in axes:
            if (batch_dim // got) % sizes[a] == 0:
                chosen.append(a)
                got *= sizes[a]
        axes = chosen
    first = tuple(axes) if len(axes) > 1 else (axes[0] if axes else None)
    return NamedSharding(mesh, P(*([first] + [None] * (ndim - 1))))


def cache_sharding(mesh: Mesh, shape: tuple[int, ...],
                   seq_axis: int = 2) -> NamedSharding:
    """KV caches: (L, B, S, KV, hd) -> batch over data axes, S over model
    (both only when divisible)."""
    sizes = _axis_sizes(mesh)
    parts: list[Any] = [None] * len(shape)
    baxes = [a for a in ("pod", "data") if a in sizes
             and shape[1] % sizes[a] == 0]
    # use as many batch axes as divide
    got = 1
    chosen = []
    for a in baxes:
        if (shape[1] // got) % sizes[a] == 0:
            chosen.append(a)
            got *= sizes[a]
    if chosen:
        parts[1] = tuple(chosen) if len(chosen) > 1 else chosen[0]
    if len(shape) > seq_axis and "model" in sizes \
            and shape[seq_axis] % sizes["model"] == 0:
        parts[seq_axis] = "model"
    return NamedSharding(mesh, P(*parts))


def count_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in leaves)
