"""DistContext: the one object threaded through model forwards that
knows the mesh and axis conventions. Keeps models mesh-agnostic (None =
single device, e.g. smoke tests)."""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, PartitionSpec as P

try:                                    # jax >= 0.6 exposes jax.shard_map
    _shard_map = jax.shard_map
except AttributeError:                  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map  # type: ignore

import inspect as _inspect

# partial-manual shard_map (manual over a subset of mesh axes via
# ``axis_names``) only works on newer jax; the 0.4.x ``auto=`` spelling
# crashes XLA with "Check failed: sharding.IsManualSubgroup()" — gate
# the deferred-reduction train step on this.
HAS_PARTIAL_MANUAL = \
    "axis_names" in _inspect.signature(_shard_map).parameters

if "check_vma" in _inspect.signature(_shard_map).parameters:
    shard_map = _shard_map
else:
    # older jax: check_vma is called check_rep, and partial-manual mode
    # takes the AUTO axis set instead of the manual ``axis_names``
    def shard_map(f, **kw):
        if "check_vma" in kw:
            kw["check_rep"] = kw.pop("check_vma")
        if "axis_names" in kw:
            manual = frozenset(kw.pop("axis_names"))
            kw["auto"] = frozenset(kw["mesh"].axis_names) - manual
        return _shard_map(f, **kw)


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Mesh | None = None
    model_axis: str = "model"
    inside_shard_map: bool = False
    sp: bool = True          # sequence-parallel residual stream
    # True inside a partial-manual shard_map over the data axes:
    # sharding constraints may then reference only the model axis
    manual_data: bool = False

    @property
    def batch_axes(self) -> tuple[str, ...]:
        if self.mesh is None:
            return ()
        return tuple(a for a in ("pod", "data")
                     if a in self.mesh.axis_names)

    def batch_pspec(self, ndim: int) -> P:
        ax = self.batch_axes
        first = ax if len(ax) > 1 else (ax[0] if ax else None)
        return P(*([first] + [None] * (ndim - 1)))

    def enter_shard_map(self) -> "DistContext":
        return dataclasses.replace(self, inside_shard_map=True)

    def _model_size(self) -> int:
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        return sizes.get(self.model_axis, 1)

    def constrain_seq(self, x):
        """Sequence-parallel residual stream: (B,S,D) -> S sharded over
        the model axis (Megatron-SP; bounds the per-layer saved residual
        to 1/TP — DESIGN.md §4)."""
        if self.mesh is None or self.inside_shard_map or x.ndim != 3 \
                or not self.sp:
            return x
        if x.shape[1] % self._model_size() != 0:
            return x
        from jax.sharding import NamedSharding
        ax = () if self.manual_data else self.batch_axes
        first = ax if len(ax) > 1 else (ax[0] if ax else None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(first, self.model_axis, None)))

    def constrain_logits(self, x):
        """Vocab-parallel logits: (B,S,V) -> V sharded over model (the
        f32 logits of a 150k-vocab LM never materialise unsharded)."""
        if self.mesh is None or self.inside_shard_map or x.ndim != 3:
            return x
        if x.shape[-1] % self._model_size() != 0:
            return x
        from jax.sharding import NamedSharding
        ax = () if self.manual_data else self.batch_axes
        first = ax if len(ax) > 1 else (ax[0] if ax else None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(first, None, self.model_axis)))
