"""Transposed BSpMM: dX = dY @ W^T with W in packed balanced BCSC —
the backward kernel that makes PACKED weights trainable (sparse
fine-tuning at fixed masks), not just servable.

W^T scatters: block (row=idx[j,k], col=j) of W contributes its transpose
at output block-column idx[j,k]. The TPU grid is sequential over
("arbitrary") dimensions, so read-modify-write accumulation into a
revisited output block is safe; a scalar-prefetched FIRST-VISIT flag
table (host-computed from idx — static) selects init-vs-accumulate, and
a final pass zeroes never-visited blocks via a visited-count table.

To keep never-visited output blocks defined, the wrapper zero-initialises
the output via input_output_aliasing of a zeros buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import PackedBCSC

_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def first_visit_flags(idx: np.ndarray, kb: int) -> np.ndarray:
    """(Nb, nnz) int32: 1 where this (j,k) is the first occurrence of
    idx[j,k] in (j,k)-lexicographic traversal order."""
    seen = np.zeros(kb, bool)
    nb, nnz = idx.shape
    flags = np.zeros((nb, nnz), np.int32)
    for j in range(nb):
        for k in range(nnz):
            r = int(idx[j, k])
            if not seen[r]:
                flags[j, k] = 1
                seen[r] = True
    return flags


def _bspmm_t_kernel(idx_ref, first_ref, dy_ref, w_ref, o_ref):
    j = pl.program_id(1)
    k = pl.program_id(2)
    part = jnp.dot(dy_ref[...], w_ref[0, 0].T,
                   preferred_element_type=jnp.float32)

    @pl.when(first_ref[j, k] == 1)
    def _init():
        o_ref[...] = part.astype(o_ref.dtype)

    @pl.when(first_ref[j, k] != 1)
    def _acc():
        o_ref[...] = (o_ref[...].astype(jnp.float32)
                      + part).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("kb", "blk_m", "interpret"))
def _bspmm_t_call(dy, blocks, idx, first, kb, *, blk_m=128,
                  interpret=False):
    m = dy.shape[0]
    nb, nnz, b_in, b_out = blocks.shape
    blk_m = min(blk_m, m)
    assert m % blk_m == 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m // blk_m, nb, nnz),
        in_specs=[
            pl.BlockSpec((blk_m, b_out),
                         lambda i, j, k, idx, first: (i, j)),
            pl.BlockSpec((1, 1, b_in, b_out),
                         lambda i, j, k, idx, first: (j, k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((blk_m, b_in),
                               lambda i, j, k, idx, first: (i, idx[j, k])),
    )
    kwargs = {}
    if _CompilerParams is not None:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"))
    return pl.pallas_call(
        _bspmm_t_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, kb * b_in), dy.dtype),
        interpret=interpret,
        **kwargs,
    )(idx, first, dy, blocks)


def bspmm_t(dy: jax.Array, packed: PackedBCSC, *, blk_m: int = 128,
            interpret: bool = False) -> jax.Array:
    """dX[M, K] = dY[M, N] @ W^T (packed balanced BCSC).

    Block-rows of W never touched by any kept block produce zero output
    columns (handled by a host-computed mask of visited rows)."""
    idx_np = np.asarray(jax.device_get(packed.idx))
    first = jnp.asarray(first_visit_flags(idx_np, packed.kb))
    dx = _bspmm_t_call(dy, packed.blocks, packed.idx, first, packed.kb,
                       blk_m=blk_m, interpret=interpret)
    visited = np.zeros(packed.kb, bool)
    visited[idx_np.reshape(-1)] = True
    if visited.all():
        return dx
    keep = jnp.repeat(jnp.asarray(visited), packed.b_in)
    # never-visited output blocks hold garbage (not written): hard-zero
    return jnp.where(keep[None, :], dx, 0).astype(dx.dtype)
