"""Paged-KV decode attention as a Pallas TPU blocked-gather kernel.

The XLA paged path (models/attention.py ``gather_pages`` +
``_scores_to_out``) materialises a (B, R*ps, KV, hd) gathered copy of
each lane's live pages in HBM before the attention core reads it — the
bytes are right, but they move twice. This kernel reuses the BCSC-style
block-gather machinery of ``bspmm.py``: the scalar-prefetched block
table drives the ``BlockSpec.index_map`` of the K/V pool operands, so
Mosaic's pipeline DMAs each live page HBM->VMEM exactly once, straight
into a flash-decode online-softmax accumulation — no gathered
intermediate ever exists (the paper's "only necessary blocks are
loaded", applied to the KV cache instead of the weights).

grid = (lanes, kv heads, pages); the page axis is ``arbitrary`` (it
carries the running max / sum / accumulator scratch), lanes and heads
are parallel. Masking (causal, window, ragged left-pad) arrives as an
additive-bias row per (lane, slot) — precomputed in XLA from the same
``_cache_positions`` logic as the dense path, so the two paths mask
identically.

Validated in interpret mode against the XLA gather path
(tests/test_paged_kv.py); the engine picks it via
``attn_backend='pallas'``.

Mixed read-page buckets per lane: the grid reads the SAME ``R`` pages
for every lane even when frontiers differ wildly (the engine buckets
``R`` to the batch max). A lane whose live context is shorter than
``R`` pages has block-table entries past its allocation pointing at
pool page 0 — a page that may belong to another lane — so tolerating
mixed buckets means those reads must contribute NOTHING: the bias row
marks every slot past the lane's frontier NEG_INF (causal mask), the
``valid`` guard zeroes their probabilities before the accumulator sees
them, and a fully-masked page leaves m/l/acc untouched. Verified by
tests/test_paged_kv.py::test_kernel_tolerates_mixed_read_buckets
(one-page lane next to a many-page lane under one shared bucket).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _paged_decode_kernel(scale, softcap, bt_ref, q_ref, k_ref, v_ref,
                         bias_ref, o_ref, acc_ref, m_ref, l_ref):
    """One (lane b, kv head h, page j) grid step: fold pool page
    bt[b, j] into lane b's online softmax for head h."""
    j = pl.program_id(2)
    npg = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                                  # (G, hd)
    k = k_ref[0, :, 0, :]                            # (ps, hd)
    v = v_ref[0, :, 0, :]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    bias = bias_ref[0]                               # (ps,) 0 / NEG_INF
    valid = bias > NEG_INF / 2
    s = jnp.where(valid[None, :], s, NEG_INF)        # (G, ps)

    m_prev = m_ref[...]                              # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(valid[None, :], p, 0.0)            # fully-masked pages
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == npg - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)   # all-masked lane: garbage,
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)  # discarded


def paged_flash_decode(q4, pool_k, pool_v, block_tables, bias, *,
                       scale: float, softcap: float = 0.0,
                       interpret: bool = False) -> jax.Array:
    """q4: (B, KV, G, hd); pool_k/v: (n_pages, ps, KV, hd);
    block_tables: (B, R) int32 — the lanes' first R logical pages;
    bias: (B, R*ps) f32, 0 where the slot may be attended, NEG_INF
    where masked. Returns (B, KV, G, hd) f32."""
    b, kvh, g, hd = q4.shape
    ps = pool_k.shape[1]
    r = block_tables.shape[1]
    assert bias.shape == (b, r * ps), (bias.shape, b, r, ps)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, r),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda i, h, j, bt: (i, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda i, h, j, bt: (bt[i, j], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda i, h, j, bt: (bt[i, j], 0, h, 0)),
            pl.BlockSpec((1, ps), lambda i, h, j, bt: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda i, h, j, bt: (i, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, hd), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32)],
    )
    kwargs = {}
    if _CompilerParams is not None:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    kernel = functools.partial(_paged_decode_kernel, scale, softcap)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(block_tables, q4, pool_k, pool_v, bias)


def mask_bias(posb, kpos, window: int = 0) -> jax.Array:
    """(B,1) query positions + (B,S) slot positions -> (B,S) additive
    bias: 0 where the causal (AND optional window) mask admits the slot,
    NEG_INF elsewhere — the dense path's where-mask as a bias row."""
    mask = posb >= kpos
    if window:
        mask &= posb - kpos < window
    return jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)


def paged_decode_attn(cfg, q, pool_k, pool_v, block_tables, posb, kpos,
                      *, window: int = 0,
                      interpret: bool = False) -> jax.Array:
    """models/attention.py adapter: q (B,1,H,hd) -> out (B,1,H,hd),
    matching ``_scores_to_out``'s grouped layout and mixed precision."""
    b, _, h, hd = q.shape
    kvh = pool_k.shape[2]
    g = h // kvh
    scale = cfg.attn_scale or 1.0 / math.sqrt(hd)
    q4 = q.reshape(b, kvh, g, hd)
    bias = mask_bias(posb, kpos, window)
    out = paged_flash_decode(
        q4, pool_k, pool_v, block_tables, bias, scale=scale,
        softcap=float(cfg.attn_logit_softcap or 0.0), interpret=interpret)
    return out.reshape(b, 1, h, hd).astype(q.dtype)
