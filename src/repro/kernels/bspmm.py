"""BLaST BSpMM as a Pallas TPU kernel (paper §3.3, TPU-native redesign).

Computes ``Y[M, N] = X[M, K] @ W`` where W is block-sparse in *balanced
BCSC*: every block-column holds exactly ``nnz`` kept (b_in, b_out) blocks
(``core/packing.py``). The TPU adaptation of the paper's Triton kernel
(DESIGN.md §2):

  * grid = (M tiles, block-columns, nnz)  — static because the sparsifier
    produces balanced structure (the paper's "no skewed load imbalance",
    taken to its static-shape conclusion);
  * the scalar-prefetched block-row index table drives the
    ``BlockSpec.index_map`` of the dense operand X, so Mosaic's pipeline
    only DMAs the X tiles that the sparsity structure actually needs —
    the TPU analogue of the paper's "only necessary blocks of X can be
    loaded" (paper Listing 2's pointer algebra becomes an index map);
  * accumulation in an f32 VMEM scratch tile, written out on the last
    nnz step; MXU engaged via jnp.dot with preferred f32 accumulation.

Validated in interpret mode against ``ref.py`` over shape/dtype sweeps
(tests/test_kernels_bspmm.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import PackedBCSC

_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _bspmm_kernel(idx_ref, x_ref, w_ref, o_ref, acc_ref):
    """One (i, j, k) grid step: acc += X[i, idx[j,k]] @ Wblk[j,k]."""
    k = pl.program_id(2)
    nnz = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[0, 0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nnz - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk_m", "interpret"))
def bspmm(x: jax.Array, packed: PackedBCSC, *, blk_m: int = 128,
          interpret: bool = False) -> jax.Array:
    """Y = X @ W (packed balanced BCSC). ``blk_m`` is the paper's blk_M —
    rows of X reused per VMEM-resident sparse block (COSMA-style reuse).

    Requires M % blk_m == 0 (callers pad; serving shapes are multiples of
    8 already)."""
    m, k_dim = x.shape
    nb, nnz, b_in, b_out = packed.blocks.shape
    assert packed.kb * b_in == k_dim, (packed.kb, b_in, k_dim)
    blk_m = min(blk_m, m)
    assert m % blk_m == 0, f"M={m} not a multiple of blk_m={blk_m}"
    n = nb * b_out

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // blk_m, nb, nnz),
        in_specs=[
            pl.BlockSpec((blk_m, b_in),
                         lambda i, j, k, idx: (i, idx[j, k])),
            pl.BlockSpec((1, 1, b_in, b_out),
                         lambda i, j, k, idx: (j, k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((blk_m, b_out),
                               lambda i, j, k, idx: (i, j)),
        scratch_shapes=[pltpu.VMEM((blk_m, b_out), jnp.float32)],
    )
    kwargs = {}
    if _CompilerParams is not None:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        _bspmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
        **kwargs,
    )(packed.idx, x, packed.blocks)


def _fused_glu_kernel(act_id, idx_g_ref, idx_u_ref, xg_ref, xu_ref,
                      wg_ref, wu_ref, o_ref, accg_ref, accu_ref):
    """Fused front half of the Sparse MLP (paper §3.3.3):
    H[i, j] = act(sum_k X @ Wg) * (sum_k X @ Wu), both sums sparse."""
    k = pl.program_id(2)
    nnz = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    accg_ref[...] += jnp.dot(xg_ref[...], wg_ref[0, 0],
                             preferred_element_type=jnp.float32)
    accu_ref[...] += jnp.dot(xu_ref[...], wu_ref[0, 0],
                             preferred_element_type=jnp.float32)

    @pl.when(k == nnz - 1)
    def _flush():
        hg = accg_ref[...]
        if act_id == 0:
            a = jax.nn.silu(hg)
        elif act_id == 1:
            a = jax.nn.gelu(hg, approximate=True)
        else:
            a = jax.nn.relu(hg)
        o_ref[...] = (a * accu_ref[...]).astype(o_ref.dtype)


def _fused_glu_joint_kernel(act_id, idx_ref, x_ref, wg_ref, wu_ref,
                            o_ref, accg_ref, accu_ref):
    """Joint-structure variant: gate and up share ONE idx table, so each
    X tile is a single operand — Mosaic DMAs it once per (i, j, k) step
    instead of twice (the gate/up weight streams stay separate)."""
    k = pl.program_id(2)
    nnz = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    xt = x_ref[...]
    accg_ref[...] += jnp.dot(xt, wg_ref[0, 0],
                             preferred_element_type=jnp.float32)
    accu_ref[...] += jnp.dot(xt, wu_ref[0, 0],
                             preferred_element_type=jnp.float32)

    @pl.when(k == nnz - 1)
    def _flush():
        hg = accg_ref[...]
        if act_id == 0:
            a = jax.nn.silu(hg)
        elif act_id == 1:
            a = jax.nn.gelu(hg, approximate=True)
        else:
            a = jax.nn.relu(hg)
        o_ref[...] = (a * accu_ref[...]).astype(o_ref.dtype)


_ACT_IDS = {"silu": 0, "gelu": 1, "relu": 2}


def _fused_glu_joint(x, p_gate, p_up, *, act, blk_m, interpret):
    """Single-X-stream fused GLU (``PackedBCSC.joint`` pack-time
    promise: identical gate/up idx tables)."""
    m, _ = x.shape
    nb, nnz, b_in, b_out = p_gate.blocks.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // blk_m, nb, nnz),
        in_specs=[
            pl.BlockSpec((blk_m, b_in),
                         lambda i, j, k, idx: (i, idx[j, k])),
            pl.BlockSpec((1, 1, b_in, b_out),
                         lambda i, j, k, idx: (j, k, 0, 0)),
            pl.BlockSpec((1, 1, b_in, b_out),
                         lambda i, j, k, idx: (j, k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((blk_m, b_out),
                               lambda i, j, k, idx: (i, j)),
        scratch_shapes=[pltpu.VMEM((blk_m, b_out), jnp.float32),
                        pltpu.VMEM((blk_m, b_out), jnp.float32)],
    )
    kwargs = {}
    if _CompilerParams is not None:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    kernel = functools.partial(_fused_glu_joint_kernel, _ACT_IDS[act])
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, nb * b_out), x.dtype),
        interpret=interpret,
        **kwargs,
    )(p_gate.idx, x, p_gate.blocks, p_up.blocks)


@functools.partial(jax.jit,
                   static_argnames=("act", "blk_m", "interpret"))
def fused_glu(x: jax.Array, p_gate: PackedBCSC, p_up: PackedBCSC, *,
              act: str = "silu", blk_m: int = 128,
              interpret: bool = False) -> jax.Array:
    """H = act(X Wg) * (X Wu) in ONE kernel — the memory-bound
    nonlinearity fused into the compute-bound SpMM epilogue (paper
    §3.3.3). Wg and Wu normally have independent sparsity structures
    (two scalar-prefetched index tables, two accumulators); when both
    carry the pack-time ``joint`` promise (identical idx tables, the
    common joint-pruning case) X becomes a single operand and each of
    its tiles is DMA'd once instead of twice."""
    m, k_dim = x.shape
    if p_gate.nnz != p_up.nnz:   # align (zero-block padding, exact)
        from repro.core.packing import pad_nnz
        nnz_max = max(p_gate.nnz, p_up.nnz)
        p_gate = pad_nnz(p_gate, nnz_max)
        p_up = pad_nnz(p_up, nnz_max)
    nb, nnz, b_in, b_out = p_gate.blocks.shape
    assert p_up.blocks.shape == (nb, nnz, b_in, b_out)
    blk_m = min(blk_m, m)
    assert m % blk_m == 0
    if p_gate.joint and p_up.joint:
        return _fused_glu_joint(x, p_gate, p_up, act=act, blk_m=blk_m,
                                interpret=interpret)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m // blk_m, nb, nnz),
        in_specs=[
            pl.BlockSpec((blk_m, b_in),
                         lambda i, j, k, ig, iu: (i, ig[j, k])),
            pl.BlockSpec((blk_m, b_in),
                         lambda i, j, k, ig, iu: (i, iu[j, k])),
            pl.BlockSpec((1, 1, b_in, b_out),
                         lambda i, j, k, ig, iu: (j, k, 0, 0)),
            pl.BlockSpec((1, 1, b_in, b_out),
                         lambda i, j, k, ig, iu: (j, k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((blk_m, b_out),
                               lambda i, j, k, ig, iu: (i, j)),
        scratch_shapes=[pltpu.VMEM((blk_m, b_out), jnp.float32),
                        pltpu.VMEM((blk_m, b_out), jnp.float32)],
    )
    kwargs = {}
    if _CompilerParams is not None:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    kernel = functools.partial(_fused_glu_kernel, _ACT_IDS[act])
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, nb * b_out), x.dtype),
        interpret=interpret,
        **kwargs,
    )(p_gate.idx, p_up.idx, x, x, p_gate.blocks, p_up.blocks)
