"""Jitted wrappers + GSPMD-partitionable XLA twin of the BSpMM kernel.

Three execution backends for the same balanced-BCSC math:

  * ``backend='pallas'``      — the Mosaic TPU kernel (production TPU);
  * ``backend='pallas_interp'``— same kernel, interpret mode (CPU tests);
  * ``backend='xla'``          — gather+einsum formulation that GSPMD can
    partition (used inside the multi-pod dry-run / serving so the
    compiled HLO carries the true sparse FLOP count and the packed
    memory footprint — DESIGN.md §2).

``sparse_mlp_apply`` is the full paper Eq. (1) with packed weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import PackedBCSC
from repro.kernels import bspmm as _pk


def _contract_gathered(xg: jax.Array, blocks: jax.Array,
                       out_dtype) -> jax.Array:
    """(M, Nb, nnz, b_in) gathered X tiles @ (Nb, nnz, b_in, b_out)
    blocks -> (M, N). The single source of truth for the XLA BSpMM
    contraction — the joint fused-GLU path reuses it so both operands
    stay bitwise-identical to ``bspmm_xla``."""
    m = xg.shape[0]
    nb, _, _, b_out = blocks.shape
    y = jnp.einsum("mjnb,jnbo->mjo", xg, blocks,
                   preferred_element_type=jnp.float32)
    return y.reshape(m, nb * b_out).astype(out_dtype)


def bspmm_xla(x: jax.Array, packed: PackedBCSC) -> jax.Array:
    """Y = X @ W, packed balanced BCSC, expressed in partitionable XLA.

    xb = X viewed as (M, Kb, b_in); for every block-column j we gather its
    ``nnz`` X tiles and contract (nnz, b_in) at once — exactly the Pallas
    kernel's dataflow, with XLA's gather playing the index-map role.
    FLOPs = 2 * M * nnz * b_in * N  ==  dense * (1 - sparsity)."""
    m, k_dim = x.shape
    xb = x.reshape(m, packed.kb, packed.b_in)
    xg = jnp.take(xb, packed.idx, axis=1)        # (M, Nb, nnz, b_in)
    return _contract_gathered(xg, packed.blocks, x.dtype)


def bspmm(x: jax.Array, packed: PackedBCSC, *, backend: str = "xla",
          blk_m: int = 128) -> jax.Array:
    if backend == "xla":
        return bspmm_xla(x, packed)
    return _pk.bspmm(x, packed, blk_m=blk_m,
                     interpret=(backend == "pallas_interp"))


def fused_glu(x, p_gate, p_up, *, act="silu", backend="xla", blk_m=128):
    """act(X Wg) * (X Wu), both packed. When gate and up carry the
    pack-time ``joint`` promise (identical idx tables — common after
    joint pruning; ``packing.mark_joint`` / ``export.pack_params``), X
    is gathered/streamed ONCE for both contractions."""
    if backend == "xla":
        import repro.core.sparse_mlp as sm
        if p_gate.joint and p_up.joint:
            m = x.shape[0]
            xb = x.reshape(m, p_gate.kb, p_gate.b_in)
            xg = jnp.take(xb, p_gate.idx, axis=1)    # one gather of X
            hg = _contract_gathered(xg, p_gate.blocks,
                                    x.dtype).astype(jnp.float32)
            hu = _contract_gathered(xg, p_up.blocks,
                                    x.dtype).astype(jnp.float32)
        else:
            hg = bspmm_xla(x, p_gate).astype(jnp.float32)
            hu = bspmm_xla(x, p_up).astype(jnp.float32)
        return (sm.act_fn(act)(hg) * hu).astype(x.dtype)
    return _pk.fused_glu(x, p_gate, p_up, act=act, blk_m=blk_m,
                         interpret=(backend == "pallas_interp"))


def sparse_mlp_apply(x: jax.Array, p_gate: PackedBCSC, p_up: PackedBCSC,
                     p_down: PackedBCSC, *, act: str = "silu",
                     backend: str = "xla", blk_m: int = 128) -> jax.Array:
    """Paper Eq. (1): Y = (act(X Wg) * (X Wu)) Wd, all three packed.

    The front half is ONE fused kernel; the second contraction is a
    second BSpMM (triple fusion would need a (blk_m, d_ff) VMEM resident
    intermediate — DESIGN.md §2)."""
    h = fused_glu(x, p_gate, p_up, act=act, backend=backend, blk_m=blk_m)
    return bspmm(h, p_down, backend=backend, blk_m=blk_m)


def bspmm_t_xla(dy: jax.Array, packed: PackedBCSC) -> jax.Array:
    """dX = dY @ W^T, partitionable XLA twin of kernels/bspmm_t.py:
    per-(column, k) partials scattered-added into the K block grid."""
    m = dy.shape[0]
    nb, nnz, b_in, b_out = packed.blocks.shape
    dyb = dy.reshape(m, nb, b_out)
    # partials P[m, j, k, bi] = dY_j @ Wblk[j,k]^T
    parts = jnp.einsum("mjo,jkio->mjki", dyb, packed.blocks,
                       preferred_element_type=jnp.float32)
    dxb = jnp.zeros((m, packed.kb, b_in), jnp.float32)
    dxb = dxb.at[:, packed.idx.reshape(-1)].add(
        parts.reshape(m, nb * nnz, b_in))
    return dxb.reshape(m, packed.kb * b_in).astype(dy.dtype)


def bspmm_grad_blocks(x: jax.Array, dy: jax.Array, packed: PackedBCSC
                      ) -> jax.Array:
    """dW blocks: for kept block (j,k): X[:, idx[j,k]]^T @ dY_j —
    gathered, no dense dW materialisation (sparse fine-tuning)."""
    m = x.shape[0]
    nb, nnz, b_in, b_out = packed.blocks.shape
    xb = x.reshape(m, packed.kb, b_in)
    xg = jnp.take(xb, packed.idx, axis=1)           # (M, Nb, nnz, bi)
    dyb = dy.reshape(m, nb, b_out)
    return jnp.einsum("mjki,mjo->jkio", xg, dyb,
                      preferred_element_type=jnp.float32
                      ).astype(packed.blocks.dtype)


def make_bspmm_trainable(idx: jax.Array, kb: int):
    """Factory: Y = X @ W with a SPARSE backward for a FIXED mask
    structure (idx closed over — the paper's fine-tuning stage at final
    sparsity). Returns f(x, blocks) with custom VJP: dX via the
    transposed BSpMM, dW only on kept blocks."""

    @jax.custom_vjp
    def f(x, blocks):
        return bspmm_xla(x, PackedBCSC(blocks=blocks, idx=idx, kb=kb))

    def fwd(x, blocks):
        return f(x, blocks), (x, blocks)

    def bwd(res, dy):
        x, blocks = res
        p = PackedBCSC(blocks=blocks, idx=idx, kb=kb)
        return bspmm_t_xla(dy, p), bspmm_grad_blocks(x, dy, p)

    f.defvjp(fwd, bwd)
    return f


def flops_bspmm(m: int, packed: PackedBCSC) -> int:
    """True sparse FLOPs of one BSpMM call."""
    nb, nnz, b_in, b_out = packed.blocks.shape
    return 2 * m * nb * nnz * b_in * b_out


def flops_dense(m: int, k: int, n: int) -> int:
    return 2 * m * k * n
