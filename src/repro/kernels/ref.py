"""Pure-jnp oracles for the BLaST kernels (the ``ref.py`` of each kernel).

Everything here is the *definitionally correct* implementation, used by
tests to validate the Pallas kernels (interpret mode) and the XLA scan
formulation over shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import PackedBCSC, unpack


def bspmm_ref(x: jax.Array, packed: PackedBCSC) -> jax.Array:
    """Y = X @ W  with W given in packed balanced BCSC. Dense reference:
    unpack to dense and matmul in f32."""
    w = unpack(packed)
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)


def bspmm_masked_ref(x: jax.Array, w: jax.Array, mask_elem: jax.Array
                     ) -> jax.Array:
    """Masked-dense reference: Y = X @ (W * mask)."""
    wm = (w * mask_elem.astype(w.dtype)).astype(jnp.float32)
    return (x.astype(jnp.float32) @ wm).astype(x.dtype)


def fused_glu_ref(x: jax.Array, p_gate: PackedBCSC, p_up: PackedBCSC,
                  act: str = "silu") -> jax.Array:
    """H = act(X Wg) * (X Wu) with both weights packed BCSC (paper §3.3.3
    fused Sparse-MLP front half)."""
    import repro.core.sparse_mlp as sm
    hg = bspmm_ref(x, p_gate).astype(jnp.float32)
    hu = bspmm_ref(x, p_up).astype(jnp.float32)
    return (sm.act_fn(act)(hg) * hu).astype(x.dtype)


def sparse_mlp_ref(x, p_gate, p_up, p_down, act: str = "silu"):
    """Full paper Eq. (1) with packed weights:
    Y = (act(X Wg) * (X Wu)) Wd."""
    h = fused_glu_ref(x, p_gate, p_up, act)
    return bspmm_ref(h, p_down)
