"""Paper Fig. 4 — BSpMM kernel speedup vs dense, over sparsity x block
size x (Emb, Seq). On CPU we measure the XLA twin of the kernel (the
compute actually drops with sparsity) and report measured speedup plus
the FLOP-ratio-derived roofline speedup (what the TPU kernel achieves
when compute-bound)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core import packing, topk
from repro.core.prune_grow import BlastSpec, generate_mask
from repro.kernels import ops


def _make(key, k_dim, n, bi, bo, s):
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (k_dim, n), jnp.float32)
    g = jax.random.normal(k2, (k_dim, n), jnp.float32)
    spec = BlastSpec(b_in=bi, b_out=bo, s_max=s, total_steps=1)
    m = generate_mask(spec, w, g, 1)
    wm = topk.apply_block_mask(w, m, bi, bo)
    return wm, packing.pack(wm, m, bi, bo)


def main():
    key = jax.random.PRNGKey(0)
    seq = 256
    for emb in (256, 512):
        n = 4 * emb                      # paper: N = 4 x Emb
        x = jax.random.normal(key, (seq, emb), jnp.float32)
        dense_w = jax.random.normal(key, (emb, n), jnp.float32)
        f_dense = jax.jit(lambda x, w: x @ w)
        t_dense = timeit(f_dense, x, dense_w)
        for b in (32, 64):
            for s in (0.5, 0.7, 0.9, 0.95):
                _, p = _make(key, emb, n, b, b, s)
                f_sp = jax.jit(lambda x, p=p: ops.bspmm_xla(x, p))
                t_sp = timeit(f_sp, x)
                flop_ratio = ops.flops_dense(seq, emb, n) / max(
                    ops.flops_bspmm(seq, p), 1)
                row(f"bspmm_emb{emb}_b{b}_s{int(s*100)}", t_sp,
                    f"speedup={t_dense / t_sp:.2f}x "
                    f"roofline_speedup={flop_ratio:.2f}x")
        row(f"dense_emb{emb}", t_dense, "baseline")


if __name__ == "__main__":
    main()
