"""Paper Fig. 6 — end-to-end inference speedup (sparse vs dense serving)
across block sizes and sparsity levels, CPU-scale model. Sections: the
jitted decode-step micro-bench, end-to-end tokens/s through the
continuous-batching engine across decode SLAB sizes (K=1 is the
per-token baseline: one host sync per token) for BOTH KV-cache layouts
(paged page-pool vs contiguous slab), a SHARED-PREFIX workload with the
radix-tree prefix cache on vs off (hit rate, prefill tokens skipped,
referenced-KV peak), a MIXED-vs-PHASED sweep under continuous arrivals
(one submit per engine step: decode-stall steps, TTFT / inter-token
p50/p95), a multi-tenant FRONT-DOOR trace (interactive + batch priority
classes) under FIFO vs SLA vs SLA+preemption-with-host-KV-offload
(per-class TTFT p95, preemption / offload counters), and a
``BENCH_serving.json`` artifact — tok/s, peak KV-cache bytes,
block-table page-read counters, and scheduler observability (queue
depth, page-gate rejections, queued time) — so the serving perf
trajectory is tracked PR over PR (CI uploads it on every run).

    PYTHONPATH=src:. python benchmarks/bench_inference.py \
        [--smoke] [--mixed-only] [--frontdoor-only] [--chaos-only] \
        [--out BENCH_serving.json]

``--smoke`` runs a tiny config through the same dispatch path (CI guard
against decode-loop regressions; kernels on the CPU-safe XLA backend)
and HARD-ASSERTS the paged engine's guarantees: greedy tokens
bitwise-equal to the contiguous engine, strictly fewer pages read than
a dense ``max_len`` scan at short live lengths; for the prefix cache —
bitwise token parity sharing-on vs sharing-off with a real hit rate,
prefill-token savings, and a referenced-KV peak strictly under the
no-sharing baseline on a common-system-prompt workload; and for mixed
batching — bitwise token parity mixed vs phased vs the oracle under
continuous arrivals, decode stalls ELIMINATED (the counter reads 0
where phased racks them up), and TTFT p95 no worse than phased.
``--mixed-only`` runs just the mixed sweep + its asserts (the CI
mixed-smoke job). ``--chaos-only`` runs the fault-injection suite (the
CI chaos-smoke job) and writes ``BENCH_chaos.json`` — the chaos parity
oracle (seeded NaN lane + engine-thread crash + corrupted offload
record: survivors bitwise-identical, victims fail structurally), the
watchdog hang recovery (>=1 lane restored from offloaded KV with ZERO
re-prefilled tokens, recovery latency recorded), and a load-shed flood
(bounded queue, retry-after on every rejection, admitted-request TTFT
p95 under the queue-depth service bound).
``--frontdoor-only`` runs just the front-door sweep
and HARD-ASSERTS the production-API guarantees: tokens bitwise-equal
across FIFO / SLA / SLA+preempt schedulers, interactive TTFT p95
STRICTLY better under SLA than FIFO on the same trace, >=1 real
preemption with zero re-prefilled tokens (prefill counters equal,
restored == offloaded pages), no batch request starved past the aging
bound under a sustained interactive flood, and the asyncio front end
serving continuous arrivals with zero stalled decode steps and bounded
TTFT p95 while streaming bitwise-correct tokens (the CI async-smoke
job).
"""
from __future__ import annotations

import argparse
import asyncio
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (bench_cfg, replace_blast, row, timeit,
                               write_bench_artifact)
from repro.core.prune_grow import initial_mask
from repro.models import registry
from repro.obs.export import write_chrome_trace
from repro.obs.metrics import parse_prometheus_text
from repro.obs.trace import Tracer
from repro.serving import engine, export, serve_loop
from repro.serving.faults import (BackpressureError, FaultPlan,
                                  LaneFaultError)
from repro.serving.frontend import AsyncEngine
from repro.serving.scheduler import (BATCH, INTERACTIVE, FIFOScheduler,
                                     SLAScheduler)

SLAB_SIZES = (1, 4, 16)


def _pack(cfg, params):
    masks = {}
    import dataclasses as dc
    from repro.core import sparse_mlp as sm
    for path in registry.sparse_paths(cfg):
        w = sm.get_path(params, path)
        bi, bo = sm.block_dims_for(cfg.blast, path)
        pspec = dc.replace(cfg.blast, b_in=bi, b_out=bo)
        masks[path] = initial_mask(pspec, w)
    return export.pack_params(cfg, params, masks, dtype=jnp.float32)


def _one(cfg, sparsity, b):
    cfg = replace_blast(cfg, b_in=b, b_out=b, s_init=sparsity,
                        s_max=sparsity)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    packed = _pack(cfg, params)
    B, MAX = 8, 64
    cache = registry.init_cache(cfg, B, MAX, dtype=jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, i:
                   registry.decode_step(cfg, p, c, t, i)[0])
    return timeit(step, packed, cache, tok, jnp.int32(3))


def _engine_stats(cfg, params, *, slab_k: int, ragged: bool,
                  n_req: int = 8, max_batch: int = 4, max_len: int = 64,
                  new_tokens: int = 33, reps: int = 3,
                  paged: bool = True, page_size: int = 16) -> dict:
    """Serving stats through the continuous-batching engine (requests
    over fewer lanes exercises admission + per-lane slot reuse).
    ``new_tokens=33`` -> 32 decode steps/request, divisible by every
    SLAB_SIZES entry. Best of ``reps`` measured passes (decode tok/s)."""
    rng = np.random.default_rng(0)
    lens = (rng.integers(8, 17, size=n_req) if ragged
            else [16] * n_req)
    prompts = [rng.integers(0, cfg.vocab_size, size=(int(p),))
               .astype(np.int32) for p in lens]
    # one Engine for all passes: its jitted steps are per-instance, so
    # the warm-up pass must run on the instance being measured
    eng = engine.Engine(cfg, params, max_batch=max_batch,
                        max_len=max_len, prefill_chunk=8, slab_k=slab_k,
                        paged=paged, page_size=page_size)
    for p in prompts:
        eng.submit(p, new_tokens)
    eng.run()                               # warm jit
    best = None
    for _ in range(reps):
        eng.reset_stats()
        for p in prompts:
            eng.submit(p, new_tokens)
        eng.run()                           # measured
        if best is None or eng.stats["tok_per_s"] > best["tok_per_s"]:
            best = dict(eng.stats)
    return best


def _serving_sweep(cfg, label: str, params, *, sparsity: float,
                   results: list, ragged: bool = False,
                   slab_sizes=SLAB_SIZES, paged: bool = True,
                   **kw) -> None:
    """One engine workload across slab sizes; K=1 is the per-token
    baseline (one host sync per generated token)."""
    cachetag = "paged" if paged else "contig"
    for k in slab_sizes:
        st = _engine_stats(cfg, params, slab_k=k, ragged=ragged,
                           paged=paged, **kw)
        name = (f"engine_{label}_{cachetag}_k{k}"
                + ("_ragged" if ragged else ""))
        row(name, 1e6 / max(st["e2e_tok_per_s"], 1e-9),
            f"decode_tok_per_s={st['tok_per_s']:.1f} "
            f"e2e_tok_per_s={st['e2e_tok_per_s']:.1f} "
            f"syncs={st['decode_slabs']} "
            f"peak_kv_kib={st['peak_kv_bytes'] / 1024:.1f}")
        results.append({
            "name": name, "slab_k": k, "ragged": ragged,
            "batch": kw.get("max_batch", 4), "sparsity": sparsity,
            "paged": paged,
            "decode_tok_per_s": st["tok_per_s"],
            "e2e_tok_per_s": st["e2e_tok_per_s"],
            "decode_tokens": st["decode_tokens"],
            "host_syncs": st["decode_slabs"],
            "peak_kv_bytes": st["peak_kv_bytes"],
            "kv_bytes_contiguous_equiv": st["kv_bytes_contiguous_equiv"],
            "pages_read": st["pages_read"],
            "pages_read_dense_equiv": st["pages_read_dense_equiv"],
            "baseline_per_token": k == 1,
        })


def _shared_prefix_stats(cfg, params, *, prefix_cache: bool,
                         n_req: int = 8, sys_len: int = 48,
                         sfx_len: int = 6, max_batch: int = 4,
                         new_tokens: int = 9, page_size: int = 8,
                         reps: int = 3) -> dict:
    """The prefix-cache workload: every request = one common system
    prompt + a short unique suffix (the agents/few-shot serving shape).
    With ``prefix_cache=True`` the radix tree should cover the system
    prompt after the first request — measured stats report the hit
    rate, prefill tokens skipped, and both KV peaks (referenced = pages
    live lanes pin at once; occupancy additionally counts reclaimable
    cached-idle pages)."""
    rng = np.random.default_rng(0)
    sys_p = rng.integers(0, cfg.vocab_size, size=(sys_len,)) \
        .astype(np.int32)
    prompts = [np.concatenate(
        [sys_p, rng.integers(0, cfg.vocab_size, size=(sfx_len,))
         .astype(np.int32)]) for _ in range(n_req)]
    max_len = sys_len + sfx_len + new_tokens + 8
    eng = engine.Engine(cfg, params, max_batch=max_batch,
                        max_len=max_len, prefill_chunk=8, slab_k=4,
                        paged=True, page_size=page_size,
                        prefix_cache=prefix_cache)
    for p in prompts:
        eng.submit(p, new_tokens)
    eng.run()                               # warm jit (and the tree)
    best = None
    for _ in range(reps):
        eng.reset_stats()
        for p in prompts:
            eng.submit(p, new_tokens)
        eng.run()
        if best is None or eng.stats["e2e_tok_per_s"] > best["e2e_tok_per_s"]:
            best = dict(eng.stats)
    return best


def _prefix_sweep(cfg, label: str, params, *, sparsity: float,
                  results: list, **kw) -> None:
    """Shared-prefix workload, sharing ON vs OFF (same prompts, same
    weights): the BENCH_serving.json rows carry hit rate, skipped
    prefill tokens and the peak-KV comparison PR over PR."""
    for pc in (False, True):
        st = _shared_prefix_stats(cfg, params, prefix_cache=pc, **kw)
        name = f"engine_{label}_prefix_{'on' if pc else 'off'}"
        extra = (f"hit_rate={st.get('prefix_hit_rate', 0.0):.2f} "
                 f"skipped={st.get('prefill_tokens_skipped', 0)}"
                 if pc else "baseline")
        row(name, 1e6 / max(st["e2e_tok_per_s"], 1e-9),
            f"e2e_tok_per_s={st['e2e_tok_per_s']:.1f} "
            f"prefill_tokens={st['prefill_tokens']} "
            f"peak_kv_ref_kib={st['peak_kv_bytes_referenced'] / 1024:.1f} "
            + extra)
        results.append({
            "name": name, "prefix_cache": pc, "sparsity": sparsity,
            "e2e_tok_per_s": st["e2e_tok_per_s"],
            "decode_tok_per_s": st["tok_per_s"],
            "prompt_tokens": st["prompt_tokens"],
            "prefill_tokens": st["prefill_tokens"],
            "prefill_tokens_skipped": st["prefill_tokens_skipped"],
            "prefix_hit_rate": st.get("prefix_hit_rate", 0.0),
            "prefix_hits": st["prefix_hits"],
            "cow_copies": st["cow_copies"],
            "cache_evicted_pages": st["cache_evicted_pages"],
            "peak_kv_bytes": st["peak_kv_bytes"],
            "peak_kv_bytes_referenced": st["peak_kv_bytes_referenced"],
            "queue_depth_peak": st["queue_depth_peak"],
            "admission_rejections": st["admission_rejections"],
            "queued_s_total": st["queued_s_total"],
            "queued_s_max": st["queued_s_max"],
        })


def _continuous_run(eng, prompts, new_tokens):
    """CONTINUOUS arrivals: submit one request per engine step (prompts
    land while other lanes decode — the workload where phased admission
    stalls running lanes), drain, finalize stats. ``new_tokens`` is a
    per-request budget list (RAGGED budgets desynchronize lane
    lifetimes, so admissions genuinely overlap running decode) or one
    int for all. Returns (uids, {uid: GenResult}, stats)."""
    budget = (new_tokens if isinstance(new_tokens, (list, tuple))
              else [new_tokens] * len(prompts))
    uids = [eng.submit(prompts[0], budget[0])]
    res, k, guard = {}, 1, 0
    while k < len(prompts) or eng.active_lanes or len(eng.scheduler):
        if k < len(prompts):
            uids.append(eng.submit(prompts[k], budget[k]))
            k += 1
        for r in eng.step():
            res[r.uid] = r
        guard += 1
        assert guard < 100_000, "engine failed to drain"
    eng.finalize_stats()
    return uids, res, dict(eng.stats)


def _mixed_stats(cfg, params, *, mixed: bool, n_req: int = 8,
                 max_batch: int = 4, max_len: int = 64,
                 new_tokens: int = 17, prefill_chunk: int = 8,
                 page_size: int = 8, reps: int = 3):
    """Continuous-arrival serving stats, mixed vs phased scheduling
    (same prompts, same weights, same arrival pattern). Best of
    ``reps`` measured passes by e2e tok/s; TTFT/ITL percentiles ride
    along from the same best pass."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=(int(n),))
               .astype(np.int32)
               for n in rng.integers(8, 25, size=n_req)]
    # ragged budgets: lanes free at DIFFERENT steps, so later arrivals
    # admit while a neighbour still decodes (the stall-or-fuse moment)
    budgets = [int(b) for b in
               rng.integers(max(2, new_tokens - 6), new_tokens + 1,
                            size=n_req)]
    eng = engine.Engine(cfg, params, max_batch=max_batch,
                        max_len=max_len, prefill_chunk=prefill_chunk,
                        slab_k=4, paged=True, page_size=page_size,
                        mixed=mixed)
    _continuous_run(eng, prompts, budgets)           # warm jit
    best = None
    for _ in range(reps):
        eng.reset_stats()
        _, _, st = _continuous_run(eng, prompts, budgets)
        if best is None or st["e2e_tok_per_s"] > best["e2e_tok_per_s"]:
            best = st
    return best


def _mixed_sweep(cfg, label: str, params, *, sparsity: float,
                 results: list, **kw) -> None:
    """Mixed vs phased under continuous arrivals: the rows carry the
    decode-stall counter (structurally 0 in mixed mode), fused-step
    count, and per-request TTFT / inter-token latency percentiles."""
    for mixed in (False, True):
        st = _mixed_stats(cfg, params, mixed=mixed, **kw)
        mode = "mixed" if mixed else "phased"
        name = f"engine_{label}_{mode}_arrivals"
        row(name, 1e6 / max(st["e2e_tok_per_s"], 1e-9),
            f"e2e_tok_per_s={st['e2e_tok_per_s']:.1f} "
            f"stalled_decode_steps={st['stalled_decode_steps']} "
            f"ttft_p95_ms={st['ttft_p95_s'] * 1e3:.1f} "
            f"itl_p95_ms={st['itl_p95_s'] * 1e3:.1f}")
        results.append({
            "name": name, "mixed": mixed, "sparsity": sparsity,
            "decode_tok_per_s": st["tok_per_s"],
            "e2e_tok_per_s": st["e2e_tok_per_s"],
            "stalled_decode_steps": st["stalled_decode_steps"],
            "mixed_steps": st["mixed_steps"],
            "prefill_chunks": st["prefill_chunks"],
            "prefill_tokens": st["prefill_tokens"],
            "decode_tokens": st["decode_tokens"],
            "ttft_p50_s": st["ttft_p50_s"],
            "ttft_p95_s": st["ttft_p95_s"],
            "itl_p50_s": st["itl_p50_s"],
            "itl_p95_s": st["itl_p95_s"],
            "queue_depth_peak": st["queue_depth_peak"],
            "queued_s_max": st["queued_s_max"],
        })


def _sla_trace_run(eng, batch_prompts, inter_prompts, *,
                   batch_budget: int, inter_budget: int):
    """The mixed interactive+batch arrival trace: every batch job is
    queued up front (saturating the lanes), interactive requests then
    arrive every other engine step mid-decode. The SAME submission
    script runs under every scheduler — FIFO simply ignores the
    priority tags. Returns (batch_uids, inter_uids, results, stats)."""
    uids_b = [eng.submit(p, batch_budget, priority=BATCH)
              for p in batch_prompts]
    uids_i, res, k, guard = [], {}, 0, 0
    while (eng.active_lanes or len(eng.scheduler) or eng._preempted
           or k < len(inter_prompts)):
        if k < len(inter_prompts) and guard % 2 == 1:
            uids_i.append(eng.submit(inter_prompts[k], inter_budget,
                                     priority=INTERACTIVE))
            k += 1
        for r in eng.step():
            res[r.uid] = r
        guard += 1
        assert guard < 100_000, "engine failed to drain"
    eng.finalize_stats()
    return uids_b, uids_i, res, dict(eng.stats)


def _frontdoor_stats(cfg, params, *, kind: str, n_batch: int = 6,
                     n_inter: int = 4, max_batch: int = 2,
                     max_len: int = 64, page_size: int = 8,
                     batch_budget: int = 17, inter_budget: int = 5,
                     seed: int = 5):
    """One scheduler flavor over the SLA trace: ``fifo`` (the parity
    baseline — priority tags ignored), ``sla`` (class-ordered
    admission), or ``sla_preempt`` (plus lane/page preemption with host
    KV offload). Returns (per-class TTFT p95s, results keyed by class,
    stats) from one measured pass after a jit-warm pass."""
    rng = np.random.default_rng(seed)
    batch_prompts = [rng.integers(0, cfg.vocab_size, size=(int(n),))
                     .astype(np.int32)
                     for n in rng.integers(8, 13, size=n_batch)]
    inter_prompts = [rng.integers(0, cfg.vocab_size, size=(int(n),))
                     .astype(np.int32)
                     for n in rng.integers(4, 9, size=n_inter)]
    if kind == "fifo":
        sched = FIFOScheduler(max_batch, max_len)
    else:
        sched = SLAScheduler(max_batch, max_len, aging_s=5.0)
    eng = engine.Engine(cfg, params, max_batch=max_batch,
                        max_len=max_len, prefill_chunk=8, slab_k=2,
                        page_size=page_size, scheduler=sched,
                        preempt=(kind == "sla_preempt"))
    kw = dict(batch_budget=batch_budget, inter_budget=inter_budget)
    _sla_trace_run(eng, batch_prompts, inter_prompts, **kw)  # warm jit
    eng.reset_stats()
    ub, ui, res, st = _sla_trace_run(eng, batch_prompts, inter_prompts,
                                     **kw)
    ttft = {
        "inter_p95": float(np.percentile(
            [res[u].ttft_s for u in ui], 95)),
        "batch_p95": float(np.percentile(
            [res[u].ttft_s for u in ub], 95)),
    }
    toks = {"batch": [res[u].generated.tolist() for u in ub],
            "inter": [res[u].generated.tolist() for u in ui]}
    return ttft, toks, st


def _frontdoor_sweep(cfg, label: str, params, *, sparsity: float,
                     results: list, **kw) -> None:
    """FIFO vs SLA vs SLA+preemption over the same interactive+batch
    trace: the rows carry per-class TTFT p95 and the preemption/offload
    counters, so the multi-tenant latency story is tracked PR over
    PR."""
    for kind in ("fifo", "sla", "sla_preempt"):
        ttft, _, st = _frontdoor_stats(cfg, params, kind=kind, **kw)
        name = f"engine_{label}_frontdoor_{kind}"
        row(name, 1e6 / max(st["e2e_tok_per_s"], 1e-9),
            f"e2e_tok_per_s={st['e2e_tok_per_s']:.1f} "
            f"ttft_p95_inter_ms={ttft['inter_p95'] * 1e3:.1f} "
            f"ttft_p95_batch_ms={ttft['batch_p95'] * 1e3:.1f} "
            f"preemptions={st['preemptions']}")
        results.append({
            "name": name, "scheduler": kind, "sparsity": sparsity,
            "e2e_tok_per_s": st["e2e_tok_per_s"],
            "decode_tok_per_s": st["tok_per_s"],
            "ttft_p95_interactive_s": ttft["inter_p95"],
            "ttft_p95_batch_s": ttft["batch_p95"],
            "ttft_p95_s": st["ttft_p95_s"],
            "preemptions": st["preemptions"],
            "restores": st["restores"],
            "offloaded_pages": st["offloaded_pages"],
            "restored_pages": st["restored_pages"],
            "preempt_pinned_pages": st["preempt_pinned_pages"],
            "offload_bytes_peak": st["offload_bytes_peak"],
            "prefill_tokens": st["prefill_tokens"],
            "queue_depth_peak": st["queue_depth_peak"],
            "admission_rejections": st["admission_rejections"],
            "admission_rejected_steps": st["admission_rejected_steps"],
            "queued_s_max": st["queued_s_max"],
        })


def _check_frontdoor_guarantees(cfg, params) -> None:
    """--smoke hard asserts for the production front door (acceptance
    criteria): (a) under the mixed interactive+batch trace, the SLA
    scheduler's interactive-class TTFT p95 is STRICTLY lower than plain
    FIFO's on the same trace; (b) with ``preempt=True`` the
    lane-blocked interactive head actually preempts batch lanes (>=1
    preemption, KV offloaded and restored) with ZERO re-prefilled
    tokens — prefill_tokens equal to the non-preempting run; and
    (c) greedy tokens are bitwise-identical across all three
    schedulers (admission ORDER changes, per-request streams must
    not)."""
    t_fifo, toks_fifo, st_fifo = _frontdoor_stats(cfg, params,
                                                  kind="fifo")
    t_sla, toks_sla, st_sla = _frontdoor_stats(cfg, params, kind="sla")
    t_pre, toks_pre, st_pre = _frontdoor_stats(cfg, params,
                                               kind="sla_preempt")
    assert toks_fifo == toks_sla == toks_pre
    assert t_sla["inter_p95"] < t_fifo["inter_p95"], (t_sla, t_fifo)
    assert st_pre["preemptions"] >= 1 and st_pre["restores"] >= 1, st_pre
    assert st_pre["prefill_tokens"] == st_sla["prefill_tokens"], \
        (st_pre["prefill_tokens"], st_sla["prefill_tokens"])
    assert st_pre["restored_pages"] == st_pre["offloaded_pages"], st_pre
    print("# frontdoor SLA/preempt OK: "
          f"ttft_p95_inter_fifo={t_fifo['inter_p95'] * 1e3:.1f}ms "
          f"sla={t_sla['inter_p95'] * 1e3:.1f}ms "
          f"preempt={t_pre['inter_p95'] * 1e3:.1f}ms "
          f"preemptions={st_pre['preemptions']} "
          f"offloaded_pages={st_pre['offloaded_pages']}")


def _check_no_starvation(cfg, params) -> None:
    """--smoke hard assert: the aging bound holds END TO END — a batch
    request under a sustained interactive flood (arrivals outpace
    service, the backlog never empties) is still admitted through the
    real engine, WHILE the flood continues, within the property-test
    bound scaled to the trace."""
    rng = np.random.default_rng(7)
    p_batch = rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32)
    eng = engine.Engine(cfg, params, max_batch=1, max_len=32,
                        prefill_chunk=8, slab_k=2, page_size=8,
                        scheduler=SLAScheduler(1, 32, aging_s=0.05))
    eng.submit(p_batch, 2, priority=BATCH)     # warm jit on both shapes
    eng.submit(rng.integers(0, cfg.vocab_size, size=(5,))
               .astype(np.int32), 2, priority=INTERACTIVE)
    eng.run()
    eng.reset_stats()
    ub = eng.submit(p_batch, 2, priority=BATCH)
    admitted = False
    for _ in range(300):
        # flood: one interactive per step, service <= 1 per 2 steps
        eng.submit(rng.integers(0, cfg.vocab_size, size=(5,))
                   .astype(np.int32), 2, priority=INTERACTIVE)
        res = eng.step()
        if (any(eng.lanes[i].req.uid == ub for i in eng.active_lanes)
                or any(r.uid == ub for r in res)):
            admitted = True
            break
    assert admitted, "batch request starved under interactive flood"
    # the flood NEVER let up: admission happened past the backlog, by
    # aging, not because the queue drained
    assert len(eng.scheduler) > 0
    print(f"# no-starvation OK: batch admitted with "
          f"{len(eng.scheduler)} interactive requests still queued")


def _check_async_guarantees(cfg, params) -> None:
    """--smoke hard asserts for the asyncio front end (the CI
    async-smoke job): continuous arrivals stream through
    ``AsyncEngine`` over the mixed engine under a WALL-CLOCK timeout,
    and (a) every stream's tokens equal its final GenResult and the
    synchronous engine's run of the same workload (bitwise), (b) zero
    stalled decode steps (the mixed guarantee must survive the thread
    hop), and (c) TTFT p95 bounded RELATIVE to the synchronous
    engine's on the same workload — the thread hop and inbox must not
    blow up time-to-first-token (a relative bound stays meaningful
    when the host is loaded; an absolute ceiling would flake)."""
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, size=(int(n),))
               .astype(np.int32) for n in rng.integers(6, 15, size=8)]
    budgets = [int(b) for b in rng.integers(4, 10, size=8)]

    def make():
        return engine.Engine(cfg, params, max_batch=2, max_len=64,
                             prefill_chunk=8, slab_k=4, page_size=8,
                             mixed=True,
                             scheduler=SLAScheduler(2, 64, aging_s=5.0))

    sync = make()
    uids = [sync.submit(p, b) for p, b in zip(prompts, budgets)]
    base = {u: r.generated.tolist() for u, r in sync.run().items()}
    want = [base[u] for u in uids]
    sync_ttft_p95 = sync.stats["ttft_p95_s"]

    eng = make()
    # warm the jit OUTSIDE the timed async drive (compile time is not
    # serving latency)
    for p, b in zip(prompts, budgets):
        eng.submit(p, b)
    eng.run()
    eng.reset_stats()

    async def drive():
        async with AsyncEngine(eng) as front:
            streams = []
            for i, (p, b) in enumerate(zip(prompts, budgets)):
                streams.append(await front.submit_async(
                    p, b, priority=i % 2))
                await asyncio.sleep(0.002)     # continuous arrivals
            got = []
            for s in streams:
                toks = []
                async for chunk in s:
                    toks.extend(chunk)
                res = await s.result()
                assert toks == res.generated.tolist()
                got.append(toks)
            return got

    got = asyncio.run(asyncio.wait_for(drive(), timeout=180.0))
    assert got == want, "async front end diverged from sync engine"
    assert eng.stats["stalled_decode_steps"] == 0, eng.stats
    # the sync run queues everything up front (worst-case backlog TTFT);
    # the async drive trickles arrivals, so 2x + scheduling slack is a
    # real regression bound for the thread hop, not headroom
    bound = 2.0 * sync_ttft_p95 + 0.25
    assert eng.stats["ttft_p95_s"] < bound, \
        (eng.stats["ttft_p95_s"], sync_ttft_p95)
    assert eng.stats["generated_tokens"] == sum(budgets)
    print("# async front end OK: "
          f"ttft_p95={eng.stats['ttft_p95_s'] * 1e3:.1f}ms "
          f"stalled_decode_steps={eng.stats['stalled_decode_steps']} "
          f"streams={len(got)}")


def _pool_balanced(eng) -> bool:
    pool = eng.pool
    return (pool.free_pages + pool.referenced + pool.cached_idle
            == pool.n_pages and pool.referenced == 0)


def _chaos_trace(cfg, params, *, seed: int = 5):
    """The chaos oracle workload (mirrors the slow chaos test): one
    seeded plan arms a NaN lane at step 2, a host-side engine-thread
    crash at step 4 (live KV salvaged to host RAM), and a bit-flip of
    the FIRST salvaged record. Driven through ``AsyncEngine`` so the
    watchdog monitor performs the recovery. Returns the fault-free
    baseline, the chaos results (GenResult or the structured error per
    request), and the stats + recovery log the rows and asserts read."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab_size, size=(int(n),))
               .astype(np.int32) for n in (7, 5, 9, 6)]

    eng0 = engine.Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                         page_size=4)
    buids = [eng0.submit(p, 12) for p in prompts]
    base = eng0.run()

    async def drive():
        plan = (FaultPlan(seed=seed).poison_logits(2, 1)
                .crash(4, device_lost=False)
                .corrupt_offload(nth_save=0))
        eng = engine.Engine(cfg, params, max_batch=2, max_len=48,
                            slab_k=4, page_size=4, faults=plan)
        front = AsyncEngine(eng, max_recoveries=2)
        async with front:
            streams = [await front.submit_async(p, 12) for p in prompts]
            results = {}
            for s in streams:
                try:
                    res = await s.result()
                except Exception as e:           # structured failure
                    results[s.uid] = e
                else:
                    results[res.uid] = res
        return eng, front, plan, results

    t0 = time.monotonic()
    eng, front, plan, got = asyncio.run(
        asyncio.wait_for(drive(), timeout=300.0))
    return {"eng": eng, "front": front, "plan": plan, "got": got,
            "base": base, "buids": buids,
            "elapsed_s": time.monotonic() - t0}


def _watchdog_trace(cfg, params, *, seed: int = 4):
    """The hung-step scenario: a jitted step stalls far past the
    watchdog deadline; the monitor condemns and tears down the stepper,
    the supervisor salvages every live lane's KV to host RAM, and the
    run completes with ZERO re-prefilled tokens (the acceptance
    criterion the bench records)."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab_size, size=(int(n),))
               .astype(np.int32) for n in (7, 5, 9)]

    eng0 = engine.Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                         page_size=4)
    buids = [eng0.submit(p, 12) for p in prompts]
    base = eng0.run()

    async def drive():
        eng = engine.Engine(cfg, params, max_batch=2, max_len=48,
                            slab_k=4, page_size=4,
                            faults=FaultPlan().stall(2, seconds=60.0))
        # generous deadline: a slow-but-progressing step must never
        # trip it (and a condemned step that is merely slow is treated
        # as a false alarm) — only the injected stall dies here
        front = AsyncEngine(eng, watchdog_s=2.0, max_recoveries=1)
        async with front:
            streams = [await front.submit_async(p, 12) for p in prompts]
            results = {r.uid: r
                       for r in [await s.result() for s in streams]}
        return eng, front, results

    t0 = time.monotonic()
    eng, front, got = asyncio.run(
        asyncio.wait_for(drive(), timeout=300.0))
    return {"eng": eng, "front": front, "got": got, "base": base,
            "buids": buids, "elapsed_s": time.monotonic() - t0}


def _shed_flood(cfg, params, *, limit: int = 4, n_flood: int = 40,
                budget: int = 4, seed: int = 13):
    """Load-shedding under a sustained flood: arrivals outpace service
    2 submits per engine step, the admission queue is bounded at
    ``limit``, and every overflow is rejected at submit time with a
    ``BackpressureError`` carrying a retry-after hint. Admitted
    requests must keep a bounded TTFT — the whole point of shedding is
    that the clients you DO accept are served promptly."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab_size, size=(int(n),))
               .astype(np.int32)
               for n in rng.integers(5, 9, size=n_flood)]
    eng = engine.Engine(cfg, params, max_batch=2, max_len=48,
                        prefill_chunk=8, slab_k=2, page_size=8,
                        scheduler=SLAScheduler(2, 48, aging_s=5.0),
                        admission_queue_limit=limit)
    for p in prompts[:2]:                      # warm the jit shapes
        eng.submit(p, budget, priority=INTERACTIVE)
    eng.run()
    eng.reset_stats()

    admitted, hints, res = [], [], {}
    i, guard = 0, 0
    t0 = time.monotonic()
    while i < n_flood or eng.active_lanes or len(eng.scheduler):
        for _ in range(2):                     # 2 arrivals per step
            if i >= n_flood:
                break
            try:
                admitted.append(eng.submit(prompts[i], budget,
                                           priority=INTERACTIVE))
            except BackpressureError as e:
                hints.append(e.retry_after_s)
            i += 1
        for r in eng.step():
            res[r.uid] = r
        guard += 1
        assert guard < 100_000, "flood failed to drain"
    elapsed = time.monotonic() - t0
    eng.finalize_stats()
    st = dict(eng.stats)
    ttft_p95 = float(np.percentile(
        [res[u].ttft_s for u in admitted], 95))
    # with a bounded queue, an admitted request waits behind at most
    # ``limit`` queued + ``max_batch`` running requests; 3x measured
    # per-request service + slack is a real bound, not headroom — an
    # unbounded queue would push TTFT toward n_flood * service
    service_s = elapsed / max(len(admitted), 1)
    bound_s = 3.0 * (limit + 2) * service_s + 0.5
    return {"eng": eng, "st": st, "admitted": admitted, "res": res,
            "hints": hints, "n_flood": n_flood, "limit": limit,
            "ttft_p95_s": ttft_p95, "service_s": service_s,
            "bound_s": bound_s, "elapsed_s": elapsed}


def _chaos_sweep(cfg, label: str, params, *, results: list):
    """Fault injection / recovery / load-shedding rows for
    ``BENCH_chaos.json``: recovery latency, re-prefilled tokens per
    recovery, zero-reprefill salvage counts, and the shed rate — so the
    fault-tolerance trajectory is tracked PR over PR. Returns the three
    measured traces for ``_check_chaos_guarantees`` (the rows land on
    disk BEFORE the asserts run)."""
    chaos = _chaos_trace(cfg, params)
    st, log = chaos["eng"].stats, chaos["front"].recovery_log
    failed = sum(isinstance(r, Exception)
                 for r in chaos["got"].values())
    lat = log[0]["latency_s"] if log else float("nan")
    row(f"engine_{label}_chaos_recovery", lat * 1e6,
        f"recoveries={st['recoveries']} faults={st['faults_injected']} "
        f"quarantined={st['lanes_quarantined']} "
        f"re_prefilled={st['re_prefilled_tokens']}")
    results.append({
        "name": f"engine_{label}_chaos_recovery",
        "faults_injected": st["faults_injected"],
        "lanes_quarantined": st["lanes_quarantined"],
        "recoveries": st["recoveries"],
        "engine_crashes": st["engine_crashes"],
        "watchdog_hangs": st["watchdog_hangs"],
        "recovery_latency_s": lat,
        "recovered_zero_reprefill": st["recovered_zero_reprefill"],
        "re_prefilled_tokens": st["re_prefilled_tokens"],
        "re_prefilled_tokens_per_recovery":
            st["re_prefilled_tokens"] / max(st["recoveries"], 1),
        "salvaged_lanes": log[0]["salvaged_lanes"] if log else 0,
        "failed_requests": failed,
        "survivor_requests": len(chaos["got"]) - failed,
        "elapsed_s": chaos["elapsed_s"],
    })

    wd = _watchdog_trace(cfg, params)
    st, log = wd["eng"].stats, wd["front"].recovery_log
    lat = log[0]["latency_s"] if log else float("nan")
    row(f"engine_{label}_chaos_watchdog", lat * 1e6,
        f"hangs={st['watchdog_hangs']} "
        f"salvaged={log[0]['salvaged_lanes'] if log else 0} "
        f"re_prefilled={st['re_prefilled_tokens']}")
    results.append({
        "name": f"engine_{label}_chaos_watchdog",
        "watchdog_hangs": st["watchdog_hangs"],
        "recoveries": st["recoveries"],
        "recovery_latency_s": lat,
        "recovered_zero_reprefill": st["recovered_zero_reprefill"],
        "re_prefilled_tokens": st["re_prefilled_tokens"],
        "salvaged_lanes": log[0]["salvaged_lanes"] if log else 0,
        "offload_bytes_peak": st["offload_bytes_peak"],
        "elapsed_s": wd["elapsed_s"],
    })

    shed = _shed_flood(cfg, params)
    st = shed["st"]
    row(f"engine_{label}_chaos_shed",
        shed["ttft_p95_s"] * 1e6,
        f"shed={st['shed_requests']}/{shed['n_flood']} "
        f"admitted={len(shed['admitted'])} "
        f"queue_peak={st['queue_depth_peak']} "
        f"ttft_p95_ms={shed['ttft_p95_s'] * 1e3:.1f}")
    results.append({
        "name": f"engine_{label}_chaos_shed",
        "flood_requests": shed["n_flood"],
        "admission_queue_limit": shed["limit"],
        "admitted": len(shed["admitted"]),
        "shed_requests": st["shed_requests"],
        "shed_rate": st["shed_requests"] / shed["n_flood"],
        "retry_after_mean_s":
            float(np.mean(shed["hints"])) if shed["hints"] else 0.0,
        "queue_depth_peak": st["queue_depth_peak"],
        "ttft_p95_admitted_s": shed["ttft_p95_s"],
        "ttft_bound_s": shed["bound_s"],
        "service_s_per_request": shed["service_s"],
        "elapsed_s": shed["elapsed_s"],
    })
    return chaos, wd, shed


def _obs_run(cfg, params, *, tracer=None, seed: int = 7):
    """One deterministic engine workload, optionally traced — the
    parity pair for the zero-overhead-tracing oracle."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab_size, size=(int(n),))
               .astype(np.int32) for n in (7, 5, 9, 6)]
    eng = engine.Engine(cfg, params, max_batch=2, max_len=48, slab_k=4,
                        page_size=4, tracer=tracer)
    for p in prompts:
        eng.submit(p, 12)
    return eng, eng.run()


def _obs_crash_postmortem(cfg, params, *, seed: int = 5):
    """A poisoned-lane + stepper-crash run with the flight recorder
    attached: the watchdog and the supervisor each freeze the span ring
    into a postmortem. Returns (tracer, victim uids, results)."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab_size, size=(int(n),))
               .astype(np.int32) for n in (7, 5, 9, 6)]
    tracer = Tracer(capacity=1024)

    async def drive():
        plan = (FaultPlan(seed=seed).poison_logits(2, 1)
                .crash(4, device_lost=False))
        eng = engine.Engine(cfg, params, max_batch=2, max_len=48,
                            slab_k=4, page_size=4, faults=plan,
                            tracer=tracer)
        front = AsyncEngine(eng, max_recoveries=2)
        async with front:
            streams = [await front.submit_async(p, 12) for p in prompts]
            results = {}
            for s in streams:
                try:
                    res = await s.result()
                except Exception as e:
                    results[s.uid] = e
                else:
                    results[res.uid] = res
        return results

    got = asyncio.run(asyncio.wait_for(drive(), timeout=300.0))
    uids = sorted(got)
    return tracer, uids, got


def _obs_sweep(cfg, label: str, params, *, results: list,
               trace_out: str, postmortem_out: str):
    """--obs-only rows for ``BENCH_obs.json``: (a) tracing parity — the
    same workload traced and untraced emits bitwise-identical tokens
    (spans attach only at existing host syncs); (b) a Prometheus
    exposition round-trip over the traced engine's registry; (c) a
    crash run whose flight recorder yields postmortems carrying the
    victims' span timelines. The Perfetto trace and the postmortem JSON
    land on disk BEFORE the asserts run (CI artifacts either way)."""
    eng_off, res_off = _obs_run(cfg, params)
    tracer = Tracer(capacity=4096)
    t0 = time.monotonic()
    eng_on, res_on = _obs_run(cfg, params, tracer=tracer)
    traced_s = time.monotonic() - t0
    a = {u: r.tokens for u, r in res_off.items()}
    b = {u: r.tokens for u, r in res_on.items()}
    bitwise = (set(a) == set(b)
               and all(np.array_equal(a[u], b[u]) for u in a))
    write_chrome_trace(trace_out, tracer.records)
    with open(trace_out) as f:
        n_events = len(json.load(f)["traceEvents"])
    prom = eng_on.metrics.prometheus_text()
    parsed = parse_prometheus_text(prom)
    snap = eng_on.metrics.snapshot()
    prom_ok = (parsed["blast_decode_tokens"] == snap["decode_tokens"]
               and parsed["blast_ttft_s_count"]
               == snap["ttft_s"]["count"])
    row(f"engine_{label}_obs_parity", traced_s * 1e6,
        f"bitwise={bitwise} spans={len(tracer.records)} "
        f"trace_events={n_events} prom_samples={len(parsed)}")
    results.append({
        "name": f"engine_{label}_obs_parity",
        "tokens_bitwise_identical": bitwise,
        "spans_recorded": len(tracer.records),
        "trace_events": n_events,
        "prometheus_samples": len(parsed),
        "prometheus_roundtrip_ok": prom_ok,
        "traced_run_s": traced_s,
    })

    pm_tracer, uids, got = _obs_crash_postmortem(cfg, params)
    pms = list(pm_tracer.postmortems)
    with open(postmortem_out, "w") as f:
        json.dump(pms, f, indent=2)
        f.write("\n")
    print(f"# wrote {postmortem_out} ({len(pms)} postmortems)")
    victim_spans = 0
    if pms:
        last = pms[-1]
        span_uids = {s["attrs"].get("uid") for s in last["spans"]} | {
            u for s in last["spans"]
            for u in (s["attrs"].get("uids") or ())}
        victim_spans = sum(u in span_uids for u in uids)
    row(f"engine_{label}_obs_postmortem", 0.0,
        f"postmortems={len(pms)} victims_with_spans={victim_spans}")
    results.append({
        "name": f"engine_{label}_obs_postmortem",
        "postmortems": len(pms),
        "postmortem_reasons": [p["reason"] for p in pms],
        "victims_with_spans": victim_spans,
        "requests": len(uids),
    })
    return {"bitwise": bitwise, "spans": len(tracer.records),
            "events": n_events, "prom_ok": prom_ok, "pms": pms,
            "victim_spans": victim_spans, "uids": uids}


def _check_obs_guarantees(obs) -> None:
    """--obs-only hard asserts: tracing changes no output bits, the
    Perfetto export is non-trivial, the exposition round-trips, and
    every crash postmortem carries a non-empty span timeline that
    includes the victims."""
    assert obs["bitwise"], "tracing changed emitted tokens"
    assert obs["spans"] > 0 and obs["events"] >= obs["spans"]
    assert obs["prom_ok"], "prometheus exposition did not round-trip"
    assert obs["pms"], "crash run produced no postmortem"
    assert all(p["spans"] for p in obs["pms"]), \
        "postmortem with an empty flight-recorder ring"
    assert obs["victim_spans"] > 0, \
        "no victim request appears in the postmortem timeline"
    print("obs guarantees OK")


def _swap_trace(cfg, packed_old, packed_new, art_dir, *, seed: int = 11,
                new_tokens: int = 16):
    """The measured hot-swap run: two streams admitted on the OLD
    weights, a mid-decode ``swap_weights`` to the sealed artifact, two
    more admitted post-flip — against two reference runs (pure-old and
    pure-new) for the bitwise oracle. Returns everything
    ``_check_swap_guarantees`` asserts on."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab_size, size=(int(n),))
               .astype(np.int32) for n in (6, 8, 5, 7)]

    def reference(params):
        eng = engine.Engine(cfg, params, max_batch=4, max_len=48,
                            slab_k=4, page_size=8)
        for p in prompts:
            eng.submit(p, new_tokens)
        out = {}
        while (len(eng.scheduler) or eng.active_lanes
               or eng._preempted or eng._pending_results):
            for r in eng.step():
                out[r.uid] = r
        return out

    base_old = reference(packed_old)
    base_new = reference(packed_new)

    eng = engine.Engine(cfg, packed_old, max_batch=4, max_len=48,
                        slab_k=4, page_size=8)
    out, step, rep = {}, 0, None
    tok_at_flip = tok_at_commit = None
    for p in prompts[:2]:
        eng.submit(p, new_tokens)
    t0 = time.monotonic()
    while (len(eng.scheduler) or eng.active_lanes or eng._preempted
           or eng._pending_results or step < 2):
        if step == 1:
            rep = eng.swap_weights(art_dir, monitor_steps=4)
            tok_at_flip = eng.stats["generated_tokens"]
            for p in prompts[2:]:
                eng.submit(p, new_tokens)
        for r in eng.step():
            out[r.uid] = r
        if (rep is not None and tok_at_commit is None
                and eng._swap_monitor is None):
            tok_at_commit = eng.stats["generated_tokens"]
        step += 1
    elapsed = time.monotonic() - t0
    if tok_at_commit is None:   # window outlived the workload
        while eng._swap_monitor is not None:
            eng.step()
        tok_at_commit = eng.stats["generated_tokens"]
    return {"eng": eng, "rep": rep, "out": out, "elapsed_s": elapsed,
            "base_old": base_old, "base_new": base_new,
            "n_req": len(prompts),
            "tokens_during_window": tok_at_commit - tok_at_flip}


def _swap_sweep(cfg, label: str, params, *, results: list):
    """--swap-only rows for ``BENCH_swap.json``: swap latency split
    (stage / canary / flip), canary cost in tokens and seconds, tokens
    served inside the monitoring window, and the dropped-request count
    (must be 0). The sealed artifact is built in a temp dir from a
    SECOND weight init so old and new generations genuinely differ."""
    import shutil
    import tempfile
    from repro.serving import artifact

    packed_new = _pack(cfg, registry.init_params(
        cfg, jax.random.PRNGKey(7)))
    d = tempfile.mkdtemp(prefix="blast_swap_bench_")
    art_dir = f"{d}/artifact"
    try:
        manifest = artifact.seal(cfg, packed_new, art_dir)
        tr = _swap_trace(cfg, params, packed_new, art_dir)
        eng, rep, out = tr["eng"], tr["rep"], tr["out"]
        dropped = (tr["n_req"] - len(out)
                   + sum(r.error is not None for r in out.values()))
        row(f"engine_{label}_swap_flip", rep.flip_s * 1e6,
            f"stage_ms={rep.stage_s * 1e3:.1f} "
            f"canary_ms={rep.canary_s * 1e3:.1f} "
            f"state={rep.state} dropped={dropped}")
        results.append({
            "name": f"engine_{label}_swap",
            "state": rep.state,
            "stage_s": rep.stage_s,
            "canary_s": rep.canary_s,
            "flip_s": rep.flip_s,
            "swap_total_s": rep.stage_s + rep.canary_s + rep.flip_s,
            "canary_tokens": eng.stats["swap_canary_tokens"],
            "canary_s_per_token": rep.canary_s / max(
                eng.stats["swap_canary_tokens"], 1),
            "n_canaries": len(manifest["canaries"]),
            "monitor_steps": rep.monitor_steps,
            "tokens_during_window": tr["tokens_during_window"],
            "requests": tr["n_req"],
            "dropped_requests": dropped,
            "quarantines": rep.quarantines,
            "weight_generations_held":
                eng.stats["weight_generations_held"],
            "elapsed_s": tr["elapsed_s"],
        })
        return tr
    finally:
        shutil.rmtree(d, ignore_errors=True)


def _check_swap_guarantees(tr) -> None:
    """--swap-only hard asserts, on the SAME trace the row was measured
    from: the swap commits, ZERO requests drop, old-generation streams
    are bitwise-identical to the no-swap reference, post-flip
    admissions to the pure-new-weights reference, the canaries actually
    cost tokens, and the old weights were freed once their last lane
    retired."""
    eng, rep, out = tr["eng"], tr["rep"], tr["out"]
    assert rep.state == "COMMITTED", rep.state
    assert sorted(out) == list(range(tr["n_req"])), sorted(out)
    assert all(r.error is None for r in out.values())
    for u in (0, 1):
        assert (out[u].generated.tolist()
                == tr["base_old"][u].generated.tolist()), u
    for u in (2, 3):
        assert (out[u].generated.tolist()
                == tr["base_new"][u].generated.tolist()), u
    assert eng.stats["weight_swaps"] == 1
    assert eng.stats["swap_rollbacks"] == 0
    assert eng.stats["swap_canary_tokens"] > 0
    assert tr["tokens_during_window"] > 0, \
        "no tokens served inside the monitoring window"
    assert eng.stats["weight_generations_held"] == 1
    print("# swap suite OK: "
          f"stage_ms={rep.stage_s * 1e3:.1f} "
          f"canary_ms={rep.canary_s * 1e3:.1f} "
          f"flip_ms={rep.flip_s * 1e3:.1f} "
          f"tokens_during_window={tr['tokens_during_window']} "
          f"dropped=0")


def _check_chaos_guarantees(chaos, wd, shed) -> None:
    """--chaos-only hard asserts (acceptance criteria), on the SAME
    traces the rows were measured from: (a) the chaos parity oracle —
    all three faults fire, exactly the poisoned lane and the corrupted
    record fail (structured ``LaneFaultError``s), every survivor is
    bitwise-identical to the fault-free run, and the page pool balances
    after recovery; (b) the watchdog tears down the hung step and the
    salvage restores >=1 lane from offloaded KV with ZERO re-prefilled
    tokens; (c) the flood keeps the queue bounded, every rejection
    carries a positive retry-after, and admitted requests' TTFT p95
    stays under the queue-depth service bound."""
    st, plan, got = chaos["eng"].stats, chaos["plan"], chaos["got"]
    assert len(plan.fired) == 3, plan.fired
    failed = {u: r for u, r in got.items() if isinstance(r, Exception)}
    assert len(failed) == 2, sorted(failed)
    assert all(isinstance(e, LaneFaultError) for e in failed.values())
    assert sum("checksum" in e.reason for e in failed.values()) == 1
    base, buids = chaos["base"], chaos["buids"]
    for u in sorted(u for u in got if u not in failed):
        assert (got[u].generated.tolist()
                == base[buids[u]].generated.tolist()), u
    assert st["faults_injected"] == 3, st
    assert st["lanes_quarantined"] == 2, st
    assert st["recoveries"] == 1 and st["engine_crashes"] == 1, st
    assert _pool_balanced(chaos["eng"])

    st, log, got = wd["eng"].stats, wd["front"].recovery_log, wd["got"]
    assert st["watchdog_hangs"] == 1 and st["recoveries"] == 1, st
    assert st["recovered_zero_reprefill"] >= 1, st
    assert st["re_prefilled_tokens"] == 0, st
    assert log and log[0]["salvaged_lanes"] >= 1, log
    for u in sorted(got):
        assert (got[u].generated.tolist()
                == wd["base"][wd["buids"][u]].generated.tolist()), u
    assert _pool_balanced(wd["eng"])

    st = shed["st"]
    assert st["shed_requests"] > 0, st
    assert st["shed_requests"] == len(shed["hints"])
    assert all(h > 0 for h in shed["hints"])
    assert st["queue_depth_peak"] <= shed["limit"], st
    assert all(shed["res"][u].ok for u in shed["admitted"])
    assert shed["ttft_p95_s"] < shed["bound_s"], \
        (shed["ttft_p95_s"], shed["bound_s"])
    print("# chaos suite OK: "
          f"recovery_latency_ms={chaos['front'].recovery_log[0]['latency_s'] * 1e3:.1f} "
          f"watchdog_salvaged={log[0]['salvaged_lanes']} "
          f"re_prefilled_after_hang={wd['eng'].stats['re_prefilled_tokens']} "
          f"shed={st['shed_requests']}/{shed['n_flood']} "
          f"ttft_p95_admitted_ms={shed['ttft_p95_s'] * 1e3:.1f}")


def _check_mixed_guarantees(cfg, params) -> None:
    """--smoke hard asserts for mixed batching, under continuous
    arrivals (one submit per step): (a) greedy tokens BITWISE-equal
    mixed vs phased vs the serve_loop oracle, (b) decode stalls
    ELIMINATED — the phased engine's stalled_decode_steps counter is
    positive on this workload, the mixed engine's is exactly 0, and
    (c) TTFT p95 no worse than phased, up to a bounded slack: on this
    CPU smoke model the per-call host-sync overhead the fused steps pay
    is the SAME order as the whole per-step compute (the economics that
    favor fusion on real accelerators invert), so the assert allows
    1.5x + 50 ms — it still fails hard on any real TTFT regression
    while the structural stall guarantee is asserted exactly. Both
    engines are jit-warmed and measured best-of-3, so the comparison is
    steady-state scheduling, not compile or scheduler noise."""
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=(int(n),))
               .astype(np.int32) for n in (8, 12, 6, 10, 9)]
    budgets = [9, 5, 11, 4, 8]      # ragged: lanes free asynchronously

    def run(mixed):
        eng = engine.Engine(cfg, params, max_batch=2, max_len=64,
                            prefill_chunk=8, slab_k=4, page_size=8,
                            mixed=mixed)
        _continuous_run(eng, prompts, budgets)       # warm jit
        best = None
        for _ in range(3):
            eng.reset_stats()
            uids, res, st = _continuous_run(eng, prompts, budgets)
            if best is None or st["ttft_p95_s"] < best[2]["ttft_p95_s"]:
                best = (uids, res, st)
        return best

    u0, res0, st0 = run(False)
    u1, res1, st1 = run(True)
    for a, b in zip(u0, u1):
        np.testing.assert_array_equal(res0[a].tokens, res1[b].tokens)
    want, _ = serve_loop.generate(cfg, params,
                                  jnp.asarray(prompts[0])[None],
                                  max_new_tokens=9, max_len=64)
    np.testing.assert_array_equal(res1[u1[0]].tokens, np.asarray(want)[0])
    assert st0["stalled_decode_steps"] > 0, st0
    assert st1["stalled_decode_steps"] == 0, st1
    assert st1["mixed_steps"] > 0, st1
    assert (st1["ttft_p95_s"]
            <= st0["ttft_p95_s"] * 1.5 + 0.05), (st1, st0)
    print("# mixed-vs-phased parity OK: "
          f"stalled_phased={st0['stalled_decode_steps']} "
          f"stalled_mixed={st1['stalled_decode_steps']} "
          f"ttft_p95_phased={st0['ttft_p95_s'] * 1e3:.1f}ms "
          f"ttft_p95_mixed={st1['ttft_p95_s'] * 1e3:.1f}ms")


def _check_prefix_guarantees(cfg, params) -> None:
    """--smoke hard asserts for the prefix cache: (a) greedy tokens
    BITWISE-equal sharing-on vs sharing-off on a common-system-prompt
    workload, (b) a real hit rate with prefill-token savings, and
    (c) the referenced-KV peak strictly under the no-sharing baseline
    (shared pages pinned once across lanes)."""
    rng = np.random.default_rng(2)
    sys_p = rng.integers(0, cfg.vocab_size, size=(24,)).astype(np.int32)
    prompts = [np.concatenate(
        [sys_p, rng.integers(0, cfg.vocab_size, size=(4,))
         .astype(np.int32)]) for _ in range(6)]

    def run(pc):
        eng = engine.Engine(cfg, params, max_batch=2, max_len=64,
                            prefill_chunk=8, slab_k=4, page_size=8,
                            prefix_cache=pc)
        if pc:          # warm the tree like a running server's would be
            eng.submit(sys_p, 1)
            eng.run()
            eng.reset_stats()
        uids = [eng.submit(p, 7) for p in prompts]
        res = eng.run()
        return [res[u].tokens for u in uids], eng.stats

    off, st_off = run(False)
    on, st_on = run(True)
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a, b)
    assert st_on["prefix_hit_rate"] > 0, st_on
    assert st_on["prefill_tokens"] < st_off["prefill_tokens"], st_on
    assert (st_on["peak_kv_bytes_referenced"]
            < st_off["peak_kv_bytes_referenced"]), (st_on, st_off)
    print("# prefix-cache parity OK: "
          f"hit_rate={st_on['prefix_hit_rate']:.2f} "
          f"prefill_tokens={st_on['prefill_tokens']} "
          f"(baseline {st_off['prefill_tokens']}) "
          f"peak_kv_ref={st_on['peak_kv_bytes_referenced']} "
          f"(baseline {st_off['peak_kv_bytes_referenced']})")


def _check_paged_guarantees(cfg, params) -> None:
    """--smoke hard asserts: the paged engine is not just fast, it is
    CORRECT (bitwise token parity with the contiguous engine) and
    actually SPARSE in its reads (block-table gather touches fewer
    pages than a dense max_len scan at short live lengths)."""
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=(int(n),))
               .astype(np.int32) for n in (8, 12, 6, 9)]
    kw = dict(max_new_tokens=9, max_len=128, prefill_chunk=8, slab_k=4,
              max_batch=2)
    dense, _ = engine.generate(cfg, params, prompts, paged=False, **kw)
    paged, st = engine.generate(cfg, params, prompts, paged=True,
                                page_size=8, **kw)
    for a, b in zip(dense, paged):
        np.testing.assert_array_equal(a, b)
    assert 0 < st["pages_read"] < st["pages_read_dense_equiv"], st
    assert st["peak_kv_bytes"] < st["kv_bytes_contiguous_equiv"], st
    print("# paged-vs-contiguous parity OK: "
          f"pages_read={st['pages_read']} "
          f"dense_equiv={st['pages_read_dense_equiv']} "
          f"peak_kv_bytes={st['peak_kv_bytes']} "
          f"contig_bytes={st['kv_bytes_contiguous_equiv']}")


def main(smoke: bool = False, out: str = "BENCH_serving.json",
         mixed_only: bool = False, frontdoor_only: bool = False,
         chaos_only: bool = False, obs_only: bool = False,
         swap_only: bool = False,
         trace_out: str = "BENCH_obs_trace.json",
         postmortem_out: str = "BENCH_obs_postmortem.json"):
    results: list[dict] = []
    check = None
    chaos_payload = None
    obs_payload = None
    swap_payload = None
    if (smoke or mixed_only or frontdoor_only or chaos_only or obs_only
            or swap_only):
        # tiny config through the REAL dispatch path: decode slabs,
        # per-lane frontiers, paged pool, packed XLA-backend kernels
        cfg = bench_cfg(num_layers=1, d_model=64, d_ff=128,
                        vocab_size=128, num_heads=2, num_kv_heads=2)
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        check = (cfg, params)
        if swap_only:
            # sparse packed weights: the swap moves REAL artifacts
            # (packed BCSC leaves, canaries, checksums), not toys
            scfg = replace_blast(cfg, s_init=0.7, s_max=0.7)
            packed = _pack(scfg, registry.init_params(
                scfg, jax.random.PRNGKey(0)))
            swap_payload = _swap_sweep(scfg, "packed_s70", packed,
                                       results=results)
        elif chaos_only:
            chaos_payload = _chaos_sweep(cfg, "dense", params,
                                         results=results)
        elif obs_only:
            obs_payload = _obs_sweep(cfg, "dense", params,
                                     results=results,
                                     trace_out=trace_out,
                                     postmortem_out=postmortem_out)
        elif frontdoor_only:
            _frontdoor_sweep(cfg, "dense", params, sparsity=0.0,
                             results=results)
        elif not mixed_only:
            for paged in (True, False):
                _serving_sweep(cfg, "dense", params, sparsity=0.0,
                               results=results, slab_sizes=(1, 4),
                               n_req=4, max_batch=2, new_tokens=9,
                               paged=paged)
            scfg = replace_blast(cfg, s_init=0.7, s_max=0.7)
            packed = _pack(scfg, registry.init_params(
                scfg, jax.random.PRNGKey(0)))
            _serving_sweep(scfg, "packed_s70", packed, sparsity=0.7,
                           results=results, ragged=True,
                           slab_sizes=(1, 4), n_req=4, max_batch=2,
                           new_tokens=9)
            _prefix_sweep(cfg, "dense", params, sparsity=0.0,
                          results=results, n_req=4, max_batch=2,
                          sys_len=24, sfx_len=4, new_tokens=5)
            _frontdoor_sweep(cfg, "dense", params, sparsity=0.0,
                             results=results, n_batch=4, n_inter=3,
                             batch_budget=13)
        if not (frontdoor_only or chaos_only or obs_only or swap_only):
            _mixed_sweep(cfg, "dense", params, sparsity=0.0,
                         results=results, n_req=6, max_batch=2,
                         new_tokens=9, prefill_chunk=4, reps=2)
    else:
        cfg = bench_cfg(num_layers=2)
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        B, MAX = 8, 64
        cache = registry.init_cache(cfg, B, MAX, dtype=jnp.float32)
        tok = jnp.zeros((B, 1), jnp.int32)
        step = jax.jit(lambda p, c, t, i:
                       registry.decode_step(cfg, p, c, t, i)[0])
        t_dense = timeit(step, params, cache, tok, jnp.int32(3))
        row("decode_dense", t_dense, "baseline")
        for b in (16, 32):
            for s in (0.7, 0.9, 0.95):
                t = _one(cfg, s, b)
                row(f"decode_b{b}_s{int(s*100)}", t,
                    f"speedup={t_dense / t:.2f}x")

        # ---- end-to-end serving throughput across decode slab sizes,
        # paged pool vs contiguous slab (same workload, same weights)
        for paged in (True, False):
            _serving_sweep(cfg, "dense", params, sparsity=0.0,
                           results=results, paged=paged)
        scfg = replace_blast(cfg, b_in=32, b_out=32, s_init=0.9,
                             s_max=0.9)
        sparams = registry.init_params(scfg, jax.random.PRNGKey(0))
        packed = _pack(scfg, sparams)
        for paged in (True, False):
            _serving_sweep(scfg, "packed_s90", packed, sparsity=0.9,
                           results=results, paged=paged)
        _serving_sweep(scfg, "packed_s90", packed, sparsity=0.9,
                       results=results, ragged=True)
        # ---- shared-prefix workload: radix-tree page sharing on/off
        _prefix_sweep(cfg, "dense", params, sparsity=0.0,
                      results=results)
        _prefix_sweep(scfg, "packed_s90", packed, sparsity=0.9,
                      results=results)
        # ---- continuous arrivals: mixed vs phased scheduling
        _mixed_sweep(cfg, "dense", params, sparsity=0.0,
                     results=results)
        _mixed_sweep(scfg, "packed_s90", packed, sparsity=0.9,
                     results=results)
        # ---- multi-tenant trace: FIFO vs SLA vs SLA+preemption
        _frontdoor_sweep(cfg, "dense", params, sparsity=0.0,
                         results=results)
        _frontdoor_sweep(scfg, "packed_s90", packed, sparsity=0.9,
                         results=results)

    write_bench_artifact(
        out,
        "swap" if swap_only else "chaos" if chaos_only
        else "obs" if obs_only else "serving",
        results,
        smoke=(smoke or mixed_only or frontdoor_only or chaos_only
               or obs_only or swap_only))
    if check is not None:
        # hard asserts AFTER the artifact lands on disk, so the CI
        # upload preserves the measured rows even when parity breaks —
        # exactly the runs where the trajectory matters most
        if swap_only:
            _check_swap_guarantees(swap_payload)
            return
        if chaos_only:
            _check_chaos_guarantees(*chaos_payload)
            return
        if obs_only:
            _check_obs_guarantees(obs_payload)
            return
        if frontdoor_only:
            _check_frontdoor_guarantees(*check)
            _check_no_starvation(*check)
            _check_async_guarantees(*check)
            return
        if not mixed_only:
            _check_paged_guarantees(*check)
            _check_prefix_guarantees(*check)
            _check_frontdoor_guarantees(*check)
            _check_no_starvation(*check)
            _check_async_guarantees(*check)
        _check_mixed_guarantees(*check)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + small workload (CI dispatch-"
                         "path guard incl. paged-vs-contiguous parity)")
    ap.add_argument("--mixed-only", action="store_true",
                    help="just the mixed-vs-phased continuous-arrival "
                         "sweep + its hard asserts (CI mixed-smoke job)")
    ap.add_argument("--frontdoor-only", action="store_true",
                    help="just the FIFO-vs-SLA-vs-preempt front-door "
                         "sweep + async/SLA/no-starvation hard asserts "
                         "(CI async-smoke job)")
    ap.add_argument("--chaos-only", action="store_true",
                    help="just the fault-injection suite: chaos parity "
                         "oracle, watchdog hang recovery, load-shed "
                         "flood + their hard asserts, writing "
                         "BENCH_chaos.json (CI chaos-smoke job)")
    ap.add_argument("--obs-only", action="store_true",
                    help="just the observability suite: traced-vs-"
                         "untraced bitwise parity, Prometheus round-"
                         "trip, Perfetto export + crash postmortem "
                         "artifacts (CI obs-smoke job)")
    ap.add_argument("--swap-only", action="store_true",
                    help="just the hot-swap suite: seal a second-init "
                         "artifact, swap mid-decode, assert the "
                         "bitwise zero-drop oracle, writing "
                         "BENCH_swap.json (CI swap-smoke job)")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--trace-out", default="BENCH_obs_trace.json",
                    help="Perfetto/Chrome trace artifact (--obs-only)")
    ap.add_argument("--postmortem-out",
                    default="BENCH_obs_postmortem.json",
                    help="flight-recorder dump artifact (--obs-only)")
    args = ap.parse_args()
    main(smoke=args.smoke, out=args.out, mixed_only=args.mixed_only,
         frontdoor_only=args.frontdoor_only, chaos_only=args.chaos_only,
         obs_only=args.obs_only, swap_only=args.swap_only,
         trace_out=args.trace_out,
         postmortem_out=args.postmortem_out)
