"""Paper Fig. 6 — end-to-end inference speedup (sparse vs dense serving)
across block sizes and sparsity levels, CPU-scale model. Two sections:
the jitted decode-step micro-bench, and end-to-end tokens/s through the
continuous-batching engine (ragged prompts, chunked batched prefill)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, replace_blast, row, timeit
from repro.core.prune_grow import initial_mask
from repro.models import registry
from repro.serving import engine, export


def _pack(cfg, params):
    masks = {}
    import dataclasses as dc
    from repro.core import sparse_mlp as sm
    for path in registry.sparse_paths(cfg):
        w = sm.get_path(params, path)
        bi, bo = sm.block_dims_for(cfg.blast, path)
        pspec = dc.replace(cfg.blast, b_in=bi, b_out=bo)
        masks[path] = initial_mask(pspec, w)
    return export.pack_params(cfg, params, masks, dtype=jnp.float32)


def _one(cfg, sparsity, b):
    cfg = replace_blast(cfg, b_in=b, b_out=b, s_init=sparsity,
                        s_max=sparsity)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    packed = _pack(cfg, params)
    B, MAX = 8, 64
    cache = registry.init_cache(cfg, B, MAX, dtype=jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, i:
                   registry.decode_step(cfg, p, c, t, i)[0])
    return timeit(step, packed, cache, tok, jnp.int32(3))


def _engine_tok_per_s(cfg, params, *, ragged: bool) -> float:
    """End-to-end tokens/s through the continuous-batching engine
    (8 requests over 4 lanes exercises admission + slot reuse)."""
    rng = np.random.default_rng(0)
    lens = rng.integers(8, 17, size=8) if ragged else [16] * 8
    prompts = [rng.integers(0, cfg.vocab_size, size=(int(p),))
               .astype(np.int32) for p in lens]
    # one Engine for both passes: its jitted steps are per-instance, so
    # the warm-up pass must run on the instance being measured
    eng = engine.Engine(cfg, params, max_batch=4, max_len=48,
                        prefill_chunk=8)
    for p in prompts:
        eng.submit(p, 16)
    eng.run()                               # warm jit
    eng.reset_stats()
    for p in prompts:
        eng.submit(p, 16)
    eng.run()                               # measured
    return eng.stats["e2e_tok_per_s"]


def main():
    cfg = bench_cfg(num_layers=2)
    # dense baseline = sparsity 0 packed? use raw dense params
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    B, MAX = 8, 64
    cache = registry.init_cache(cfg, B, MAX, dtype=jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, i:
                   registry.decode_step(cfg, p, c, t, i)[0])
    t_dense = timeit(step, params, cache, tok, jnp.int32(3))
    row("decode_dense", t_dense, "baseline")
    for b in (16, 32):
        for s in (0.7, 0.9, 0.95):
            t = _one(cfg, s, b)
            row(f"decode_b{b}_s{int(s*100)}", t,
                f"speedup={t_dense / t:.2f}x")

    # ---- end-to-end serving throughput through the engine
    tps = _engine_tok_per_s(cfg, params, ragged=False)
    row("engine_dense", 1e6 / max(tps, 1e-9), f"e2e_tok_per_s={tps:.1f}")
    scfg = replace_blast(cfg, b_in=32, b_out=32, s_init=0.9, s_max=0.9)
    sparams = registry.init_params(scfg, jax.random.PRNGKey(0))
    packed = _pack(scfg, sparams)
    tps_p = _engine_tok_per_s(scfg, packed, ragged=False)
    row("engine_packed_s90", 1e6 / max(tps_p, 1e-9),
        f"e2e_tok_per_s={tps_p:.1f}")
    tps_r = _engine_tok_per_s(scfg, packed, ragged=True)
    row("engine_packed_s90_ragged", 1e6 / max(tps_r, 1e-9),
        f"e2e_tok_per_s={tps_r:.1f}")


if __name__ == "__main__":
    main()
