"""Paper Fig. 6 — end-to-end inference speedup (sparse vs dense serving)
across block sizes and sparsity levels, CPU-scale model."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import bench_cfg, replace_blast, row, timeit
from repro.core.prune_grow import initial_mask
from repro.models import registry
from repro.serving import export


def _one(cfg, sparsity, b):
    cfg = replace_blast(cfg, b_in=b, b_out=b, s_init=sparsity,
                        s_max=sparsity)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    masks = {}
    import dataclasses as dc
    from repro.core import sparse_mlp as sm
    for path in registry.sparse_paths(cfg):
        w = sm.get_path(params, path)
        bi, bo = sm.block_dims_for(cfg.blast, path)
        pspec = dc.replace(cfg.blast, b_in=bi, b_out=bo)
        masks[path] = initial_mask(pspec, w)
    packed = export.pack_params(cfg, params, masks, dtype=jnp.float32)
    B, MAX = 8, 64
    cache = registry.init_cache(cfg, B, MAX, dtype=jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, i:
                   registry.decode_step(cfg, p, c, t, i)[0])
    return timeit(step, packed, cache, tok, jnp.int32(3))


def main():
    cfg = bench_cfg(num_layers=2)
    # dense baseline = sparsity 0 packed? use raw dense params
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    B, MAX = 8, 64
    cache = registry.init_cache(cfg, B, MAX, dtype=jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, i:
                   registry.decode_step(cfg, p, c, t, i)[0])
    t_dense = timeit(step, params, cache, tok, jnp.int32(3))
    row("decode_dense", t_dense, "baseline")
    for b in (16, 32):
        for s in (0.7, 0.9, 0.95):
            t = _one(cfg, s, b)
            row(f"decode_b{b}_s{int(s*100)}", t,
                f"speedup={t_dense / t:.2f}x")


if __name__ == "__main__":
    main()
