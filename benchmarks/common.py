"""Shared benchmark utilities. Every benchmark prints
``name,us_per_call,derived`` CSV rows (task spec)."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.prune_grow import BlastSpec


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (jit'd fn)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")


def bench_cfg(**overrides) -> ModelConfig:
    """The CPU-scale GPT2-ish model used by the paper-table benchmarks."""
    base = dict(
        name="bench", family="dense", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=4, head_dim=32, d_ff=512,
        vocab_size=512, mlp_kind="mlp2", mlp_act="gelu",
        norm_kind="layernorm", tie_embeddings=True, remat=False,
        compute_dtype="float32", chunk_size=32,
        blast=BlastSpec(enabled=True, b_in=32, b_out=32, s_max=0.7,
                        total_steps=60, step_size=10, dense_last=1,
                        decay=0),
    )
    base.update(overrides)
    return ModelConfig(**base)


def replace_blast(cfg, **kw):
    return dataclasses.replace(cfg, blast=dataclasses.replace(cfg.blast,
                                                              **kw))
