"""Shared benchmark utilities. Every benchmark prints
``name,us_per_call,derived`` CSV rows (task spec), and every
``BENCH_*.json`` artifact goes through ``write_bench_artifact`` so CI
runs are comparable across commits: each file carries the same
provenance stamp (git sha, jax/jaxlib versions, device kind, UTC
timestamp)."""
from __future__ import annotations

import dataclasses
import datetime
import json
import subprocess
import time
from collections.abc import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.prune_grow import BlastSpec


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (jit'd fn)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")


def bench_cfg(**overrides) -> ModelConfig:
    """The CPU-scale GPT2-ish model used by the paper-table benchmarks."""
    base = dict(
        name="bench", family="dense", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=4, head_dim=32, d_ff=512,
        vocab_size=512, mlp_kind="mlp2", mlp_act="gelu",
        norm_kind="layernorm", tie_embeddings=True, remat=False,
        compute_dtype="float32", chunk_size=32,
        blast=BlastSpec(enabled=True, b_in=32, b_out=32, s_max=0.7,
                        total_steps=60, step_size=10, dense_last=1,
                        decay=0),
    )
    base.update(overrides)
    return ModelConfig(**base)


def replace_blast(cfg, **kw):
    return dataclasses.replace(cfg, blast=dataclasses.replace(cfg.blast,
                                                              **kw))


# ------------------------------------------------------------ artifacts
def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
    except Exception:
        return "unknown"


def provenance() -> dict:
    """The stamp every BENCH_*.json carries (who/what/where/when)."""
    try:
        import jaxlib
        jaxlib_version = jaxlib.__version__
    except Exception:
        jaxlib_version = "unknown"
    dev = jax.devices()[0]
    return {
        "git_sha": _git_sha(),
        "jax_version": jax.__version__,
        "jaxlib_version": jaxlib_version,
        "device_kind": getattr(dev, "device_kind", dev.platform),
        "platform": dev.platform,
        "timestamp_unix": time.time(),
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(),
    }


def _json_safe(x):
    """Coerce Mapping facades / numpy scalars that land in rows."""
    if isinstance(x, Mapping):
        return dict(x)
    if isinstance(x, np.generic):
        return x.item()
    if isinstance(x, np.ndarray):
        return x.tolist()
    raise TypeError(f"not JSON serializable: {type(x).__name__}")


def write_bench_artifact(out: str, bench: str, rows: list,
                         **extra) -> dict:
    """Write a BENCH_*.json with the unified schema:
    ``{"bench", "provenance", "rows", **extra}``. Rows may contain
    StatsView/numpy values. Returns the written payload."""
    payload = {"bench": bench, "provenance": provenance(),
               "rows": rows, **extra}
    with open(out, "w") as f:
        json.dump(payload, f, indent=2, default=_json_safe)
        f.write("\n")
    print(f"# wrote {out} ({len(rows)} rows)")
    return payload
