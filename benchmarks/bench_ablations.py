"""Paper ablations:
  Table 4 — block size b x perplexity (incl. 1x1 unstructured baseline);
  Table 5 — step_size robustness;
  Table 6 — decay d;
  Fig. 11 — dense-last-L placement;
  plus the TPU-adaptation ablation: balanced vs global selection.
"""
from __future__ import annotations

from benchmarks.common import bench_cfg, replace_blast, row
from benchmarks.bench_pretrain import run

STEPS = 50


def block_size():
    for b in (8, 16, 32):
        cfg = replace_blast(bench_cfg(), b_in=b, b_out=b, s_max=0.7,
                            total_steps=STEPS, step_size=1)
        tw, ppl, sp = run(cfg, STEPS)
        row(f"tbl4_block_{b}x{b}", tw * 1e6 / STEPS,
            f"ppl={ppl:.2f} sparsity={sp:.2f}")


def step_size():
    for ss in (1, 5, 10, 25):
        cfg = replace_blast(bench_cfg(), step_size=ss, s_max=0.7,
                            total_steps=STEPS)
        tw, ppl, sp = run(cfg, STEPS)
        row(f"tbl5_stepsize_{ss}", tw * 1e6 / STEPS, f"ppl={ppl:.2f}")


def decay():
    for d in (0, 10, 25):
        cfg = replace_blast(bench_cfg(), decay=d, s_max=0.7,
                            total_steps=STEPS)
        tw, ppl, sp = run(cfg, STEPS)
        row(f"tbl6_decay_{d}", tw * 1e6 / STEPS,
            f"ppl={ppl:.2f} sparsity={sp:.2f}")


def dense_last():
    for L in (0, 1, 2):
        cfg = replace_blast(bench_cfg(), dense_last=L, s_max=0.7,
                            total_steps=STEPS)
        tw, ppl, sp = run(cfg, STEPS)
        row(f"fig11_denseL_{L}", tw * 1e6 / STEPS,
            f"ppl={ppl:.2f} sparsity={sp:.2f}")


def selection():
    for sel in ("balanced", "global"):
        cfg = replace_blast(bench_cfg(), selection=sel, s_max=0.7,
                            total_steps=STEPS)
        tw, ppl, sp = run(cfg, STEPS)
        row(f"sel_{sel}", tw * 1e6 / STEPS, f"ppl={ppl:.2f}")


def main():
    block_size()
    step_size()
    decay()
    dense_last()
    selection()


if __name__ == "__main__":
    main()
