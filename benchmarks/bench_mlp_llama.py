"""Paper Fig. 5 — MLP-layer speedup across the Llama family (1B..405B
dims) at BLaST sparsities, and Fig. 7 — weight memory / #accelerators.

The MLP dims are exact (configs/paper_models.LLAMA_FAMILY_MLP); the
token batch is CPU-scale. Derived columns report the FLOP-bound speedup
(the TPU expectation) and the packed-weight memory ratio."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.configs.paper_models import LLAMA_FAMILY_MLP
from repro.core import packing, topk
from repro.core.prune_grow import BlastSpec, generate_mask
from repro.kernels import ops


def main():
    key = jax.random.PRNGKey(1)
    tokens = 64
    for name, (d, f) in LLAMA_FAMILY_MLP.items():
        # scale dims down 8x for CPU wall-clock, keep the RATIO exact
        ds, fs = d // 8, f // 8
        x = jax.random.normal(key, (tokens, ds), jnp.float32)
        ws = [jax.random.normal(jax.random.PRNGKey(i), shape) * 0.05
              for i, shape in enumerate([(ds, fs), (ds, fs), (fs, ds)])]
        f_dense = jax.jit(lambda x, a, b, c:
                          (jax.nn.silu(x @ a) * (x @ b)) @ c)
        t_dense = timeit(f_dense, x, *ws)
        for s in (0.7, 0.8, 0.9, 0.95):
            spec = BlastSpec(b_in=32, b_out=32, s_max=s, total_steps=1)
            packs = []
            dense_bytes = packed_bytes = 0
            for i, w in enumerate(ws):
                m = generate_mask(spec, w, w, 1)
                wm = topk.apply_block_mask(w, m, 32, 32)
                p = packing.pack(wm, m, 32, 32)
                packs.append(p)
                dense_bytes += w.size * 2            # bf16 serving
                packed_bytes += (p.blocks.size * 2
                                 + p.idx.size * 4)
            f_sp = jax.jit(lambda x: ops.sparse_mlp_apply(
                x, packs[0], packs[1], packs[2]))
            t_sp = timeit(f_sp, x)
            flops_d = 3 * ops.flops_dense(tokens, ds, fs)
            flops_s = (2 * ops.flops_bspmm(tokens, packs[0])
                       + ops.flops_bspmm(tokens, packs[2]))
            row(f"mlp_{name}_s{int(s*100)}", t_sp,
                f"speedup={t_dense/t_sp:.2f}x "
                f"roofline_speedup={flops_d/max(flops_s,1):.2f}x "
                f"mem_ratio={dense_bytes/max(packed_bytes,1):.2f}x")
        row(f"mlp_{name}_dense", t_dense, "baseline")
    # Fig. 7: full-model weight memory -> #GPUs (exact dims, no alloc)
    for name, (d, f) in LLAMA_FAMILY_MLP.items():
        layers = {"llama3.2-1b": 16, "llama3.2-3b": 28, "llama3.1-8b": 32,
                  "llama3.1-70b": 80, "llama3.1-405b": 126}[name]
        mlp = 3 * d * f * layers
        other = (4 * d * d) * layers + 2 * 128_256 * d
        for s in (0.0, 0.7, 0.95):
            fp32 = 4 * (other + mlp * (1 - s))
            gpus = int(np.ceil(fp32 / (96 * 2**30)))
            row(f"gpus_{name}_s{int(s*100)}", 0.0,
                f"fp32_GiB={fp32/2**30:.1f} gpus96GB={gpus}")


if __name__ == "__main__":
    main()
