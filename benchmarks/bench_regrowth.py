"""Paper Fig. 10 — proportion of regrown blocks per refresh across block
sizes (the indicator of pruning/optimization-direction consistency)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, replace_blast, row
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.training import step as ts


def main():
    for b in (8, 16, 32):
        cfg = replace_blast(bench_cfg(num_layers=2), b_in=b, b_out=b,
                            s_max=0.7, total_steps=40, step_size=5)
        src = SyntheticLM(cfg.vocab_size, seq_len=64, global_batch=16,
                          seed=7)
        opt = adamw.AdamWConfig(peak_lr=3e-3, total_steps=40,
                                warmup_steps=2)
        step_fn = jax.jit(ts.make_train_step(cfg, opt))
        state = ts.init_state(cfg, jax.random.PRNGKey(0))
        prev = {k: np.asarray(v) for k, v in state.masks.items()}
        ratios = []
        for i in range(40):
            batch = {k: jnp.asarray(v) for k, v in src.batch(i).items()}
            state, _ = step_fn(state, batch)
            if (i + 1) % 5 == 0:
                cur = {k: np.asarray(v) for k, v in state.masks.items()}
                grown = sum(int((c & ~p).sum())
                            for c, p in zip(cur.values(), prev.values()))
                total = sum(int(c.size) for c in cur.values())
                ratios.append(grown / total)
                prev = cur
        row(f"fig10_regrow_b{b}", 0.0,
            f"mean_ratio={np.mean(ratios):.4f} "
            f"max_ratio={np.max(ratios):.4f}")


if __name__ == "__main__":
    main()
