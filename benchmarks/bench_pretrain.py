"""Paper Table 2 / Fig. 8 — pretraining: end-to-end time + perplexity,
BLaST vs dense, on the synthetic corpus (OpenWebText stand-in)."""
from __future__ import annotations

import math
import time

import numpy as np

from benchmarks.common import bench_cfg, replace_blast, row
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.training import train_loop


def run(cfg, steps=60, seed=3):
    src = SyntheticLM(cfg.vocab_size, seq_len=64, global_batch=16,
                      seed=seed)
    opt = adamw.AdamWConfig(peak_lr=3e-3, warmup_steps=5,
                            total_steps=steps, weight_decay=0.01)
    loop = train_loop.TrainLoopConfig(total_steps=steps, log_every=steps)
    t0 = time.time()
    state, hist = train_loop.train(cfg, opt, src, loop,
                                   log_fn=lambda m: None)
    wall = time.time() - t0
    # eval perplexity on held-out batches
    import jax, jax.numpy as jnp
    from repro.core.distill import cross_entropy
    from repro.models import registry
    losses = []
    for i in range(3):
        b = src.batch(10_000 + i)
        logits, _ = registry.forward(cfg, state.params,
                                     jnp.asarray(b["tokens"]),
                                     masks=state.masks or None)
        losses.append(float(cross_entropy(logits,
                                          jnp.asarray(b["labels"]))))
    ppl = math.exp(np.mean(losses))
    return wall, ppl, hist[-1]["sparsity"]


def main():
    steps = 60
    dense = bench_cfg()
    dense = replace_blast(dense, enabled=False)
    tw, ppl, _ = run(dense, steps)
    row("pretrain_dense", tw * 1e6 / steps, f"ppl={ppl:.2f}")
    for s_max, d in ((0.7, 0), (0.8, 20)):
        cfg = bench_cfg()
        cfg = replace_blast(cfg, s_max=s_max, decay=d, total_steps=steps)
        tw, ppl, sp = run(cfg, steps)
        row(f"pretrain_blast_s{int(s_max*100)}_d{d}",
            tw * 1e6 / steps,
            f"ppl={ppl:.2f} sparsity={sp:.2f}")


if __name__ == "__main__":
    main()
